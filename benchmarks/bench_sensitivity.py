"""Paper Figures 12 + 13: performance vs fast-memory size, and the minimum
fast-memory size that matches fast-only across depth variants (ResNet-sweep
analogue: layer-count sweep of smollm)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_ARCHS, bench_profile
from repro.configs.base import get_config
from repro.core import hmsim, planner, profiler
from repro.core.hardware import PAPER_HM
from repro.models import model
from repro.models.layers import split_params


def run():
    rows = [("bench_sensitivity", "arch", "fast_frac", "slowdown")]
    hw = PAPER_HM
    for arch in BENCH_ARCHS[:4]:
        cfg, prof = bench_profile(arch)
        peak = prof.peak_bytes()
        base = hmsim.simulate_static(prof, hw, "fast").step_time
        for frac in (0.2, 0.3, 0.4, 0.6, 0.8, 1.0):
            pl = planner.plan(prof, hw, frac * peak)
            rows.append(("bench_sensitivity", arch, frac,
                         round(pl.sim.step_time / base, 4)))
    return rows


def run_depth_sweep():
    """Fig. 13 analogue: peak footprint grows ~linearly with depth while the
    fast memory needed for <=2% slowdown grows much slower."""
    rows = [("bench_depth", "layers", "peak_MB", "min_fast_MB",
             "min_fast_frac")]
    hw = PAPER_HM
    for L in (4, 8, 16):
        base_cfg = get_config("smollm-360m")
        cfg = dataclasses.replace(base_cfg, num_layers=L, d_model=256,
                                  num_heads=8, num_kv_heads=4, d_ff=1024,
                                  head_dim=32, vocab_size=2048,
                                  dtype="float32")
        params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
        pshapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        b = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
        prof = profiler.trace_profile(
            jax.grad(lambda p, bb: model.loss_fn(p, cfg, bb,
                                                 unroll_periods=True)),
            pshapes, b, num_periods=cfg.num_periods)
        peak = prof.peak_bytes()
        base = hmsim.simulate_static(prof, hw, "fast").step_time
        lo, hi = 0.05, 1.0
        for _ in range(8):   # bisect the minimum adequate fast size
            mid = 0.5 * (lo + hi)
            pl = planner.plan(prof, hw, mid * peak)
            if pl.sim.step_time <= 1.02 * base:
                hi = mid
            else:
                lo = mid
        rows.append(("bench_depth", L, round(peak / 1e6, 1),
                     round(hi * peak / 1e6, 1), round(hi, 3)))
    return rows


if __name__ == "__main__":
    for r in run() + run_depth_sweep():
        print(",".join(map(str, r)))
