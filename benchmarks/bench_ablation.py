"""Paper Figure 11: feature ablations — page-level false sharing, short-lived
space reservation, test-and-trial. Performance normalized to full Sentinel."""
from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, bench_profile
from repro.core import hmsim, planner
from repro.core.hardware import PAPER_HM


def run(fast_frac: float = 0.25):
    rows = [("bench_ablation", "arch", "full", "having_false_sharing",
             "no_space_reservation", "no_test_and_trial")]
    hw = PAPER_HM
    for arch in BENCH_ARCHS[:4]:
        cfg, prof = bench_profile(arch)
        fast = fast_frac * prof.peak_bytes()
        plan = planner.plan(prof, hw, fast)
        mi = plan.mi
        full = plan.sim.step_time
        fs = hmsim.simulate_sentinel_tt(prof, hw, fast, mi,
                                        granularity="page",
                                        page_mode="original").step_time
        nores = hmsim.simulate_sentinel_tt(prof, hw, fast, mi,
                                           reserve_pool=False).step_time
        nott = hmsim.simulate_sentinel(prof, hw, fast, mi,
                                       stall_on_case3=True).step_time
        rows.append(("bench_ablation", arch, 1.0,
                     round(full / fs, 3), round(full / nores, 3),
                     round(full / nott, 3)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
