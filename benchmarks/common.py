"""Shared benchmark plumbing: profiled traces per arch (cached), csv output."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import profiler
from repro.models import model
from repro.models.layers import split_params

# Benchmark models: reduced-but-nontrivial variants of the assigned archs +
# the paper's own LSTM. (The paper benches 5 models; we bench our 11.)
BENCH_ARCHS = ["smollm-360m", "gemma2-2b", "granite-moe-3b-a800m",
               "zamba2-7b", "xlstm-1.3b", "lstm-ptb"]


@functools.lru_cache(maxsize=None)
def bench_profile(arch: str, batch: int = 8, seq: int = 128):
    """One profiled training step (the paper's dynamic profiling phase)."""
    base = get_config(arch)
    cfg = dataclasses.replace(
        base, num_layers=len(base.prologue) + 4 * base.period_len,
        d_model=256,
        num_heads=8, num_kv_heads=min(base.num_kv_heads, 4), d_ff=1024
        if base.d_ff else 0, head_dim=32, vocab_size=2048,
        q_lora_rank=0, kv_lora_rank=64 if base.kv_lora_rank else 0,
        qk_nope_dim=32 if base.qk_nope_dim else 0,
        qk_rope_dim=16 if base.qk_rope_dim else 0,
        v_head_dim=32 if base.v_head_dim else 0,
        prologue_d_ff=1024 if base.prologue else 0,
        moe=dataclasses.replace(base.moe, d_ff=256) if base.moe else None,
        ssm=dataclasses.replace(base.ssm, state_dim=32, head_dim=16, chunk=32)
        if base.ssm else None,
        num_prefix_tokens=16 if base.num_prefix_tokens else 0,
        sliding_window=min(base.sliding_window, 32) if base.sliding_window else 0,
        dtype="float32")
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    pshapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           params)
    if cfg.num_codebooks:
        tok = jax.ShapeDtypeStruct((batch, seq, cfg.num_codebooks), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    b = {"tokens": tok, "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.num_prefix_tokens:
        b["prefix_embed"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
        b["labels"] = jax.ShapeDtypeStruct(
            (batch, seq + cfg.num_prefix_tokens), jnp.int32)
    prof = profiler.trace_profile(
        jax.grad(lambda p, bb: model.loss_fn(p, cfg, bb, unroll_periods=True)),
        pshapes, b, num_periods=cfg.num_periods)
    return cfg, prof


def emit(name: str, rows):
    """name,us_per_call,derived CSV convention + readable table."""
    for r in rows:
        print(",".join(str(x) for x in r))
