"""Paper Figures 1-4 + Tables 1/5: data-object distributions, false sharing,
profiling footprint overhead — from the jaxpr profiler."""
from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, bench_profile
from repro.core import allocator


def run():
    rows = [("bench_profile", "arch", "objects", "frac_short_lived",
             "hot10_access_share", "false_shared_pages_frac",
             "profiling_overhead_frac", "peak_MB", "rs_MB")]
    for arch in BENCH_ARCHS:
        cfg, prof = bench_profile(arch)
        acts = [o for o in prof.objects if o.kind == "activation"]
        short = prof.short_lived(include_fused=True)
        hot = sorted(acts, key=lambda o: -o.reads)[:max(1, len(acts) // 10)]
        share = sum(o.reads for o in hot) / max(1, sum(o.reads for o in acts))
        fs = allocator.false_sharing_stats(prof)
        ov = allocator.profiling_overhead(prof)
        rows.append(("bench_profile", arch, len(prof.objects),
                     round(len(short) / max(1, len(acts)), 3),
                     round(share, 3),
                     round(fs["false_sharing_frac"], 3),
                     round(ov["overhead_frac"], 3),
                     round(prof.peak_bytes() / 1e6, 1),
                     round(prof.rs_bytes(1) / 1e6, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
