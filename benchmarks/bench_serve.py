"""Sentinel-Serve: simulated decode throughput, fast-memory fraction x batch
slots x placement policy — plus the paged/per-slot engine smoke.

The serving analogue of the paper's Fig. 10 sweep, dispatched entirely
through the unified runtime API (``runtime.plan`` + the one policy
registry): per-slot, per-layer KV blocks are the data objects; ``sentinel``
(lifetime-aware, object-granular, look-ahead prefetch via the decode-phase
planner) against the page-grain reactive LRU daemon and static PreferHBM
placement.  ``--policies`` accepts *any* registered policy — including the
training-native ``sentinel_mi`` / ``ial`` / ``all_slow`` — because every
policy runs on every workload.

    PYTHONPATH=src python -m benchmarks.bench_serve
    PYTHONPATH=src python -m benchmarks.bench_serve \
        --arch smollm-360m --fracs 0.1,0.2 --slots 4 --policies sentinel,lru_page
    PYTHONPATH=src python -m benchmarks.bench_serve \
        --objective latency --paged --shared-prefix --json BENCH_serve.json

Exits non-zero if the Sentinel object policy loses to the best page-grain
baseline at the paper's headline 20% fast-memory fraction — the CI smoke
gate.  ``--objective latency`` additionally runs the time-domain sweep:
every policy's recorded per-step traffic is priced on the shared default
``CostModel`` (``core.hardware.default_cost_model``) and the gates move
from migration bytes to *simulated seconds* — at 20% fast memory
``sentinel`` must be at least as fast as ``lru_page`` in predicted time
and within 8% of ``all_fast`` (the paper's headline parity claim), and the
latency-objective planner must pick ``alpha_migration`` somewhere it beats
the bytes-objective plan's predicted time.  ``--paged`` additionally runs the real ContinuousBatcher in the
tiered layouts (global-boundary concat, per-slot paged, and the persistent
page pools with ``use_paged_decode`` — attention writing into and reading
from the physical pools through ``ops.paged_decode_attention``) on a
reduced model and gates on the paged paths (a) reproducing the all-HBM
tokens and (b) re-hosting strictly fewer simulated migration bytes than the
concat path.  ``--shared-prefix`` runs the N-tenants x one-system-prompt
workload shared vs unshared — simulator sweep plus the pool engine with
``prefix_key`` sharing — and gates shared migration bytes AND peak pool
bytes strictly below the unshared run at 20% fast memory.  ``--tenants``
runs the adversarial multi-tenant SLO mix and gates ``sentinel_slo`` at
zero per-tenant quota violations (exactly where the tenant-blind
``sentinel`` violates at least one tenant's guarantee) with aggregate
migration bytes within 1.2x of the blind run.  ``--disagg`` runs the
prefill/decode disaggregation gates: the ``DisaggregatedEngine`` must
emit bit-identical tokens to the single-device pools engine with zero
steady-state re-packs, its cross-device migration ledger must equal the
planner's predicted edge traffic integer-exactly, and ``price_disagg``
must show disaggregated tokens/sec at or above colocated at equal total
HBM under a prefill-heavy mix.  ``--prefill`` runs the cache-aware prefill
gates on the real pool engine: shared-prefix admits must *run* strictly
fewer prefill tokens than the unshared stream (the donor pages' compute is
skipped), and on a burst mix the chunked engine must keep the p95 priced
decode-step gap strictly below one-shot admission at tokens/sec no worse —
both bit-identical to the dense all-HBM reference.  ``--json`` publishes every row (and the
gate verdicts) for trend tracking across PRs.
"""
from __future__ import annotations

import argparse
import json

from repro import runtime
from repro.configs.base import get_config
from repro.core import hmsim
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.serve.engine import serve_trace_for

ARCH = "smollm-360m"
FRACS = (0.1, 0.2, 0.4, 0.8)
SLOTS = (4, 8)
# default sweep: the serving-native trio (any registered policy is allowed)
SERVE_POLICIES = ("lru_page", "prefer_fast", "sentinel")


def build_trace(cfg, slots: int) -> hmsim.ServeTrace:
    # full-size byte geometry (real KV/weight volumes decide placement
    # quality), coarsened to one object per 8-layer KV block so the pure-
    # Python sweep stays a smoke test
    reqs = hmsim.synthetic_requests(3 * slots)
    return serve_trace_for(cfg, reqs, slots=slots, layer_group=8)


def run(arch: str = ARCH, fracs=FRACS, slots_list=SLOTS, policies=None):
    cfg = get_config(arch)
    pols = policies or list(SERVE_POLICIES)
    rows = [("bench_serve", "hw", "slots", "fast_frac", "policy",
             "tok_per_s", "slowdown", "migrations", "slow_gb")]
    verdicts = []
    for hw, hw_name in ((TPU_V5E, "tpu-v5e"), (PAPER_HM, "paper-hm")):
        for slots in slots_list:
            trace = build_trace(cfg, slots)
            peak = trace.peak_kv_bytes()
            # plan once at the headline fraction; the chosen look-ahead is a
            # property of the access schedule, not of the budget
            pl = runtime.plan(trace, hw, 0.2 * peak)
            for frac in fracs:
                fast = frac * peak
                best = {}
                for pol in pols:
                    knobs = ({"lookahead": pl.lookahead}
                             if pol == "sentinel" else {})
                    r = runtime.simulate(trace, hw, fast, pol, **knobs)
                    best[pol] = r
                    rows.append(("bench_serve", hw_name, slots, frac, pol,
                                 round(r.decode_throughput, 1),
                                 round(r.slowdown, 4), r.migrations,
                                 round(r.slow_bytes_accessed / 1e9, 3)))
                if abs(frac - 0.2) < 1e-9 and \
                        {"sentinel", "lru_page"} <= set(best):
                    page = best["lru_page"].decode_throughput
                    verdicts.append((hw_name, slots,
                                     best["sentinel"].decode_throughput, page))
    return rows, verdicts


def run_latency(arch: str = ARCH, fracs=FRACS, slots_list=SLOTS):
    """Time-domain sweep (``--objective latency``): price each policy's
    recorded per-step traffic on the shared default cost model and compare
    predicted seconds, the measurement ``runtime.plan(objective="latency")``
    selects by.  Returns rows, the 20% gate inputs
    ``(slots, sentinel_s, lru_page_s, all_fast_s)``, and the cells where the
    latency-objective plan picked ``alpha_migration`` and beat the
    bytes-objective plan's predicted time."""
    from repro.core.hardware import default_cost_model
    cm = default_cost_model()
    cfg = get_config(arch)
    rows = [("bench_serve_latency", "slots", "fast_frac", "policy",
             "pred_tok_per_s", "pred_slowdown", "pred_time_s")]
    gates = []
    alpha_wins = []
    for slots in slots_list:
        trace = build_trace(cfg, slots)
        peak = trace.peak_kv_bytes()
        for frac in fracs:
            fast = frac * peak
            pl_lat = runtime.plan(trace, cm, fast, objective="latency")
            pl_byt = runtime.plan(trace, cm, fast)
            t_bytes = cm.price_result(pl_byt.sim).time
            reps = {}
            for pol in ("sentinel", "lru_page", "all_fast"):
                knobs = ({"lookahead": pl_lat.lookahead}
                         if pol == "sentinel" else {})
                r = runtime.simulate(trace, cm, fast, pol, **knobs)
                reps[pol] = rep = cm.price_result(r)
                rows.append(("bench_serve_latency", slots, frac, pol,
                             round(rep.tokens_per_s, 1),
                             round(rep.slowdown, 4), round(rep.time, 6)))
            rows.append(("bench_serve_latency", slots, frac,
                         f"plan:{pl_lat.policy}",
                         round(pl_lat.predicted_decode_throughput, 1),
                         round(pl_lat.predicted_time
                               / max(reps["all_fast"].time, 1e-30), 4),
                         round(pl_lat.predicted_time, 6)))
            if abs(frac - 0.2) < 1e-9:
                gates.append((slots, reps["sentinel"].time,
                              reps["lru_page"].time, reps["all_fast"].time))
            if pl_lat.policy == "alpha_migration" and \
                    pl_lat.predicted_time < t_bytes:
                alpha_wins.append((slots, frac,
                                   round(pl_lat.predicted_time, 6),
                                   round(t_bytes, 6)))
    return rows, gates, alpha_wins


def run_shared_prefix(fracs=FRACS):
    """Prefix-sharing sweep on the unified surface: the N-tenants x one
    system prompt workload, shared (KV blocks of the common prefix are one
    physical allocation) vs the byte-identical unshared stream, under the
    ``sentinel`` policy.  Returns rows and the 20% gate inputs
    (shared/unshared migration bytes and physical peaks)."""
    from repro.runtime.synthetic import synthetic_shared_prefix_trace
    ts = synthetic_shared_prefix_trace(shared=True)
    tu = synthetic_shared_prefix_trace(shared=False)
    peak_s, peak_u = ts.peak_kv_bytes(), tu.peak_kv_bytes()
    rows = [("bench_serve_shared", "fast_frac", "mode", "tok_per_s",
             "migration_mb", "peak_mb")]
    gate = None
    for frac in fracs:
        fast = frac * peak_u                   # matched budget for both
        rs = runtime.simulate(ts, TPU_V5E, fast, "sentinel")
        ru = runtime.simulate(tu, TPU_V5E, fast, "sentinel")
        for mode, r, peak in (("shared", rs, peak_s), ("unshared", ru, peak_u)):
            rows.append(("bench_serve_shared", frac, mode,
                         round(r.decode_throughput, 1),
                         round((r.bytes_s2f + r.bytes_f2s) / 1e6, 4),
                         round(peak / 1e6, 4)))
        if abs(frac - 0.2) < 1e-9:
            gate = (rs.bytes_s2f + rs.bytes_f2s,
                    ru.bytes_s2f + ru.bytes_f2s, peak_s, peak_u)
    return rows, gate


def run_tenants(fracs=FRACS):
    """Multi-tenant SLO sweep on the unified surface: the adversarial
    chatty-vs-bursty mix (``synthetic_multi_tenant_trace``) under the
    tenant-blind ``sentinel`` vs the SLO-aware ``sentinel_slo``, both
    measured against the same per-tenant guarantees.  Returns rows plus the
    20% gate inputs: (blind violations, slo violations, blind migration
    bytes, slo migration bytes)."""
    from repro.runtime.synthetic import synthetic_multi_tenant_trace
    wl = synthetic_multi_tenant_trace()
    peak = wl.trace.peak_kv_bytes()
    rows = [("bench_serve_tenants", "fast_frac", "policy", "tok_per_s",
             "violations", "migration_mb", "tenant_fast_mb")]
    gate = None
    for frac in fracs:
        fast = frac * peak
        rb = runtime.simulate(wl, TPU_V5E, fast, "sentinel",
                              tenant_quotas=wl.tenant_quotas)
        rs = runtime.simulate(wl, TPU_V5E, fast, "sentinel_slo",
                              tenant_quotas=wl.tenant_quotas,
                              tenant_slack=wl.tenant_slack)
        for pol, r in (("sentinel", rb), ("sentinel_slo", rs)):
            # flat comma-free encoding: CSV rows keep a fixed column count
            per_tenant = "|".join(
                f"{k}:{round(v / 1e6, 3)}"
                for k, v in sorted(r.tenant_fast_bytes.items()))
            rows.append(("bench_serve_tenants", frac, pol,
                         round(r.decode_throughput, 1),
                         sum(r.tenant_violations.values()),
                         round((r.bytes_s2f + r.bytes_f2s) / 1e6, 4),
                         per_tenant))
        if abs(frac - 0.2) < 1e-9:
            gate = (sum(rb.tenant_violations.values()),
                    sum(rs.tenant_violations.values()),
                    rb.bytes_s2f + rb.bytes_f2s, rs.bytes_s2f + rs.bytes_f2s)
    return rows, gate


def run_shared_prefix_engine(arch: str = ARCH):
    """Real-engine prefix sharing: the persistent-pool batcher decoding two
    tenants off one system prompt, with and without ``prefix_key`` sharing.
    Gates on (a) tokens identical to the all-HBM reference in both runs and
    (b) shared migration bytes AND peak pool bytes strictly below
    unshared."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine

    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, slots = 32, 2
    sys_p = jax.random.randint(jax.random.PRNGKey(7), (9,), 0,
                               cfg.vocab_size).astype(jnp.int32)
    reqs = []
    for i in range(4):
        user = jax.random.randint(jax.random.PRNGKey(11 + i), (2 + i,), 0,
                                  cfg.vocab_size).astype(jnp.int32)
        reqs.append((jnp.concatenate([sys_p, user]), 5 + i % 2))
    trace = serve_trace_for(get_config(arch),
                            [(int(t.shape[0]), d, 0) for t, d in reqs],
                            slots=slots, layer_group=8,
                            shared_prefix_tokens=int(sys_p.shape[0]))
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def drive(c, p, paged, shared):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged)
        for t, d in reqs:
            b.submit(t, d, prefix_key="sys" if shared else None)
        out = b.run()
        if b.pool is None:
            return out, 0.0, 0.0
        page_bytes = b.page_tokens * b._row_bytes
        return out, b.sim_migration_bytes, b.pool.peak_pages * page_bytes

    base, _, _ = drive(cfg, None, False, False)
    out_s, mig_s, peak_s = drive(cfg_k, plan, True, True)
    out_u, mig_u, peak_u = drive(cfg_k, plan, True, False)
    match = base == out_s == out_u
    rows = [("bench_serve_shared_engine", "mode", "migration_kb", "peak_kb",
             "tokens_match"),
            ("bench_serve_shared_engine", "shared", round(mig_s / 1e3, 3),
             round(peak_s / 1e3, 3), match),
            ("bench_serve_shared_engine", "unshared", round(mig_u / 1e3, 3),
             round(peak_u / 1e3, 3), match)]
    return rows, (match, mig_s, mig_u, peak_s, peak_u)


def run_paged_smoke(arch: str = ARCH):
    """Real-engine comparison: concat (global cold boundary) vs paged
    (per-slot boundaries) vs paged-kernel (attention reads the page pools
    directly) tiering on a reduced model.  Returns rows and the
    (tokens_match, paged_bytes, concat_bytes) verdict."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine

    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    requests = [(7, 6), (9, 5), (6, 7), (8, 6)]
    trace = serve_trace_for(get_config(arch), requests, slots=slots,
                            layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    # shrink the planned windows to the reduced max_seq so both layouts
    # carry a real cold prefix (the full-size plan would keep everything hot)
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def drive(c, p, paged=False):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged)
        key = jax.random.PRNGKey(3)
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(sub, (plen,), 0,
                                        cfg.vocab_size).astype(jnp.int32), d)
        return b.run(), b.sim_migration_bytes

    base, _ = drive(cfg, None)
    out_c, bytes_c = drive(cfg, plan)
    out_p, bytes_p = drive(cfg, plan, paged=True)
    cfg_kernel = dataclasses.replace(cfg, use_paged_decode=True)
    out_k, bytes_k = drive(cfg_kernel, plan, paged=True)
    match = base == out_c == out_p == out_k
    rows = [("bench_serve_paged", "mode", "migration_mb", "tokens_match"),
            ("bench_serve_paged", "concat", round(bytes_c / 1e6, 4), match),
            ("bench_serve_paged", "paged", round(bytes_p / 1e6, 4), match),
            ("bench_serve_paged", "paged_kernel", round(bytes_k / 1e6, 4),
             match)]
    # both paged variants must beat concat (the kernel path changes the read
    # layout, never the demotion accounting — gate on the max of the two)
    return rows, (match, max(bytes_p, bytes_k), bytes_c)


def run_prefill(arch: str = ARCH):
    """Cache-aware prefill gates on the real pool engine.

    (a) Shared-prefix compute skip: admitting N requests off one system
        prompt with ``prefix_key`` set must *run* strictly fewer prefill
        tokens than the byte-identical unshared stream — the rows whose KV
        maps onto the donor's pages are never recomputed
        (``prefill_compute_tokens`` / ``prefill_skipped_tokens``) — with
        tokens identical to the dense all-HBM reference.
    (b) Chunked prefill: on a burst mix (one long-decode anchor slot plus a
        crowd of long prompts) the chunked engine must emit the same token
        set as one-shot admission while its p95 priced decode-step gap
        drops and tokens/sec does not: each engine step is priced through
        ``CostModel.step_time`` — ``chunked_prefill=True`` folds the step's
        prefill tokens into the pipe maximum (chunks hide behind decode),
        the one-shot run serializes them after the step.

    Returns rows and the verdict tuple ``(match_skip, compute_shared,
    compute_unshared, match_chunk, p95_chunk, p95_oneshot, tok_s_chunk,
    tok_s_oneshot)``.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.hardware import default_cost_model
    from repro.models import model
    from repro.models.layers import split_params
    from repro.runtime.costmodel import StepTraffic
    from repro.serve import engine

    cfg0 = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg0, use_paged_decode=True)
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    cm = default_cost_model()

    def drive(c, p, reqs, paged, chunk=0, keys=None):
        if p is not None:
            p = dataclasses.replace(p, prefill_chunk_tokens=chunk)
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged)
        for i, (t, d) in enumerate(reqs):
            b.submit(t, d, prefix_key=keys[i] if keys else None)
        results, deltas, prev = [], [], 0
        while b.queue or b._jobs or any(b.active):
            if not b.step():
                break
            for i in range(slots):
                if not b.active[i] and b.outputs[i]:
                    results.append(b.outputs[i])
                    b.outputs[i] = []
            cur = sum(len(r) for r in results) \
                + sum(len(o) for o in b.outputs)
            deltas.append(cur - prev)
            prev = cur
        return results, deltas, b.counters()

    def canon(outs):
        return sorted(tuple(o) for o in outs)

    # --- (a) shared-prefix compute skip ------------------------------------
    sys_p = jax.random.randint(jax.random.PRNGKey(7), (9,), 0,
                               cfg.vocab_size).astype(jnp.int32)
    sreqs = []
    for i in range(4):
        user = jax.random.randint(jax.random.PRNGKey(11 + i), (2 + i,), 0,
                                  cfg.vocab_size).astype(jnp.int32)
        sreqs.append((jnp.concatenate([sys_p, user]), 5 + i % 2))
    strace = serve_trace_for(get_config(arch),
                             [(int(t.shape[0]), d, 0) for t, d in sreqs],
                             slots=slots, layer_group=8,
                             shared_prefix_tokens=int(sys_p.shape[0]))
    splan = runtime.plan(strace, TPU_V5E, 0.2 * strace.peak_kv_bytes())
    splan = dataclasses.replace(splan, hot_window=max_seq // 2,
                                slot_hot_windows=[4, 8], page_tokens=4)
    base_s, _, _ = drive(cfg0, None, sreqs, False)
    out_sh, _, cnt_sh = drive(cfg, splan, sreqs, True, keys=["sys"] * 4)
    out_un, _, cnt_un = drive(cfg, splan, sreqs, True)
    match_skip = canon(base_s) == canon(out_sh) == canon(out_un)
    comp_sh = cnt_sh["prefill_compute_tokens"]
    comp_un = cnt_un["prefill_compute_tokens"]

    # --- (b) chunked prefill on a burst mix --------------------------------
    lens = [(6, 18), (20, 5), (18, 5), (19, 4)]
    key, breqs = jax.random.PRNGKey(5), []
    for plen, d in lens:
        key, sub = jax.random.split(key)
        breqs.append((jax.random.randint(sub, (plen,), 0,
                                         cfg.vocab_size).astype(jnp.int32),
                      d))
    btrace = serve_trace_for(get_config(arch), lens, slots=slots,
                             layer_group=8)
    bplan = runtime.plan(btrace, TPU_V5E, 0.2 * btrace.peak_kv_bytes())
    bplan = dataclasses.replace(bplan, hot_window=max_seq // 2,
                                slot_hot_windows=[4, 8], page_tokens=4)
    base_b, _, _ = drive(cfg0, None, breqs, False)
    out_1, d_1, c_1 = drive(cfg, bplan, breqs, True, chunk=0)
    out_c, d_c, c_c = drive(cfg, bplan, breqs, True, chunk=8)
    match_chunk = canon(base_b) == canon(out_1) == canon(out_c)

    # per-step gap pricing: decode tokens (output-count delta) and prefill
    # tokens drawn from the engines' own step series; weight/KV streaming is
    # identical in both runs, so the gap is priced on what the chunker
    # actually moves — the compute pipe and the per-token KV reads
    ft = getattr(btrace, "flops_per_token", 0.0) or 1e9
    rb = btrace.num_layers * btrace.kv_token_bytes

    def gaps(deltas, prefill_tokens, chunked):
        sp = list(prefill_tokens) + [0] * (len(deltas) - len(prefill_tokens))
        out = []
        for dtok, ptok in zip(deltas, sp):
            tr = StepTraffic(flops=dtok * ft, fast_read=dtok * rb,
                             tokens=dtok, prefill_flops=ptok * ft)
            out.append(cm.step_time(tr, chunked_prefill=chunked))
        return out

    def p95(series):
        s = sorted(series)
        return s[int(round(0.95 * (len(s) - 1)))] if s else 0.0

    g_1 = gaps(d_1, c_1["step_prefill_tokens"], chunked=False)
    g_c = gaps(d_c, c_c["step_prefill_tokens"], chunked=True)
    p95_1, p95_c = p95(g_1), p95(g_c)
    tok_1 = sum(d_1) / max(sum(g_1), 1e-30)
    tok_c = sum(d_c) / max(sum(g_c), 1e-30)

    ft_s = getattr(strace, "flops_per_token", 0.0) or 1e9
    rows = [("bench_serve_prefill", "metric", "value"),
            ("bench_serve_prefill", "tokens_match_skip", match_skip),
            ("bench_serve_prefill", "prefill_compute_tokens_shared", comp_sh),
            ("bench_serve_prefill", "prefill_compute_tokens_unshared",
             comp_un),
            ("bench_serve_prefill", "prefill_skipped_tokens",
             cnt_sh["prefill_skipped_tokens"]),
            ("bench_serve_prefill", "prefill_gflops_saved",
             round((comp_un - comp_sh) * ft_s / 1e9, 4)),
            ("bench_serve_prefill", "tokens_match_chunk", match_chunk),
            ("bench_serve_prefill", "p95_gap_oneshot_us",
             round(p95_1 * 1e6, 4)),
            ("bench_serve_prefill", "p95_gap_chunked_us",
             round(p95_c * 1e6, 4)),
            ("bench_serve_prefill", "tok_s_oneshot", round(tok_1, 1)),
            ("bench_serve_prefill", "tok_s_chunked", round(tok_c, 1))]
    return rows, (match_skip, comp_sh, comp_un, match_chunk,
                  p95_c, p95_1, tok_c, tok_1)


def run_disagg(arch: str = ARCH):
    """Prefill/decode disaggregation: the real engine pair plus the
    planner-side throughput model.

    (c) ``DisaggregatedEngine`` must emit bit-identical tokens to the
        single-device ``ContinuousBatcher`` in the pools layout with zero
        steady-state re-packs; (b) its cross-device migration ledger must
        equal ``predict_pool_counters``'s predicted edge traffic exactly;
        (a) ``price_disagg`` must show disaggregated tokens/sec at or above
        colocated at equal total HBM under a prefill-heavy mix.

    Returns rows and the verdict tuple
    ``(match, repacks, xdev_actual, xdev_pred, tok_s_disagg, tok_s_coloc)``.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core.hardware import default_cost_model
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine
    from repro.serve.disagg import DisaggregatedEngine, price_disagg
    from repro.serve.engine import predict_pool_counters

    cfg = dataclasses.replace(get_config(arch).reduced(),
                              use_paged_decode=True)
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    requests = [(7, 6), (9, 5), (6, 7), (8, 6)]
    trace = serve_trace_for(get_config(arch), requests, slots=slots,
                            layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def drive(eng_cls, **kw):
        b = eng_cls(params, cfg, slots, max_seq, plan=plan, **kw)
        key = jax.random.PRNGKey(3)
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(sub, (plen,), 0,
                                        cfg.vocab_size).astype(jnp.int32), d)
        return b.run(), b

    out_c, _ = drive(engine.ContinuousBatcher, paged=True)
    out_d, bd = drive(DisaggregatedEngine)
    match = out_c == out_d
    repacks = bd.counters()["repacks"]
    xdev = bd.xdev_migration_bytes
    pred = predict_pool_counters(requests, plan, slots=slots,
                                 max_seq=max_seq,
                                 page_tokens=bd.page_tokens,
                                 row_bytes=bd._row_bytes)
    xdev_pred = pred["xdev_migration_bytes"]

    # (a) the planner-side throughput model on a prefill-heavy mix: long
    # prompts, short decodes — the regime disaggregation exists for
    heavy = [(480, 24), (512, 16), (448, 32), (500, 20)]
    htrace = serve_trace_for(get_config(arch), heavy, slots=len(heavy),
                             layer_group=8)
    priced = price_disagg(htrace, default_cost_model(),
                          0.2 * htrace.peak_kv_bytes())
    tok_c = priced["colocated"].tokens_per_s
    tok_d = priced["disagg"].tokens_per_s

    rows = [("bench_serve_disagg", "metric", "value"),
            ("bench_serve_disagg", "tokens_match", match),
            ("bench_serve_disagg", "repacks", repacks),
            ("bench_serve_disagg", "xdev_migration_kb",
             round(xdev / 1e3, 3)),
            ("bench_serve_disagg", "xdev_predicted_kb",
             round(xdev_pred / 1e3, 3)),
            ("bench_serve_disagg", "edge_stream_mb",
             round(priced["edge_bytes"] / 1e6, 4)),
            ("bench_serve_disagg", "colocated_tok_s", round(tok_c, 1)),
            ("bench_serve_disagg", "disagg_tok_s", round(tok_d, 1))]
    return rows, (match, repacks, xdev, xdev_pred, tok_d, tok_c)


_SHARDED_SCENARIO = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json, time
import jax
import jax.numpy as jnp
from repro import runtime
from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.models import model
from repro.models.layers import split_params
from repro.serve.disagg import DisaggregatedEngine
from repro.serve.engine import predict_pool_counters, serve_trace_for

ARCH = %(arch)r
cfg = dataclasses.replace(get_config(ARCH).reduced(), use_paged_decode=True)
params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
max_seq, slots = 64, 4
requests = [(48, 12)] * 4            # prefill-heavy: long prompts, short gen
trace = serve_trace_for(get_config(ARCH), requests, slots=slots,
                        layer_group=8)
plan = runtime.plan(trace, TPU_V5E, 0.3 * trace.peak_kv_bytes())
plan = dataclasses.replace(plan, hot_window=32, slot_hot_windows=None,
                           page_tokens=8)

def drive(devices, sd, seed=3):
    b = DisaggregatedEngine(params, cfg, slots, max_seq,
                            plan=dataclasses.replace(plan, slot_devices=sd),
                            devices=devices)
    key = jax.random.PRNGKey(seed)
    for plen, d in requests:
        key, sub = jax.random.split(key)
        b.submit(jax.random.randint(sub, (plen,), 0,
                                    cfg.vocab_size).astype(jnp.int32), d)
    t0 = time.perf_counter()
    outs = b.run()
    dt = time.perf_counter() - t0
    return sum(len(o) for o in outs) / dt, b

devs = jax.devices()
drive(devs[:2], None)                          # compile warmup, both shapes
drive(devs, [s %% 2 for s in range(slots)])
tps1, _ = drive(devs[:2], None)
tps2, b2 = drive(devs, [s %% 2 for s in range(slots)])
b2.mesh_table.check()
pred = predict_pool_counters(
    requests, dataclasses.replace(plan, slot_devices=[s %% 2
                                                      for s in range(slots)]),
    slots=slots, max_seq=max_seq, page_tokens=b2.page_tokens,
    row_bytes=b2._row_bytes, dense_admit=True)
ledger_exact = (dict(b2.mesh_table.edge_bytes)
                == pred["edge_migration_bytes"])

# measured overlap: one decode step with vs without a concurrent KV-page
# stream over the prefill->decode edge.  Primed on a fresh engine so every
# timed step has all slots active and no admissions in flight.
b = DisaggregatedEngine(params, cfg, slots, max_seq,
                        plan=dataclasses.replace(
                            plan, slot_devices=[s %% 2
                                                for s in range(slots)]),
                        devices=devs)
key = jax.random.PRNGKey(5)
for plen, _d in requests:
    key, sub = jax.random.split(key)
    b.submit(jax.random.randint(sub, (plen,), 0,
                                cfg.vocab_size).astype(jnp.int32), 12)
while b.queue or b._jobs:
    b.step()
b.step()                                       # compile the decode step

D = cfg.num_kv_heads * cfg.head_dim
payload = jnp.zeros((cfg.num_layers, 2, 4, b.page_tokens, D), jnp.float32)
payload = jax.device_put(payload, b.prefill_devices[0])
payload.block_until_ready()
stream_bytes = float(payload.size * 4)

def t_stream():
    t0 = time.perf_counter()
    y = jax.device_put(payload, b.decode_devices[0])
    y.block_until_ready()
    return time.perf_counter() - t0

def t_step(with_stream):
    t0 = time.perf_counter()
    y = jax.device_put(payload, b.decode_devices[0]) if with_stream else None
    b.step()
    jax.block_until_ready(b.last_tok)
    if y is not None:
        y.block_until_ready()
    return time.perf_counter() - t0

def med(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]

stream_s = med([t_stream() for _ in range(5)])
plain, both = [], []
while any(b.active) and len(plain) < 8:
    plain.append(t_step(False))
    if any(b.active):
        both.append(t_step(True))
plain_s, both_s = med(plain), med(both or [0.0])
denom = min(stream_s, plain_s) or 1.0
overlap = max(0.0, min(1.0, (plain_s + stream_s - both_s) / denom))
print(json.dumps({
    "tok_s_single": tps1, "tok_s_sharded": tps2,
    "ledger_exact": ledger_exact,
    "stream_bytes": stream_bytes,
    "step_ms": plain_s * 1e3, "stream_ms": stream_s * 1e3,
    "step_with_stream_ms": both_s * 1e3,
    "overlap_frac": overlap}))
"""


def run_disagg_sharded(arch: str = ARCH):
    """Multi-shard disaggregation: the planner-side scaling gate plus the
    measured KV-stream/decode overlap on a forced 4-device host mesh.

    Gates (deterministic, modeled): (a) ``price_disagg`` with two decode
    shards — each keeping the single run's per-device HBM — must price
    sharded tokens/sec at or above the single-decode disaggregated run on
    a prefill-heavy mix; (b) the live 2-shard engine's per-edge
    ``MeshPageTable`` ledger must equal ``predict_pool_counters``'s
    integer-exactly.  The wall-clock rows (sharded vs single tok/s; one
    decode step with vs without a concurrent prefill->decode KV-page
    stream, next to the cost model's edge-pipe time for the same bytes)
    are published, not gated — the forced host "devices" share the same
    physical cores, so CPU wall-clock says nothing about a real mesh.
    """
    import os
    import subprocess
    import sys

    from repro.core.hardware import default_cost_model
    from repro.serve.disagg import price_disagg

    cm = default_cost_model()
    heavy = [(480, 24), (512, 16), (448, 32), (500, 20)]
    htrace = serve_trace_for(get_config(arch), heavy, slots=len(heavy),
                             layer_group=8)
    fast = 0.2 * htrace.peak_kv_bytes()
    single = price_disagg(htrace, cm, fast)
    # two shards, each with the SAME per-device HBM as the single run:
    # scaling out adds devices, it does not shrink them
    sharded = price_disagg(htrace, cm, 2 * fast, decode_devices=2)
    tok_1 = single["disagg"].tokens_per_s
    tok_n = sharded["disagg"].tokens_per_s

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c",
                          _SHARDED_SCENARIO % {"arch": arch}],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError("sharded disagg scenario failed:\n"
                           + out.stderr[-3000:])
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    modeled_stream_ms = (rec["stream_bytes"] / cm.link_bw * 1e3
                         if cm.link_bw else float("inf"))

    rows = [("bench_serve_disagg_sharded", "metric", "value"),
            ("bench_serve_disagg_sharded", "modeled_single_tok_s",
             round(tok_1, 1)),
            ("bench_serve_disagg_sharded", "modeled_sharded_tok_s",
             round(tok_n, 1)),
            ("bench_serve_disagg_sharded", "ledger_exact",
             rec["ledger_exact"]),
            ("bench_serve_disagg_sharded", "wall_single_tok_s",
             round(rec["tok_s_single"], 2)),
            ("bench_serve_disagg_sharded", "wall_sharded_tok_s",
             round(rec["tok_s_sharded"], 2)),
            ("bench_serve_disagg_sharded", "step_ms",
             round(rec["step_ms"], 3)),
            ("bench_serve_disagg_sharded", "stream_ms_measured",
             round(rec["stream_ms"], 3)),
            ("bench_serve_disagg_sharded", "stream_ms_modeled",
             round(modeled_stream_ms, 6)),
            ("bench_serve_disagg_sharded", "step_with_stream_ms",
             round(rec["step_with_stream_ms"], 3)),
            ("bench_serve_disagg_sharded", "overlap_frac",
             round(rec["overlap_frac"], 3))]
    return rows, (tok_n, tok_1, rec["ledger_exact"], rec)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default=ARCH)
    ap.add_argument("--fracs", default=",".join(map(str, FRACS)),
                    help="comma-separated fast-memory fractions of peak KV")
    ap.add_argument("--slots", default=",".join(map(str, SLOTS)),
                    help="comma-separated batch-slot counts")
    ap.add_argument("--policies", default="",
                    help="comma-separated subset of "
                         f"{runtime.list_policies()}")
    ap.add_argument("--objective", default="bytes",
                    choices=["bytes", "latency"],
                    help="latency: also run the time-domain sweep on the "
                         "default CostModel and gate on predicted seconds")
    ap.add_argument("--paged", action="store_true",
                    help="also run the paged-vs-concat engine smoke + gate")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="also run the prefix-sharing sweep (simulator + "
                         "persistent-pool engine) and gate shared strictly "
                         "below unshared at 20%% fast memory")
    ap.add_argument("--tenants", action="store_true",
                    help="also run the multi-tenant SLO sweep and gate "
                         "sentinel_slo at zero quota violations (where "
                         "tenant-blind sentinel violates) with migration "
                         "bytes within 1.2x, at 20%% fast memory")
    ap.add_argument("--prefill", action="store_true",
                    help="also run the cache-aware prefill gates: shared-"
                         "prefix admits compute strictly fewer prefill "
                         "tokens than unshared, chunked prefill keeps the "
                         "p95 priced decode-step gap below one-shot at "
                         "tokens/sec no worse, both bit-identical to the "
                         "dense all-HBM reference")
    ap.add_argument("--disagg", action="store_true",
                    help="also run the prefill/decode disaggregation gates: "
                         "bit-identical tokens vs the single-device engine "
                         "with zero re-packs, cross-device migration bytes "
                         "equal to the planner's predicted edge traffic, "
                         "disaggregated tokens/sec at or above colocated "
                         "at equal total HBM (prefill-heavy mix), plus the "
                         "2-shard gates on a forced 4-device host mesh: "
                         "modeled sharded tok/s at or above single-decode "
                         "at equal per-shard HBM, the live 2-shard edge "
                         "ledger replay-exact, and the measured-vs-modeled "
                         "KV-stream/decode overlap published")
    ap.add_argument("--json", default="",
                    help="write rows + verdicts to this JSON file")
    args = ap.parse_args(argv)

    fracs = tuple(float(x) for x in args.fracs.split(",") if x)
    slots_list = tuple(int(x) for x in args.slots.split(",") if x)
    policies = [p for p in args.policies.split(",") if p] or None

    rows, verdicts = run(args.arch, fracs, slots_list, policies)
    for r in rows:
        print(",".join(map(str, r)))
    ok = True
    checks = []
    if not verdicts:
        # the headline gate needs frac 0.2 and both sentinel + lru_page; be
        # loud that it did NOT run rather than exiting 0 indistinguishably
        checks.append({"check": "sentinel_vs_page@20%", "status": "SKIPPED",
                       "reason": "requires --fracs containing 0.2 and "
                                 "--policies containing sentinel,lru_page"})
        print("check,sentinel/page@20%,SKIPPED (needs frac 0.2 + both "
              "sentinel and lru_page policies)")
    for hw_name, slots, sent, page in verdicts:
        rel = sent / max(page, 1e-30)
        status = "OK" if rel >= 1.0 else "FAIL"
        ok &= rel >= 1.0
        checks.append({"check": "sentinel_vs_page@20%", "hw": hw_name,
                       "slots": slots, "ratio": round(rel, 4),
                       "status": status})
        print(f"check,{hw_name},slots={slots},sentinel/page@20%={rel:.3f},"
              f"{status}")

    latency_rows = []
    if args.objective == "latency":
        lrows, lgates, alpha_wins = run_latency(args.arch, fracs, slots_list)
        latency_rows += lrows
        for r in lrows:
            print(",".join(map(str, r)))
        if not lgates:
            checks.append({"check": "latency@20%", "status": "SKIPPED",
                           "reason": "requires --fracs containing 0.2"})
            print("check,latency@20%,SKIPPED (needs frac 0.2)")
        for slots, t_s, t_l, t_af in lgates:
            rel_af = t_s / max(t_af, 1e-30)
            l_ok = t_s <= t_l and rel_af <= 1.08
            ok &= l_ok
            checks.append({"check": "latency@20%", "slots": slots,
                           "sentinel_s": round(t_s, 6),
                           "lru_page_s": round(t_l, 6),
                           "all_fast_s": round(t_af, 6),
                           "sentinel_vs_all_fast": round(rel_af, 4),
                           "status": "OK" if l_ok else "FAIL"})
            print(f"check,latency@20%,slots={slots},"
                  f"sentinel={t_s:.6f}s,lru_page={t_l:.6f}s,"
                  f"vs_all_fast={rel_af:.4f},{'OK' if l_ok else 'FAIL'}")
        a_ok = bool(alpha_wins)
        ok &= a_ok
        checks.append({"check": "alpha_beats_bytes_plan",
                       "cells": [list(c) for c in alpha_wins],
                       "status": "OK" if a_ok else "FAIL"})
        print(f"check,alpha_beats_bytes_plan,cells={len(alpha_wins)},"
              f"{'OK' if a_ok else 'FAIL'}")

    paged_rows = []
    if args.paged:
        paged_rows, (match, bytes_p, bytes_c) = run_paged_smoke(args.arch)
        for r in paged_rows:
            print(",".join(map(str, r)))
        paged_ok = match and bytes_p < bytes_c
        ok &= paged_ok
        checks.append({"check": "paged_vs_concat_migration_bytes",
                       "tokens_match": match,
                       "paged_mb": round(bytes_p / 1e6, 4),
                       "concat_mb": round(bytes_c / 1e6, 4),
                       "status": "OK" if paged_ok else "FAIL"})
        print(f"check,paged,match={match},"
              f"paged_mb={bytes_p / 1e6:.4f},concat_mb={bytes_c / 1e6:.4f},"
              f"{'OK' if paged_ok else 'FAIL'}")

    shared_rows = []
    if args.shared_prefix:
        srows, gate = run_shared_prefix(fracs)
        shared_rows += srows
        for r in srows:
            print(",".join(map(str, r)))
        if gate is None:
            checks.append({"check": "shared_prefix@20%", "status": "SKIPPED",
                           "reason": "requires --fracs containing 0.2"})
            print("check,shared_prefix@20%,SKIPPED (needs frac 0.2)")
        else:
            mig_s, mig_u, peak_s, peak_u = gate
            s_ok = mig_s < mig_u and peak_s < peak_u
            ok &= s_ok
            checks.append({"check": "shared_prefix@20%",
                           "migration_shared_mb": round(mig_s / 1e6, 4),
                           "migration_unshared_mb": round(mig_u / 1e6, 4),
                           "peak_shared_mb": round(peak_s / 1e6, 4),
                           "peak_unshared_mb": round(peak_u / 1e6, 4),
                           "status": "OK" if s_ok else "FAIL"})
            print(f"check,shared_prefix@20%,mig={mig_s / 1e6:.4f}/"
                  f"{mig_u / 1e6:.4f}MB,peak={peak_s / 1e6:.4f}/"
                  f"{peak_u / 1e6:.4f}MB,{'OK' if s_ok else 'FAIL'}")
        erows, (match, mig_s, mig_u, peak_s, peak_u) = \
            run_shared_prefix_engine(args.arch)
        shared_rows += erows
        for r in erows:
            print(",".join(map(str, r)))
        e_ok = match and mig_s < mig_u and peak_s < peak_u
        ok &= e_ok
        checks.append({"check": "shared_prefix_engine",
                       "tokens_match": match,
                       "migration_shared_kb": round(mig_s / 1e3, 3),
                       "migration_unshared_kb": round(mig_u / 1e3, 3),
                       "peak_shared_kb": round(peak_s / 1e3, 3),
                       "peak_unshared_kb": round(peak_u / 1e3, 3),
                       "status": "OK" if e_ok else "FAIL"})
        print(f"check,shared_engine,match={match},"
              f"mig={mig_s / 1e3:.3f}/{mig_u / 1e3:.3f}kB,"
              f"peak={peak_s / 1e3:.3f}/{peak_u / 1e3:.3f}kB,"
              f"{'OK' if e_ok else 'FAIL'}")

    tenant_rows = []
    if args.tenants:
        trows, gate = run_tenants(fracs)
        tenant_rows += trows
        for r in trows:
            print(",".join(map(str, r)))
        if gate is None:
            checks.append({"check": "tenant_slo@20%", "status": "SKIPPED",
                           "reason": "requires --fracs containing 0.2"})
            print("check,tenant_slo@20%,SKIPPED (needs frac 0.2)")
        else:
            v_blind, v_slo, mig_blind, mig_slo = gate
            # the SLO claim: guarantees hold exactly where the tenant-blind
            # policy breaks them, at bounded extra migration traffic
            t_ok = v_slo == 0 and v_blind >= 1 and \
                mig_slo <= 1.2 * mig_blind
            ok &= t_ok
            checks.append({"check": "tenant_slo@20%",
                           "violations_blind": v_blind,
                           "violations_slo": v_slo,
                           "migration_blind_mb": round(mig_blind / 1e6, 4),
                           "migration_slo_mb": round(mig_slo / 1e6, 4),
                           "status": "OK" if t_ok else "FAIL"})
            print(f"check,tenant_slo@20%,viol={v_blind}/{v_slo},"
                  f"mig={mig_slo / 1e6:.4f}/{mig_blind / 1e6:.4f}MB,"
                  f"{'OK' if t_ok else 'FAIL'}")

    prefill_rows = []
    if args.prefill:
        prows, (m_skip, comp_sh, comp_un, m_chunk,
                p95_c, p95_1, tok_c, tok_1) = run_prefill(args.arch)
        prefill_rows += prows
        for r in prows:
            print(",".join(map(str, r)))
        p_ok = m_skip and comp_sh < comp_un \
            and m_chunk and p95_c < p95_1 and tok_c >= tok_1
        ok &= p_ok
        checks.append({"check": "prefill",
                       "tokens_match_skip": m_skip,
                       "prefill_compute_tokens_shared": comp_sh,
                       "prefill_compute_tokens_unshared": comp_un,
                       "tokens_match_chunk": m_chunk,
                       "p95_gap_chunked_us": round(p95_c * 1e6, 4),
                       "p95_gap_oneshot_us": round(p95_1 * 1e6, 4),
                       "tok_s_chunked": round(tok_c, 1),
                       "tok_s_oneshot": round(tok_1, 1),
                       "status": "OK" if p_ok else "FAIL"})
        print(f"check,prefill,match={m_skip and m_chunk},"
              f"compute_tok={comp_sh}/{comp_un},"
              f"p95_gap={p95_c * 1e6:.4f}/{p95_1 * 1e6:.4f}us,"
              f"tok_s={tok_c:.1f}/{tok_1:.1f},"
              f"{'OK' if p_ok else 'FAIL'}")

    disagg_rows = []
    if args.disagg:
        drows, (match, repacks, xdev, xdev_pred, tok_d, tok_c) = \
            run_disagg(args.arch)
        disagg_rows += drows
        for r in drows:
            print(",".join(map(str, r)))
        d_ok = match and repacks == 0 and xdev == xdev_pred \
            and tok_d >= tok_c
        ok &= d_ok
        checks.append({"check": "disagg",
                       "tokens_match": match,
                       "repacks": repacks,
                       "xdev_migration_kb": round(xdev / 1e3, 3),
                       "xdev_predicted_kb": round(xdev_pred / 1e3, 3),
                       "disagg_tok_s": round(tok_d, 1),
                       "colocated_tok_s": round(tok_c, 1),
                       "status": "OK" if d_ok else "FAIL"})
        print(f"check,disagg,match={match},repacks={repacks},"
              f"xdev={xdev / 1e3:.3f}/{xdev_pred / 1e3:.3f}kB,"
              f"tok_s={tok_d:.1f}/{tok_c:.1f},"
              f"{'OK' if d_ok else 'FAIL'}")

        srows, (tok_n, tok_1, ledger_exact, rec) = \
            run_disagg_sharded(args.arch)
        disagg_rows += srows
        for r in srows:
            print(",".join(map(str, r)))
        s_ok = tok_n >= tok_1 and ledger_exact
        ok &= s_ok
        checks.append({"check": "disagg_sharded",
                       "modeled_sharded_tok_s": round(tok_n, 1),
                       "modeled_single_tok_s": round(tok_1, 1),
                       "ledger_exact": ledger_exact,
                       "wall_sharded_tok_s":
                           round(rec["tok_s_sharded"], 2),
                       "wall_single_tok_s":
                           round(rec["tok_s_single"], 2),
                       "overlap": {
                           "step_ms": round(rec["step_ms"], 3),
                           "stream_ms_measured":
                               round(rec["stream_ms"], 3),
                           "step_with_stream_ms":
                               round(rec["step_with_stream_ms"], 3),
                           "overlap_frac":
                               round(rec["overlap_frac"], 3)},
                       "status": "OK" if s_ok else "FAIL"})
        print(f"check,disagg_sharded,"
              f"modeled_tok_s={tok_n:.1f}/{tok_1:.1f},"
              f"ledger_exact={ledger_exact},"
              f"overlap_frac={rec['overlap_frac']:.3f},"
              f"{'OK' if s_ok else 'FAIL'}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [list(r) for r in
                                rows + latency_rows + paged_rows
                                + shared_rows + tenant_rows + prefill_rows
                                + disagg_rows],
                       "checks": checks}, f, indent=2)
        print(f"wrote {args.json}")

    if not ok:
        raise SystemExit("serve benchmark gate failed (see checks above)")


if __name__ == "__main__":
    main()
