"""Sentinel-Serve: simulated decode throughput, fast-memory fraction x batch
slots x placement policy.

The serving analogue of the paper's Fig. 10 sweep: per-slot, per-layer KV
blocks are the data objects; ``sentinel`` (lifetime-aware, object-granular,
look-ahead prefetch via the decode-phase planner) against the page-grain
reactive LRU daemon and static PreferHBM placement.

    PYTHONPATH=src python -m benchmarks.bench_serve

Exits non-zero if the Sentinel object policy loses to the best page-grain
baseline at the paper's headline 20% fast-memory fraction — the CI smoke gate.
"""
from __future__ import annotations

from repro.configs.base import get_config
from repro.core import hmsim, planner
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.core.policies import list_policies
from repro.serve.engine import serve_trace_for

ARCH = "smollm-360m"
FRACS = (0.1, 0.2, 0.4, 0.8)
SLOTS = (4, 8)


def build_trace(cfg, slots: int) -> hmsim.ServeTrace:
    # full-size byte geometry (real KV/weight volumes decide placement
    # quality), coarsened to one object per 8-layer KV block so the pure-
    # Python sweep stays a smoke test
    reqs = hmsim.synthetic_requests(3 * slots)
    return serve_trace_for(cfg, reqs, slots=slots, layer_group=8)


def run(arch: str = ARCH):
    cfg = get_config(arch)
    rows = [("bench_serve", "hw", "slots", "fast_frac", "policy",
             "tok_per_s", "slowdown", "migrations", "slow_gb")]
    verdicts = []
    for hw, hw_name in ((TPU_V5E, "tpu-v5e"), (PAPER_HM, "paper-hm")):
        for slots in SLOTS:
            trace = build_trace(cfg, slots)
            peak = trace.peak_kv_bytes()
            # plan once at the headline fraction; the chosen look-ahead is a
            # property of the access schedule, not of the budget
            pl = planner.plan_serve(trace, hw, 0.2 * peak)
            for frac in FRACS:
                fast = frac * peak
                best = {}
                for pol in list_policies():
                    knobs = ({"lookahead": pl.lookahead}
                             if pol == "sentinel" else {})
                    r = hmsim.simulate_serve(trace, hw, fast, pol, **knobs)
                    best[pol] = r
                    rows.append(("bench_serve", hw_name, slots, frac, pol,
                                 round(r.decode_throughput, 1),
                                 round(r.slowdown, 4), r.migrations,
                                 round(r.slow_bytes_accessed / 1e9, 3)))
                if abs(frac - 0.2) < 1e-9:
                    page = best["lru_page"].decode_throughput
                    verdicts.append((hw_name, slots,
                                     best["sentinel"].decode_throughput, page))
    return rows, verdicts


def main():
    rows, verdicts = run()
    for r in rows:
        print(",".join(map(str, r)))
    ok = True
    for hw_name, slots, sent, page in verdicts:
        rel = sent / max(page, 1e-30)
        status = "OK" if rel >= 1.0 else "FAIL"
        ok &= rel >= 1.0
        print(f"check,{hw_name},slots={slots},sentinel/page@20%={rel:.3f},"
              f"{status}")
    if not ok:
        raise SystemExit("sentinel lost to a page-grain baseline at 20% "
                         "fast-memory fraction")


if __name__ == "__main__":
    main()
