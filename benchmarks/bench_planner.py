"""Paper Figures 7 + 8 and Table 3: throughput vs migration interval (sweet
spot), Case 1/2/3 occurrences vs MI, and the steps used for profiling +
MI-determination + test-and-trial."""
from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, bench_profile
from repro import runtime
from repro.core.hardware import PAPER_HM, TPU_V5E


def run_table3(fast_frac: float = 0.3):
    """Paper Table 3: '# of training steps for p, m & t' per model."""
    rows = [("bench_table3", "arch", "steps_profile", "steps_pmt_total",
             "tt_used")]
    for arch in BENCH_ARCHS:
        cfg, prof = bench_profile(arch)
        plan = runtime.plan(prof, PAPER_HM, fast_frac * prof.peak_bytes())
        rows.append(("bench_table3", arch, 1, plan.steps_used,
                     plan.sim.detail.get("tt_choice", "n/a")))
    return rows


def run(arch: str = "smollm-360m", fast_frac: float = 0.3):
    rows = [("bench_planner", "hw", "mi", "rel_throughput",
             "case1", "case2", "case3", "migrations", "is_planned_mi")]
    cfg, prof = bench_profile(arch)
    peak = prof.peak_bytes()
    for hw, name in ((PAPER_HM, "paper-hm"), (TPU_V5E, "tpu-v5e")):
        fast = fast_frac * peak
        base = runtime.simulate(prof, hw, fast, "all_fast").step_time
        plan = runtime.plan(prof, hw, fast)
        for mi in sorted({1, 2, 3, 4, 6, 8, 12, 16, plan.mi}):
            r = runtime.simulate(prof, hw, fast, "sentinel_mi", mi=mi)
            rows.append(("bench_planner", name, mi,
                         round(base / r.step_time, 4),
                         r.cases[1], r.cases[2], r.cases[3], r.migrations,
                         int(mi == plan.mi)))
    return rows


if __name__ == "__main__":
    for r in run() + run_table3():
        print(",".join(map(str, r)))
