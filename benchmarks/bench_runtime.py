"""Unified-runtime smoke: one entry point, both workloads.

Runs a training-sim sweep and a serving sweep through the *same* surface —
``runtime.plan`` + the unified policy registry — on deterministic synthetic
workloads (no model tracing, no RNG), and publishes ``BENCH_runtime.json``
beside ``BENCH_serve.json`` for trend tracking across PRs.

    PYTHONPATH=src python -m benchmarks.bench_runtime --json BENCH_runtime.json

Gates (exit non-zero on failure):
  - on BOTH workloads at the paper's headline 20% fast-memory fraction, the
    lifetime-aware object policy must not lose to the page-grain reactive
    baseline (``sentinel_mi`` vs ``ial`` on training, ``sentinel`` vs
    ``lru_page`` on serving);
  - every plan — including a latency-objective plan carrying its serialized
    ``CostModel`` and predicted step times — must round-trip through
    ``PlacementPlan.to_json`` / ``from_json`` byte-identically
    (planner-drift canary);
  - with ``--drift``, the online re-planner (runtime/online.py) on every
    piecewise-stationary drift workload: predicted tokens/sec ≥ the
    static-stale plan's, regret vs the per-segment clairvoyant plan
    sequence ≤ 10%, migration bytes ≤ 1.3x clairvoyant, and hysteresis
    churn within budget.

Every row also carries the time-domain prediction (``pred_time_s``): the
policy's recorded per-step traffic priced on the machine's ``CostModel``.
"""
from __future__ import annotations

import argparse
import json

from repro import runtime
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.runtime.synthetic import synthetic_profile, synthetic_serve_trace

FRACS = (0.1, 0.2, 0.4, 0.8)


def sweep(workload, hw, hw_name: str, kind: str, peak: float, policies,
          fracs=FRACS):
    """One (workload, hw) sweep: plan once, then simulate every policy at
    every fast-memory fraction."""
    pl = runtime.plan(workload, hw, 0.2 * peak)
    cm = runtime.as_cost_model(hw)
    rows, results = [], {}
    for frac in fracs:
        fast = frac * peak
        for pol in policies:
            knobs = {}
            if pol == "sentinel" and kind == "serving":
                knobs["lookahead"] = pl.lookahead
            if pol == "sentinel_mi" and kind == "training":
                knobs["mi"] = pl.mi
            r = runtime.simulate(workload, hw, fast, pol, **knobs)
            results[(frac, pol)] = r
            rows.append(("bench_runtime", kind, hw_name, frac, pol,
                         round(r.slowdown, 4),
                         round(r.decode_throughput, 1), r.migrations,
                         round(r.slow_bytes_accessed / 1e9, 4),
                         round(cm.price_result(r).time, 6)))
    return pl, rows, results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", default="",
                    help="write rows + checks to this JSON file")
    ap.add_argument("--drift", action="store_true",
                    help="also sweep the online re-planner over the "
                         "piecewise-stationary drift workloads and gate "
                         "online vs static-stale vs clairvoyant")
    args = ap.parse_args(argv)

    prof = synthetic_profile()
    trace = synthetic_serve_trace()
    header = ("bench_runtime", "workload", "hw", "fast_frac", "policy",
              "slowdown", "tok_per_s", "migrations", "slow_gb",
              "pred_time_s")
    rows, checks = [header], []
    ok = True

    def gate(name: str, winner, loser, lo, hi):
        nonlocal ok
        ratio = lo / max(hi, 1e-30)
        status = "OK" if ratio <= 1.0 else "FAIL"
        ok &= ratio <= 1.0
        checks.append({"check": name, "winner": winner, "loser": loser,
                       "slowdown_ratio": round(ratio, 4), "status": status})
        print(f"check,{name},{winner}<= {loser},ratio={ratio:.4f},{status}")

    # ---- training workload: the MI planner through the unified surface ----
    pl_t, rows_t, res_t = sweep(
        prof, PAPER_HM, "paper-hm", "training",
        prof.peak_bytes(), ("all_slow", "ial", "lru", "sentinel",
                            "sentinel_mi"))
    rows += rows_t
    gate("training_sentinel_vs_page@20%", "sentinel_mi", "ial",
         res_t[(0.2, "sentinel_mi")].time, res_t[(0.2, "ial")].time)

    # ---- serving workload: the decode planner through the same surface ----
    pl_s, rows_s, res_s = sweep(
        trace, TPU_V5E, "tpu-v5e", "serving",
        trace.peak_kv_bytes(), ("all_slow", "lru_page", "prefer_fast",
                                "sentinel"))
    rows += rows_s
    gate("serving_sentinel_vs_page@20%", "sentinel", "lru_page",
         res_s[(0.2, "sentinel")].time, res_s[(0.2, "lru_page")].time)

    # ---- latency objective: plan by predicted time on the default model ----
    from repro.core.hardware import default_cost_model
    pl_lat = runtime.plan(trace, default_cost_model(),
                          0.2 * trace.peak_kv_bytes(), objective="latency")
    print(f"check,latency_plan,policy={pl_lat.policy},"
          f"pred_time={pl_lat.predicted_time:.6f}s,"
          f"pred_tok_per_s={pl_lat.predicted_decode_throughput:.1f}")

    # ---- plan serialization canary: byte-identical JSON round trip ----
    for kind, pl in (("training", pl_t), ("serving", pl_s),
                     ("serving_latency", pl_lat)):
        s = pl.to_json()
        stable = runtime.PlacementPlan.from_json(s).to_json() == s
        ok &= stable
        checks.append({"check": f"{kind}_plan_json_roundtrip",
                       "bytes": len(s),
                       "status": "OK" if stable else "FAIL"})
        print(f"check,{kind}_plan_json_roundtrip,bytes={len(s)},"
              f"{'OK' if stable else 'FAIL'}")

    # ---- online re-planning under drift: regret vs the clairvoyant plan ----
    drift = {}
    if args.drift:
        from repro.runtime import replay_drift
        from repro.runtime.synthetic import drift_workloads
        for name, wl in drift_workloads().items():
            rep = replay_drift(wl, default_cost_model(),
                               0.2 * wl.peak_kv_bytes())
            drift[name] = rep.to_dict()
            replans = sum(1 for e in rep.events if e.applied)
            print(f"drift,{name},regret={rep.regret:.4f},"
                  f"online_tok_s={rep.online_tokens_per_s:.1f},"
                  f"static_tok_s={rep.static_tokens_per_s:.1f},"
                  f"replans={replans},churn_mb={rep.churn_bytes / 1e6:.2f}")
            gate(f"drift_{name}_online_vs_static", "online", "static_stale",
                 rep.online_s, rep.static_s)
            gate(f"drift_{name}_regret<=10%", "online", "clairvoyant*1.1",
                 rep.online_s, (1.0 + 0.10) * rep.clairvoyant_s)
            gate(f"drift_{name}_migration<=1.3x_clairvoyant", "online",
                 "clairvoyant*1.3", rep.online_mig_bytes,
                 1.3 * rep.clairvoyant_mig_bytes)
            gate(f"drift_{name}_churn_within_budget", "churn", "budget",
                 rep.churn_bytes, rep.churn_budget_bytes)

    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        out = {"rows": [list(r) for r in rows],
               "plans": {"training": pl_t.to_dict(),
                         "serving": pl_s.to_dict(),
                         "serving_latency": pl_lat.to_dict()},
               "checks": checks}
        if drift:
            out["drift"] = drift
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")
    if not ok:
        raise SystemExit("runtime benchmark gate failed (see checks above)")


if __name__ == "__main__":
    main()
