"""Unified-runtime smoke: one entry point, both workloads.

Runs a training-sim sweep and a serving sweep through the *same* surface —
``runtime.plan`` + the unified policy registry — on deterministic synthetic
workloads (no model tracing, no RNG), and publishes ``BENCH_runtime.json``
beside ``BENCH_serve.json`` for trend tracking across PRs.

    PYTHONPATH=src python -m benchmarks.bench_runtime --json BENCH_runtime.json

Gates (exit non-zero on failure):
  - on BOTH workloads at the paper's headline 20% fast-memory fraction, the
    lifetime-aware object policy must not lose to the page-grain reactive
    baseline (``sentinel_mi`` vs ``ial`` on training, ``sentinel`` vs
    ``lru_page`` on serving);
  - every plan — including a latency-objective plan carrying its serialized
    ``CostModel`` and predicted step times — must round-trip through
    ``PlacementPlan.to_json`` / ``from_json`` byte-identically
    (planner-drift canary).

Every row also carries the time-domain prediction (``pred_time_s``): the
policy's recorded per-step traffic priced on the machine's ``CostModel``.
"""
from __future__ import annotations

import argparse
import json

from repro import runtime
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.runtime.synthetic import synthetic_profile, synthetic_serve_trace

FRACS = (0.1, 0.2, 0.4, 0.8)


def sweep(workload, hw, hw_name: str, kind: str, peak: float, policies,
          fracs=FRACS):
    """One (workload, hw) sweep: plan once, then simulate every policy at
    every fast-memory fraction."""
    pl = runtime.plan(workload, hw, 0.2 * peak)
    cm = runtime.as_cost_model(hw)
    rows, results = [], {}
    for frac in fracs:
        fast = frac * peak
        for pol in policies:
            knobs = {}
            if pol == "sentinel" and kind == "serving":
                knobs["lookahead"] = pl.lookahead
            if pol == "sentinel_mi" and kind == "training":
                knobs["mi"] = pl.mi
            r = runtime.simulate(workload, hw, fast, pol, **knobs)
            results[(frac, pol)] = r
            rows.append(("bench_runtime", kind, hw_name, frac, pol,
                         round(r.slowdown, 4),
                         round(r.decode_throughput, 1), r.migrations,
                         round(r.slow_bytes_accessed / 1e9, 4),
                         round(cm.price_result(r).time, 6)))
    return pl, rows, results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", default="",
                    help="write rows + checks to this JSON file")
    args = ap.parse_args(argv)

    prof = synthetic_profile()
    trace = synthetic_serve_trace()
    header = ("bench_runtime", "workload", "hw", "fast_frac", "policy",
              "slowdown", "tok_per_s", "migrations", "slow_gb",
              "pred_time_s")
    rows, checks = [header], []
    ok = True

    def gate(name: str, winner, loser, lo, hi):
        nonlocal ok
        ratio = lo / max(hi, 1e-30)
        status = "OK" if ratio <= 1.0 else "FAIL"
        ok &= ratio <= 1.0
        checks.append({"check": name, "winner": winner, "loser": loser,
                       "slowdown_ratio": round(ratio, 4), "status": status})
        print(f"check,{name},{winner}<= {loser},ratio={ratio:.4f},{status}")

    # ---- training workload: the MI planner through the unified surface ----
    pl_t, rows_t, res_t = sweep(
        prof, PAPER_HM, "paper-hm", "training",
        prof.peak_bytes(), ("all_slow", "ial", "lru", "sentinel",
                            "sentinel_mi"))
    rows += rows_t
    gate("training_sentinel_vs_page@20%", "sentinel_mi", "ial",
         res_t[(0.2, "sentinel_mi")].time, res_t[(0.2, "ial")].time)

    # ---- serving workload: the decode planner through the same surface ----
    pl_s, rows_s, res_s = sweep(
        trace, TPU_V5E, "tpu-v5e", "serving",
        trace.peak_kv_bytes(), ("all_slow", "lru_page", "prefer_fast",
                                "sentinel"))
    rows += rows_s
    gate("serving_sentinel_vs_page@20%", "sentinel", "lru_page",
         res_s[(0.2, "sentinel")].time, res_s[(0.2, "lru_page")].time)

    # ---- latency objective: plan by predicted time on the default model ----
    from repro.core.hardware import default_cost_model
    pl_lat = runtime.plan(trace, default_cost_model(),
                          0.2 * trace.peak_kv_bytes(), objective="latency")
    print(f"check,latency_plan,policy={pl_lat.policy},"
          f"pred_time={pl_lat.predicted_time:.6f}s,"
          f"pred_tok_per_s={pl_lat.predicted_decode_throughput:.1f}")

    # ---- plan serialization canary: byte-identical JSON round trip ----
    for kind, pl in (("training", pl_t), ("serving", pl_s),
                     ("serving_latency", pl_lat)):
        s = pl.to_json()
        stable = runtime.PlacementPlan.from_json(s).to_json() == s
        ok &= stable
        checks.append({"check": f"{kind}_plan_json_roundtrip",
                       "bytes": len(s),
                       "status": "OK" if stable else "FAIL"})
        print(f"check,{kind}_plan_json_roundtrip,bytes={len(s)},"
              f"{'OK' if stable else 'FAIL'}")

    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [list(r) for r in rows],
                       "plans": {"training": pl_t.to_dict(),
                                 "serving": pl_s.to_dict(),
                                 "serving_latency": pl_lat.to_dict()},
                       "checks": checks}, f, indent=2)
        print(f"wrote {args.json}")
    if not ok:
        raise SystemExit("runtime benchmark gate failed (see checks above)")


if __name__ == "__main__":
    main()
