"""Regenerate the data-derived sections of EXPERIMENTS.md from results/.

    PYTHONPATH=src:. python benchmarks/update_experiments.py
"""
import glob
import json
import re
from collections import Counter

from benchmarks import roofline


def perf_terms(path):
    r = json.load(open(path))[0]
    if not r.get("ok"):
        return None
    ca = r["cost_analytic"]
    c = r["collectives"]["bytes"]
    wire = sum(c[k] * {"all-gather": 15 / 16, "all-reduce": 2 * 15 / 16,
                       "reduce-scatter": 15, "all-to-all": 15 / 16,
                       "collective-permute": 1.0}[k] for k in c)
    return (ca["flops_per_chip"] / 197e12, ca["bytes_per_chip"] / 819e9,
            wire / 50e9)


def main():
    rows_sp = roofline.load("results/dryrun")
    rows_mp = roofline.load("results/dryrun_mp")

    table = roofline.table(rows_sp)
    doms = Counter(r["dominant"] for r in rows_sp)
    fr = sorted(rows_sp, key=lambda r: r["roofline_frac"])
    best = fr[-1]
    worst_train = [(r["arch"], round(r["roofline_frac"], 4))
                   for r in fr if r["shape"] == "train_4k"][:3]
    summary = (f"Dominant terms over {len(rows_sp)} single-pod cells: "
               f"{dict(doms)}. Best baseline roofline fraction: "
               f"{best['arch']}×{best['shape']} = {best['roofline_frac']:.3f}; "
               f"worst train cells: {worst_train}.")

    mp_lines = ["| arch | shape | compute s | memory s | coll s | dominant |",
                "|---|---|---|---|---|---|"]
    for r in rows_mp:
        if r["shape"] == "train_4k":
            mp_lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
                f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
                f"| {r['dominant']} |")

    md = open("EXPERIMENTS.md").read()
    # replace the roofline table (between the §Roofline header paragraph and
    # the "Dominant terms" line) wholesale
    start = md.index("| arch | shape | mesh |")
    end = md.index("## §Perf — hillclimbing log")
    section = (table + "\n\n" + summary +
               "\n\nMulti-pod (2×16×16) train-cell terms (per-chip; the pod "
               "axis adds the cross-pod gradient reduction to ENTRY "
               "collectives):\n\n" + "\n".join(mp_lines) + "\n\n")
    md = md[:start] + section + md[end:]
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md §Roofline refreshed:",
          len(rows_sp), "sp cells,", len(rows_mp), "mp cells")

    print("\nperf-cell terms (corrected):")
    for f in sorted(glob.glob("results/perf/*.json*")):
        t = perf_terms(f)
        if t:
            print(f"  {f.split('/')[-1]:45s} comp={t[0]:7.3f} "
                  f"mem={t[1]:7.3f} coll={t[2]:8.3f}")


if __name__ == "__main__":
    main()
