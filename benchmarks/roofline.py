"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = wire_bytes_per_chip / link_bw

The machine constants come from the shared default CostModel
(``core.hardware.default_cost_model()``, i.e. ``TPU_V5E``'s numbers) — the
same instance ``runtime.plan`` prices placements with, so the roofline
table and the planner always describe the same machine.

cost_analysis() of the SPMD-compiled module reports per-chip FLOPs/bytes.
Collective wire bytes come from the post-SPMD HLO: per-op result bytes with
ring-algorithm factors — all-gather (S-1)/S x result, all-reduce
2(S-1)/S x result, reduce-scatter (S-1) x result (result is the 1/S shard),
all-to-all (S-1)/S x result, collective-permute 1 x result — S parsed from
replica_groups when available.

MODEL_FLOPS is the analytic 6·N·D (train) or 2·N·D (prefill/decode) with
N = active params; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundant
compute (ratio < 1 means the compiled step does extra work: recompute,
dispatch overhead, attention quadratic terms...).
"""
from __future__ import annotations

import glob
import json
import math
import os
import re
from typing import Optional

from repro.configs.base import SHAPES, get_config
from repro.core.hardware import default_cost_model

WIRE_FACTORS = {"all-gather": lambda s: (s - 1) / s,
                "all-reduce": lambda s: 2 * (s - 1) / s,
                "reduce-scatter": lambda s: (s - 1),
                "all-to-all": lambda s: (s - 1) / s,
                "collective-permute": lambda s: 1.0}


def active_params(cfg) -> float:
    """Analytic active-parameter count (MoE counts k/E of routed experts)."""
    n = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.num_codebooks:
        n *= cfg.num_codebooks
    per_layer = {}
    d = cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def attn_params():
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def mla_params():
        return (d * H * (cfg.qk_nope_dim + cfg.qk_rope_dim) +
                d * cfg.kv_lora_rank + d * cfg.qk_rope_dim +
                cfg.kv_lora_rank * H * (cfg.qk_nope_dim + cfg.v_head_dim) +
                H * cfg.v_head_dim * d)

    def mlp_params(ff):
        return 3 * d * ff

    def moe_params(active=True):
        m = cfg.moe
        frac = (m.experts_per_token / m.num_experts) if active else 1.0
        n = 3 * d * m.d_ff * m.num_experts * frac + d * m.num_experts
        if m.num_shared_experts:
            n += 3 * d * m.d_ff * m.num_shared_experts
        return n

    def mamba_params():
        s = cfg.ssm
        d_in = s.expand * d
        heads = s.num_heads or d_in // s.head_dim
        d_conv = d_in + 2 * s.state_dim
        return d * (d_in + d_conv + heads) + 4 * d_conv + 3 * heads + \
            d_in + d_in * d

    def mlstm_params():
        d_in = 2 * d
        return d * 2 * d_in + 4 * d_in + d_in * (d_in // 2) * 2 + \
            d_in * d_in + d_in * 2 * cfg.num_heads + d_in + d_in * d

    def slstm_params():
        Dh = d // cfg.num_heads
        return 4 * d + d * 4 * d + cfg.num_heads * 4 * Dh * Dh + d + d * d

    kinds = list(cfg.prologue) + list(cfg.period) * cfg.num_periods
    shared_counted = False
    total = n
    for k in kinds:
        if k in ("attn", "local"):
            total += attn_params() + (moe_params() if cfg.moe else
                                      mlp_params(cfg.d_ff))
        elif k == "mla":
            ff = cfg.prologue_d_ff if k in cfg.prologue and not shared_counted \
                else 0
            total += mla_params() + (moe_params() if cfg.moe else
                                     mlp_params(cfg.d_ff))
        elif k == "shared_attn":
            if not shared_counted:
                total += attn_params() + mlp_params(cfg.d_ff)
                shared_counted = True
        elif k == "mamba":
            total += mamba_params()
        elif k == "mlstm":
            total += mlstm_params()
        elif k == "slstm":
            total += slstm_params()
        elif k == "lstm":
            total += 2 * d * 4 * d
    return float(total)


def model_flops(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    N = active_params(cfg)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * N * D / chips
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * N * D / chips
    D = shape.global_batch * 1
    return 2.0 * N * D / chips


def terms(rec: dict, hw=None) -> Optional[dict]:
    if hw is None:
        hw = default_cost_model()
    if not rec.get("ok"):
        return None
    chips = rec["chips"]
    # trip-count-aware analytic cost preferred (see launch/costing.py); the
    # raw XLA numbers undercount scanned loop bodies
    ca = rec.get("cost_analytic")
    if ca:
        flops = ca["flops_per_chip"]
        byts = ca["bytes_per_chip"]
    else:
        flops = rec["cost"]["flops"]
        byts = rec["cost"]["bytes_accessed"]
    compute_t = flops / hw.peak_flops
    memory_t = byts / hw.fast_bw
    group = 16  # model-axis ring by default
    wire = 0.0
    for coll, b in rec["collectives"]["bytes"].items():
        wire += b * WIRE_FACTORS[coll](group)
    coll_t = wire / hw.link_bw
    dom = max((("compute", compute_t), ("memory", memory_t),
               ("collective", coll_t)), key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"], chips)
    total = max(compute_t, memory_t, coll_t)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec.get("mode"),
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "dominant": dom[0],
        "model_flops": mf, "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": (mf / hw.peak_flops) / total if total else 0.0,
        "hbm_per_chip_GB": (rec["memory"]["argument_bytes"] +
                            rec["memory"]["output_bytes"] +
                            rec["memory"]["temp_bytes"]) / 1e9,
    }


LEVERS = {
    "compute": "reduce recompute (larger MI / fewer remat blocks) or shrink "
               "non-matmul ops; check useful_ratio",
    "memory": "fuse elementwise chains, cast residuals/caches to bf16, or "
              "re-tile so operands stay in VMEM",
    "collective": "reshard to cut all-gathers (fold TP axes), overlap "
                  "collectives with compute, or shrink payload (bf16/int8)",
}


def load(results_dir: str):
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        try:
            recs = json.load(open(f))
        except Exception:
            continue
        for rec in recs:
            t = terms(rec)
            if t:
                out.append(t)
    return out


def table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | dominant "
           "| useful | roofline frac | HBM GB/chip | lever |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['hbm_per_chip_GB']:.1f} | {LEVERS[r['dominant']][:40]}... |")
    return "\n".join(lines)


def run(results_dir: str = "results/dryrun"):
    rows = load(results_dir)
    out = [("roofline", "arch", "shape", "mesh", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_frac")]
    for r in rows:
        out.append(("roofline", r["arch"], r["shape"], r["mesh"],
                    f"{r['compute_s']:.4e}", f"{r['memory_s']:.4e}",
                    f"{r['collective_s']:.4e}", r["dominant"],
                    round(r["useful_ratio"], 3), round(r["roofline_frac"], 4)))
    return out


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(d)
    print(table(rows))
