"""Benchmark harness: one function per paper table/figure.
Prints ``name,...`` CSV rows. Usage: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (bench_ablation, bench_compare, bench_planner,
                            bench_profile, bench_sensitivity, roofline)

    sections = [
        ("Fig1-4/Tab1/Tab5: profiler distributions", bench_profile.run),
        ("Fig7/Fig8: migration-interval sweep", bench_planner.run),
        ("Table3: steps for profile+MI+test-and-trial", bench_planner.run_table3),
        ("Fig10/Tab4: Sentinel vs IAL vs fast-only", bench_compare.run),
        ("Fig11: ablations", bench_ablation.run),
        ("Fig12: fast-size sensitivity", bench_sensitivity.run),
        ("Fig13: depth sweep", bench_sensitivity.run_depth_sweep),
        ("Roofline (from dry-run artifacts)", roofline.run),
    ]
    failures = 0
    for title, fn in sections:
        print(f"# --- {title} ---", flush=True)
        try:
            for row in fn():
                print(",".join(map(str, row)), flush=True)
        except Exception as e:
            failures += 1
            print(f"# ERROR in {title}: {type(e).__name__}: {e}", flush=True)
    print(f"# benchmarks done in {time.time() - t0:.1f}s, "
          f"{failures} section failures")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
