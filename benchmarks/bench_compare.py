"""Paper Figure 10 + Table 4: Sentinel vs IAL vs fast-only across models at
20% of peak footprint as fast memory; migration counts per step."""
from __future__ import annotations

from benchmarks.common import BENCH_ARCHS, bench_profile
from repro.core import hmsim, planner
from repro.core.hardware import PAPER_HM


def run(fast_frac: float = 0.25):
    rows = [("bench_compare", "arch", "sentinel_slowdown", "ial_slowdown",
             "lru_slowdown", "slow_only_slowdown", "sentinel_vs_ial_speedup",
             "sentinel_migs", "ial_migs", "planned_mi")]
    hw = PAPER_HM
    for arch in BENCH_ARCHS:
        cfg, prof = bench_profile(arch)
        peak = prof.peak_bytes()
        fast = fast_frac * peak
        base = hmsim.simulate_static(prof, hw, "fast").step_time
        slow = hmsim.simulate_static(prof, hw, "slow").step_time
        plan = planner.plan(prof, hw, fast)
        ial = hmsim.simulate_caching(prof, hw, fast, "ial")
        lru = hmsim.simulate_caching(prof, hw, fast, "lru")
        rows.append(("bench_compare", arch,
                     round(plan.sim.step_time / base, 3),
                     round(ial.step_time / base, 3),
                     round(lru.step_time / base, 3),
                     round(slow / base, 3),
                     round(ial.step_time / plan.sim.step_time, 3),
                     plan.sim.migrations, ial.migrations, plan.mi))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
