"""Production mesh + sharding-rule construction.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax

from repro import sharding as shd


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_rules(mesh, *, kind: str = "train", fsdp: bool = False,
               seq_shard: bool = False, seq_parallel: bool = False,
               dp_only: bool = False):
    """kind: train | prefill | decode. long-context decode sets seq_shard;
    seq_parallel = Megatron-SP residual sharding; dp_only folds the model
    axis into data parallelism (small models)."""
    if kind == "train":
        return shd.tp_dp_rules(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                               dp_only=dp_only)
    return shd.serve_rules(mesh, seq_shard=seq_shard)


def disagg_groups(devices=None):
    """Split the available devices into (prefill, decode) groups for
    prefill/decode disaggregation (``serve/disagg.py``).

    Decode takes the *leading* half — it owns the resident KV pools and
    the default device, where every array the engine materializes without
    an explicit placement lands — and gets the larger share on odd counts.
    Prefill takes the trailing half.  With a single device both groups
    alias it, so the disaggregated engine runs degenerately on any machine
    (the CPU test environment sees exactly one device).  Pass a
    ``jax.sharding.Mesh`` to group its devices instead."""
    if hasattr(devices, "devices"):                  # a Mesh
        devices = list(devices.devices.flatten())
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) == 1:
        return devices, devices
    half = (len(devices) + 1) // 2
    return devices[half:], devices[:half]
