"""Production mesh + sharding-rule construction.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.
"""
from __future__ import annotations

import jax

from repro import sharding as shd


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_rules(mesh, *, kind: str = "train", fsdp: bool = False,
               seq_shard: bool = False, seq_parallel: bool = False,
               dp_only: bool = False):
    """kind: train | prefill | decode. long-context decode sets seq_shard;
    seq_parallel = Megatron-SP residual sharding; dp_only folds the model
    axis into data parallelism (small models)."""
    if kind == "train":
        return shd.tp_dp_rules(mesh, fsdp=fsdp, seq_parallel=seq_parallel,
                               dp_only=dp_only)
    return shd.serve_rules(mesh, seq_shard=seq_shard)
