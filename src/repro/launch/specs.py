"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell — weak-type
correct, shardable, zero allocation. Builds the (step_fn, example_args,
in_shardings) triple per (arch × shape × mesh)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, get_config
from repro.core.offload import SentinelConfig, loss_kwargs
from repro.models import kvcache, model
from repro.models.layers import split_params
from repro.optim import adamw


def _sds(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def param_structs(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, logical-axes tree) without materializing."""
    ptree = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    return split_params(ptree)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, decode: bool = False) -> Dict[str, Any]:
    B = shape.global_batch
    S = 1 if decode else shape.seq_len
    tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    out = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if not decode:
        if cfg.num_codebooks:
            lab_shape = tok_shape
        elif cfg.num_prefix_tokens:
            lab_shape = (B, S + cfg.num_prefix_tokens)
        else:
            lab_shape = (B, S)
        out["labels"] = jax.ShapeDtypeStruct(lab_shape, jnp.int32)
        if cfg.num_prefix_tokens:
            out["prefix_embed"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    return out


def batch_shardings(cfg, shape, rules, *, decode=False):
    def spec(path_shape, logical):
        return rules.sharding(logical)
    out = {"tokens": rules.sharding(("batch", None, None)
                                    if cfg.num_codebooks else ("batch", None))}
    if not decode:
        out["labels"] = rules.sharding(("batch", None, None)
                                       if cfg.num_codebooks else ("batch", None))
        if cfg.num_prefix_tokens:
            out["prefix_embed"] = rules.sharding(("batch", None, None))
    return out


def shardings_from_axes(axes_tree, rules, sds_tree=None):
    """Shardings per leaf; with sds_tree given, non-divisible dims fall back
    to replication (kv=5 heads, 40 experts, odd vocab sizes...)."""
    if sds_tree is None:
        return jax.tree.map(lambda ax: rules.sharding(ax), axes_tree,
                            is_leaf=shd.is_axes_leaf)
    flat_ax = jax.tree.leaves(axes_tree, is_leaf=shd.is_axes_leaf)
    flat_sds, treedef = jax.tree.flatten(sds_tree)
    assert len(flat_ax) == len(flat_sds), (len(flat_ax), len(flat_sds))
    out = [shd.sharding_for(s.shape, ax, rules)
           for ax, s in zip(flat_ax, flat_sds)]
    return jax.tree.unflatten(treedef, out)


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, rules,
                     scfg: SentinelConfig, opt_cfg=None):
    """Returns (step_fn, args_sds, in_shardings) for one training step."""
    opt_cfg = opt_cfg or adamw.OptConfig()
    params_sds, axes = param_structs(cfg)
    opt_sds = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}

    p_sh = shardings_from_axes(axes, rules, params_sds)
    opt_ax = {"m": axes, "v": axes, "count": ()}
    if opt_cfg.compress_grads:
        opt_ax["ef"] = axes
    o_sh = shardings_from_axes(opt_ax, rules, opt_sds)
    if scfg.offload_opt_state:
        o_sh = jax.tree.map(
            lambda s: s.with_memory_kind("pinned_host"), o_sh,
            is_leaf=lambda x: hasattr(x, "memory_kind"))
    state_sh = {"params": p_sh, "opt": o_sh,
                "step": rules.sharding(())}
    b_sds = batch_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, rules)
    kw = loss_kwargs(scfg)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, **kw))(state["params"])
        with jax.named_scope("boundary_opt"):
            new_params, new_opt, om = adamw.update(
                grads, state["opt"], state["params"], opt_cfg)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1},
                {"loss": loss, **om})

    return train_step, (state_sds, b_sds), (state_sh, b_sh)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, rules):
    """Prefill: full prompt forward + cache write (inference-prefill shapes)."""
    params_sds, axes = param_structs(cfg)
    p_sh = shardings_from_axes(axes, rules, params_sds)
    b_sds = {"tokens": batch_specs(cfg, shape)["tokens"]}
    if cfg.num_prefix_tokens:
        b_sds["prefix_embed"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
    b_sh = {k: v for k, v in batch_shardings(cfg, shape, rules).items()
            if k in b_sds}

    def prefill_step(params, batch):
        logits, caches = model.prefill(params, cfg, batch)
        return logits, caches

    return prefill_step, (params_sds, b_sds), (p_sh, b_sh)


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, rules):
    """serve_step: one new token against a seq_len KV cache."""
    params_sds, axes = param_structs(cfg)
    p_sh = shardings_from_axes(axes, rules, params_sds)
    B, S = shape.global_batch, shape.seq_len

    cache_sds = jax.eval_shape(
        lambda: kvcache.init_cache(cfg, B, S,
                                   jnp.bfloat16 if cfg.dtype == "bfloat16"
                                   else jnp.float32))
    cache_ax = kvcache.cache_logical_axes(cfg)
    c_sh = shardings_from_axes(cache_ax, rules, cache_sds)
    tok = jax.ShapeDtypeStruct(
        (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1), jnp.int32)
    t_sh = rules.sharding(("batch", None, None) if cfg.num_codebooks
                          else ("batch", None))
    idx = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, tokens, caches, index):
        return model.decode_step(params, cfg, tokens, caches, index)

    return (serve_step, (params_sds, tok, cache_sds, idx),
            (p_sh, t_sh, c_sh, rules.sharding(())))


def build_cell(arch: str, shape_name: str, rules, scfg=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    scfg = scfg or SentinelConfig(mode="offload",
                                  mi_periods=default_mi(cfg))
    if shape.kind == "train":
        return build_train_cell(cfg, shape, rules, scfg)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, rules)
    return build_decode_cell(cfg, shape, rules)


def default_mi(cfg: ModelConfig) -> int:
    """Paper-faithful default: planner-shaped heuristic (≈1/8 of depth,
    rounded to a divisor of num_periods). The real planner value comes from
    benchmarks/bench_planner.py; this keeps the dry-run self-contained."""
    P = cfg.num_periods
    target = max(1, P // 8)
    divs = [d for d in range(1, P + 1) if P % d == 0]
    return min(divs, key=lambda d: abs(d - target))
