"""Serving CLI: ``python -m repro.launch.serve --arch smollm-360m -n 16``
Batched prefill + decode with the serve engine (reduced config on CPU)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro.models import model
from repro.models.layers import split_params
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("-n", "--num-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(1)
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (args.batch, args.prompt_len,
                                        cfg.num_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                  cfg.vocab_size)
    prompts = {"tokens": toks.astype(jnp.int32)}
    if cfg.num_prefix_tokens:
        prompts["prefix_embed"] = jax.random.normal(
            key, (args.batch, cfg.num_prefix_tokens, cfg.d_model))

    scfg = engine.ServeConfig(temperature=args.temperature,
                              max_seq=args.prompt_len + args.num_tokens + 8)
    t0 = time.perf_counter()
    out = engine.generate(params, cfg, prompts, args.num_tokens, scfg)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    total = args.batch * args.num_tokens
    print(f"[serve] {args.arch}: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s batched)")
    print(out[0][:16])


if __name__ == "__main__":
    main()
