"""Trip-count-aware analytic costing of a step function.

XLA's HloCostAnalysis counts while-loop bodies once (verified empirically on
the CPU backend), so cost_analysis() of a scanned layer stack underreports by
the layer count. This walker counts the *jaxpr* instead — scans carry their
``length`` explicitly, so FLOPs are exact (including remat recompute, which
appears as real equations in the grad jaxpr), and bytes use the same
single-consumer-elementwise fusion model as the profiler (a close proxy for
HBM traffic of the fused program).

Counts are for the GLOBAL (unpartitioned) program; per-chip = /chips, the
roofline ideal for an evenly sharded step.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.profiler import _FUSIBLE


def _var_bytes(v) -> float:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0.0
    return float(aval.size) * aval.dtype.itemsize


def _dot_flops(eqn) -> float:
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = float(np.prod([lhs.shape[i] for i in lc])) if lc else 1.0
    return 2.0 * float(out.size) * k


def _sub_jaxprs_with_mult(eqn):
    """(sub_jaxpr, multiplier) pairs for call-like equations."""
    prim = eqn.primitive.name
    mult = 1.0
    if prim == "scan":
        mult = float(eqn.params.get("length", 1))
    elif prim == "while":
        mult = 1.0   # unknown trip count; our loops are scans
    out = []
    for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
        if key in eqn.params:
            v = eqn.params[key]
            j = v.jaxpr if hasattr(v, "jaxpr") else v
            if hasattr(j, "eqns"):
                out.append((j, mult))
    if "branches" in eqn.params:   # cond: worst-case branch
        for br in eqn.params["branches"]:
            j = br.jaxpr if hasattr(br, "jaxpr") else br
            out.append((j, 1.0 / max(1, len(eqn.params["branches"]))))
    return out


def jaxpr_cost(closed_jaxpr) -> Dict[str, float]:
    """{"flops", "bytes", "matmul_flops"} with scan lengths multiplied in."""
    total = {"flops": 0.0, "bytes": 0.0, "matmul_flops": 0.0}

    def walk(jaxpr, mult):
        for eqn in jaxpr.eqns:
            subs = _sub_jaxprs_with_mult(eqn)
            if subs:
                for j, m in subs:
                    walk(j, mult * m)
                continue
            prim = eqn.primitive.name
            out_elems = sum(float(v.aval.size) for v in eqn.outvars
                            if hasattr(v, "aval") and hasattr(v.aval, "shape"))
            if prim == "dot_general":
                f = _dot_flops(eqn)
                total["matmul_flops"] += mult * f
            elif prim == "conv_general_dilated":
                f = 2.0 * out_elems  # rough; convs only in stubs
            else:
                f = out_elems
            total["flops"] += mult * f
            if prim not in _FUSIBLE:
                b = sum(_var_bytes(v) for v in
                        list(eqn.invars) + list(eqn.outvars))
                total["bytes"] += mult * b

    walk(closed_jaxpr.jaxpr, 1.0)
    return total
