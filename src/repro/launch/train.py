"""Training CLI: ``python -m repro.launch.train --arch smollm-360m --steps 50``

Runs the full Sentinel pipeline on the local device(s): dynamic profiling
(one traced step), migration-interval planning, then the fault-tolerant
training loop with the planned offload config.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro import runtime
from repro.core import profiler
from repro.core.hardware import TPU_V5E
from repro.core.offload import SentinelConfig, from_plan
from repro.data.pipeline import DataConfig
from repro.models import model
from repro.models.layers import split_params
from repro.optim import adamw
from repro.train import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-scale config (full scale needs TPU)")
    ap.add_argument("--mi", type=int, default=0,
                    help="migration interval override (0 = plan it)")
    ap.add_argument("--mode", default="offload",
                    choices=["offload", "save_hbm", "remat", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--fast-frac", type=float, default=0.3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    # ---- Sentinel pipeline: profile -> plan -> configure ----
    if args.mi:
        scfg = SentinelConfig(mode=args.mode, mi_periods=args.mi)
        print(f"[train] MI override: {args.mi} periods")
    else:
        params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
        pshapes = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        if cfg.num_codebooks:
            tok = jax.ShapeDtypeStruct((args.batch, args.seq,
                                        cfg.num_codebooks), jnp.int32)
        else:
            tok = jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)
        lab_S = args.seq + (cfg.num_prefix_tokens or 0)
        b = {"tokens": tok,
             "labels": jax.ShapeDtypeStruct(
                 (args.batch, args.seq, cfg.num_codebooks)
                 if cfg.num_codebooks else (args.batch, lab_S), jnp.int32)}
        if cfg.num_prefix_tokens:
            b["prefix_embed"] = jax.ShapeDtypeStruct(
                (args.batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
        prof = profiler.trace_profile(
            jax.grad(lambda p, bb: model.loss_fn(p, cfg, bb,
                                                 unroll_periods=True)),
            pshapes, b, num_periods=cfg.num_periods)
        plan = runtime.plan(prof, TPU_V5E, args.fast_frac * prof.peak_bytes())
        scfg = dataclasses.replace(from_plan(prof, plan), mode=args.mode)
        print(f"[train] profiled {len(prof.objects)} data objects; "
              f"planned MI={plan.mi} steps -> {scfg.mi_periods} periods "
              f"(case3 policy: {'stall' if plan.stall_on_case3 else 'slow'})")

    ocfg = adamw.OptConfig(total_steps=args.steps, warmup_steps=max(1, args.steps // 10))
    dcfg = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch,
                      num_codebooks=cfg.num_codebooks,
                      num_prefix_tokens=cfg.num_prefix_tokens,
                      d_model=cfg.d_model)
    tcfg = loop.TrainConfig(steps=args.steps, ckpt_every=max(10, args.steps // 5),
                            ckpt_dir=args.ckpt_dir, log_every=10)
    out = loop.run(cfg, tcfg, scfg, ocfg, dcfg)
    print(f"[train] done; final loss {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
