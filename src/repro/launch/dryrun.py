"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

MUST be the very first lines — jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import sharding as shd                      # noqa: E402
from repro.configs.base import SHAPES, cells, get_config   # noqa: E402
from repro.core.hardware import TPU_V5E                # noqa: E402
from repro.core.offload import SentinelConfig          # noqa: E402
from repro.launch import specs                         # noqa: E402
from repro.launch.mesh import make_production_mesh, make_rules  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Sum byte sizes of every typed array in an HLO result type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_trips: float = 1.0) -> dict:
    """Per-collective-type byte totals from post-SPMD optimized HLO.

    Bytes counted are the (per-device) result shapes — the payload each device
    receives; ring wire factors are applied in roofline.py. XLA's text lists
    while-loop bodies once, so collectives found inside non-ENTRY computations
    (scan bodies — the per-layer TP collectives) are multiplied by
    ``loop_trips`` (the layer-period trip count); ENTRY-level collectives
    (gradient all-reduces, boundary reshards) count once.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
        elif line.startswith("%") or (line and not line[0].isspace()
                                      and not line.startswith("ENTRY")):
            in_entry = False
        for coll in _COLLECTIVES:
            if f" {coll}(" in line or f" {coll}-start(" in line:
                lhs = line.split("=", 1)
                if len(lhs) != 2:
                    continue
                shape_part = lhs[1].strip().split(coll)[0]
                mult = 1.0 if in_entry else loop_trips
                # XLA's *CPU* backend promotes bf16 all-reduces to f32
                # (reducer "...promoted"); on TPU they run in bf16 — halve.
                if "promoted" in line:
                    mult *= 0.5
                out[coll] += _shape_bytes(shape_part) * mult
                counts[coll] += 1
                break
    return {"bytes": out, "counts": counts}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mode: str = "offload", mi: int = 0, fsdp: bool = False,
             compress_grads: bool = False, seq_parallel: bool = False,
             dp_only: bool = False, moe_group: int = 0) -> dict:
    cfg = get_config(arch)
    if moe_group and cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, group_size=moe_group))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    rules = make_rules(mesh, kind=kind,
                       seq_shard=(shape_name == "long_500k"), fsdp=fsdp,
                       seq_parallel=seq_parallel, dp_only=dp_only)
    scfg = SentinelConfig(mode=mode,
                          mi_periods=mi or specs.default_mi(cfg))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": 512 if multi_pod else 256,
           "kind": kind, "mode": mode, "mi_periods": scfg.mi_periods,
           "fsdp": fsdp, "seq_parallel": seq_parallel, "dp_only": dp_only}
    with mesh:
        with shd.axis_rules(rules):
            opt_cfg = None
            if compress_grads:
                from repro.optim import adamw
                opt_cfg = adamw.OptConfig(compress_grads=True)
            # build from the (possibly overridden) local cfg
            if kind == "train":
                fn, args, in_sh = specs.build_train_cell(
                    cfg, shape, rules, scfg, opt_cfg)
            elif kind == "prefill":
                fn, args, in_sh = specs.build_prefill_cell(cfg, shape, rules)
            else:
                fn, args, in_sh = specs.build_decode_cell(cfg, shape, rules)

            # trip-aware analytic cost (global program; /chips = roofline ideal)
            from repro.launch.costing import jaxpr_cost
            jc = jaxpr_cost(jax.make_jaxpr(fn)(*args))
            rec["cost_analytic"] = {
                "flops_per_chip": jc["flops"] / rec["chips"],
                "matmul_flops_per_chip": jc["matmul_flops"] / rec["chips"],
                "bytes_per_chip": jc["bytes"] / rec["chips"],
            }

            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "host_temp_bytes": ma.host_temp_size_in_bytes,
            }
            ca = compiled.cost_analysis()
            rec["cost"] = {k: ca.get(k, 0.0)
                           for k in ("flops", "bytes accessed",
                                     "utilization operand 0 {}")
                           if k in ca}
            rec["cost"]["flops"] = ca.get("flops", 0.0)
            rec["cost"]["bytes_accessed"] = ca.get("bytes accessed", 0.0)
            txt = compiled.as_text()
            P = cfg.num_periods + len(cfg.prologue)
            rec["collectives"] = collective_bytes(txt, loop_trips=float(P))
            rec["hlo_bytes"] = len(txt)
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mode", default="offload",
                    choices=["offload", "save_hbm", "remat", "full"])
    ap.add_argument("--mi", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-SP residual sharding (beyond-paper opt)")
    ap.add_argument("--dp-only", action="store_true",
                    help="fold the model axis into DP (small models)")
    ap.add_argument("--mlstm-chunk", type=int, default=0,
                    help="chunkwise-parallel mLSTM (xlstm perf lever)")
    ap.add_argument("--moe-group", type=int, default=0,
                    help="MoE dispatch group size override (memory lever)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.mlstm_chunk:
        from repro.kernels import ops as kops
        kops.mlstm_chunk_mode(args.mlstm_chunk)

    if args.arch:
        todo = [(args.arch, args.shape or "train_4k", False)]
    else:
        todo = cells()

    results = []
    for arch, shape_name, _skip in todo:
        try:
            rec = run_cell(arch, shape_name, multi_pod=args.multipod,
                           mode=args.mode, mi=args.mi, fsdp=args.fsdp,
                           compress_grads=args.compress_grads,
                           seq_parallel=args.seq_parallel,
                           dp_only=args.dp_only, moe_group=args.moe_group)
        except Exception as e:
            rec = {"arch": arch, "shape": shape_name, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        status = "ok" if rec.get("ok") else "FAIL"
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'2x16x16' if args.multipod else '16x16'}): {status}",
              flush=True)
        if not rec.get("ok"):
            print(rec.get("error"), flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    ok = sum(1 for r in results if r.get("ok"))
    print(f"[dryrun] {ok}/{len(results)} cells passed")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
