"""Synthetic sharded token pipeline with host-side prefetch.

Deterministic per (seed, step): recovery after a failure replays the exact
same batches (fault-tolerance requirement), and every host materializes only
its addressable shard (``jax.make_array_from_callback``), so the pipeline
scales to arbitrarily many hosts without data movement.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    num_codebooks: int = 0
    num_prefix_tokens: int = 0
    d_model: int = 0              # for prefix embeddings (vlm stub)


def _host_batch(cfg: DataConfig, step: int) -> dict:
    """Full logical batch for `step` (numpy, deterministic)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    shape = (cfg.global_batch, cfg.seq_len)
    if cfg.num_codebooks:
        shape = shape + (cfg.num_codebooks,)
    tokens = rng.integers(0, cfg.vocab_size, size=shape, dtype=np.int32)
    labels = np.roll(tokens, -1, axis=1)   # (B,S) or (B,S,K): next-token/codes
    out = {"tokens": tokens, "labels": labels.astype(np.int32)}
    if cfg.num_prefix_tokens:
        out["prefix_embed"] = rng.standard_normal(
            (cfg.global_batch, cfg.num_prefix_tokens, cfg.d_model),
            dtype=np.float32)
        out["labels"] = np.pad(out["labels"],
                               ((0, 0), (cfg.num_prefix_tokens, 0)))
    return out


def make_batch(cfg: DataConfig, step: int, shardings: Optional[dict] = None) -> dict:
    """Sharded global batch; each host/device fills only its shard."""
    host = _host_batch(cfg, step)
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in host.items()}
    out = {}
    for k, v in host.items():
        s = shardings[k]
        out[k] = jax.make_array_from_callback(
            v.shape, s, lambda idx, v=v: v[idx])
    return out


class Prefetcher:
    """Background-thread prefetch of the next `depth` batches (host side)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2,
                 shardings: Optional[dict] = None):
        self.cfg = cfg
        self.shardings = shardings
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, make_batch(self.cfg, step, self.shardings)),
                           timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
