"""Unified layer stack: period-of-kinds blocks lowered as ``lax.scan``.

Every arch is ``prologue`` (unstacked layers) + ``num_periods`` repeats of a
``period`` of layer kinds (configs/base.py). Stacked params carry a leading
(num_periods,) dim per slot; the stack lowers to one scan so the HLO is
layer-count-independent, and Sentinel's migration interval maps onto blocks of
periods (core/offload.py regroups the same stacked params into
(n_blocks, periods_per_block, ...) and nests scans with offload at block
boundaries).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, LSTM, MAMBA, MLA, MLSTM, SHARED_ATTN, SLSTM
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import Param, init_mlp, mlp, rmsnorm
from repro.sharding import constrain


# ------------------------------------------------------------------ init ----

def init_block(key, cfg, kind: str, dtype, *, dense_ff: int = 0):
    """Params for one layer of the given kind. dense_ff>0 forces a dense MLP
    (deepseek prologue)."""
    ks = jax.random.split(key, 4)
    norm = lambda: Param(jnp.zeros((cfg.d_model,), dtype), ("embed",))
    if kind in (ATTN, LOCAL, SHARED_ATTN):
        p = {"ln1": norm(), "attn": attn_mod.init_attention(ks[0], cfg, dtype),
             "ln2": norm()}
        if cfg.moe is not None and not dense_ff:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, dense_ff or cfg.d_ff, dtype)
        return p
    if kind == MLA:
        p = {"ln1": norm(), "mla": attn_mod.init_mla(ks[0], cfg, dtype),
             "ln2": norm()}
        if cfg.moe is not None and not dense_ff:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, dense_ff or cfg.d_ff, dtype)
        return p
    if kind == MAMBA:
        return {"ln1": norm(), "mamba": ssm_mod.init_mamba(ks[0], cfg, dtype)}
    if kind == MLSTM:
        return {"ln1": norm(), "mlstm": xlstm_mod.init_mlstm(ks[0], cfg, dtype)}
    if kind == SLSTM:
        return {"ln1": norm(), "slstm": xlstm_mod.init_slstm(ks[0], cfg, dtype)}
    if kind == LSTM:
        return {"lstm": xlstm_mod.init_lstm(ks[0], cfg, dtype)}
    raise ValueError(kind)


def stack_trees(trees: List[Any]):
    """Stack a list of Param trees along a new leading (num_periods,) axis."""
    def stack(*leaves):
        if isinstance(leaves[0], Param):
            return Param(jnp.stack([l.value for l in leaves]),
                         ("layers",) + tuple(leaves[0].axes))
        return jnp.stack(leaves)
    return jax.tree.map(stack, *trees, is_leaf=lambda x: isinstance(x, Param))


def init_stack(key, cfg, dtype):
    """Returns {"prologue": [...], "slots": [stacked per period-slot], "shared": ...}."""
    out: Dict[str, Any] = {}
    keys = jax.random.split(key, 3)
    if cfg.prologue:
        pk = jax.random.split(keys[0], len(cfg.prologue))
        out["prologue"] = [init_block(pk[i], cfg, kind, dtype, dense_ff=cfg.prologue_d_ff)
                           for i, kind in enumerate(cfg.prologue)]
    slots = []
    for s, kind in enumerate(cfg.period):
        if kind == SHARED_ATTN:
            slots.append({})  # weights live in out["shared"], one copy
            continue
        sk = jax.random.split(jax.random.fold_in(keys[1], s), cfg.num_periods)
        slots.append(stack_trees([init_block(sk[p], cfg, kind, dtype)
                                  for p in range(cfg.num_periods)]))
    out["slots"] = slots
    if SHARED_ATTN in cfg.period:
        out["shared"] = init_block(keys[2], cfg, SHARED_ATTN, dtype,
                                   dense_ff=cfg.d_ff)
    return out


# ----------------------------------------------------------------- apply ----

def apply_block(params, cfg, kind: str, x, positions, *, cache=None,
                cache_index=None, decode=False, dense_ff: int = 0,
                paged_view=None):
    """One layer. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, LOCAL, SHARED_ATTN, MLA):
        h = rmsnorm(x, params["ln1"], cfg.norm_eps, plus_one=True)
        # explicit full-seq boundary: under sequence-parallel rules this is
        # the all-gather point (residual stays seq-sharded, attention sees
        # the whole sequence); a no-op otherwise
        h = constrain(h, ("batch", "seq", "embed"))
        if kind == MLA:
            a, new_cache = attn_mod.mla_attention(
                params["mla"], cfg, h, positions, cache=cache, cache_index=cache_index)
        else:
            a, new_cache = attn_mod.attention(
                params["attn"], cfg, h, positions,
                kind=ATTN if kind == SHARED_ATTN else kind,
                cache=cache, cache_index=cache_index, paged_view=paged_view)
        x = x + a
        h = rmsnorm(x, params["ln2"], cfg.norm_eps, plus_one=True)
        h = constrain(h, ("batch", "seq", "embed"))
        if "moe" in params:
            f, aux = moe_mod.moe_mlp(params["moe"], cfg, h, cfg.act)
        else:
            f = mlp(params["mlp"], h, cfg.act)
        x = x + f
        return constrain(x, ("batch", "seq_res", "embed")), new_cache, aux
    if kind == MAMBA:
        h = rmsnorm(x, params["ln1"], cfg.norm_eps, plus_one=True)
        y, new_cache = ssm_mod.mamba_block(params["mamba"], cfg, h,
                                           cache=cache, decode=decode)
        return constrain(x + y, ("batch", "seq_res", "embed")), new_cache, aux
    if kind == MLSTM:
        h = rmsnorm(x, params["ln1"], cfg.norm_eps, plus_one=True)
        y, new_cache = xlstm_mod.mlstm_block(params["mlstm"], cfg, h,
                                             cache=cache, decode=decode)
        return constrain(x + y, ("batch", "seq_res", "embed")), new_cache, aux
    if kind == SLSTM:
        h = rmsnorm(x, params["ln1"], cfg.norm_eps, plus_one=True)
        y, new_cache = xlstm_mod.slstm_block(params["slstm"], cfg, h,
                                             cache=cache, decode=decode)
        return constrain(x + y, ("batch", "seq_res", "embed")), new_cache, aux
    if kind == LSTM:
        y, new_cache = xlstm_mod.lstm_block(params["lstm"], cfg, x,
                                            cache=cache, decode=decode)
        return y, new_cache, aux
    raise ValueError(kind)


def _period_body(cfg, stack_params, shared_params, x, positions, caches,
                 cache_index, decode, paged_view=None):
    """Apply one period (all slots in order). caches: list per slot or None."""
    new_caches: List[Any] = []
    aux_total = jnp.zeros((), jnp.float32)
    for s, kind in enumerate(cfg.period):
        p = shared_params if kind == SHARED_ATTN else stack_params[s]
        c = caches[s] if caches is not None else None
        x, nc, aux = apply_block(p, cfg, kind, x, positions, cache=c,
                                 cache_index=cache_index, decode=decode,
                                 paged_view=paged_view)
        new_caches.append(nc)
        aux_total = aux_total + aux
    return x, new_caches, aux_total


def stack_forward(params, cfg, x, positions, *, caches=None, cache_index=None,
                  decode: bool = False, remat_policy=None,
                  unroll_periods: bool = False, mi_periods: int = 1,
                  tag_block_out: bool = False, paged_view=None):
    """Run prologue + scanned periods.

    params: raw value tree (Param wrappers stripped). caches: {"prologue": [...],
    "slots": [stacked per slot]} or None. Returns (x, new_caches, aux).

    Sentinel integration (core/offload.py):
      - mi_periods: the migration interval in periods. Periods are grouped
        into blocks of this size (outer scan over blocks, inner over periods);
        block boundaries are where long-lived residuals are saved/offloaded
        and everything inside a block is recomputed in backward (the
        reserved-pool analogue).
      - remat_policy: jax.checkpoint policy applied to the *block* body —
        e.g. save_and_offload_only_these_names(["block_out"]).
      - tag_block_out: checkpoint_name the block carry so the policy can
        offload it to pinned_host.
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_pro: List[Any] = []
    if cfg.prologue:
        for i, kind in enumerate(cfg.prologue):
            c = caches["prologue"][i] if caches is not None else None
            x, nc, aux = apply_block(params["prologue"][i], cfg, kind, x, positions,
                                     cache=c, cache_index=cache_index, decode=decode,
                                     dense_ff=cfg.prologue_d_ff,
                                     paged_view=paged_view)
            new_pro.append(nc)
            aux_total = aux_total + aux

    shared = params.get("shared")
    slot_params = params["slots"]
    slot_caches = caches["slots"] if caches is not None else None

    if unroll_periods:
        # plain python loop (profiling mode: per-layer named_scopes)
        new_slot_caches = [] if slot_caches is not None else None
        for pidx in range(cfg.num_periods):
            pp = [jax.tree.map(lambda a: a[pidx], sp) for sp in slot_params]
            cc = ([jax.tree.map(lambda a: a[pidx], sc) if sc is not None else None
                   for sc in slot_caches] if slot_caches is not None else None)
            with jax.named_scope(f"period_{pidx}"):
                x, ncs, aux = _period_body(cfg, pp, shared, x, positions, cc,
                                           cache_index, decode, paged_view)
            aux_total = aux_total + aux
            if new_slot_caches is not None:
                new_slot_caches.append(ncs)
        if new_slot_caches is not None:
            per_slot = [stacked_from([ncs[s] for ncs in new_slot_caches])
                        for s in range(len(cfg.period))]
        else:
            per_slot = None
        return x, _pack_caches(cfg, new_pro, per_slot, caches), aux_total

    def body(carry, inputs):
        x, aux = carry
        sp, sc = inputs
        x, ncs, a = _period_body(cfg, sp, shared, x, positions, sc,
                                 cache_index, decode, paged_view)
        return (x, aux + a), ncs

    xs = (slot_params, slot_caches if slot_caches is not None
          else [None] * len(cfg.period))

    if mi_periods <= 1:
        if remat_policy is not None:
            body = jax.checkpoint(body, policy=remat_policy)
        (x, aux), new_slot_caches = jax.lax.scan(body, (x, aux_total), xs)
        return x, _pack_caches(cfg, new_pro, new_slot_caches, caches), aux

    # ---- Sentinel MI blocking: scan over blocks of mi_periods periods ----
    P = cfg.num_periods
    assert P % mi_periods == 0, (
        f"num_periods {P} not divisible by migration interval {mi_periods}")
    nb = P // mi_periods
    xs_blocked = jax.tree.map(
        lambda a: a.reshape((nb, mi_periods) + a.shape[1:]), xs)

    def block_body(carry, inputs):
        (x2, aux2), ncs = jax.lax.scan(body, carry, inputs)
        if tag_block_out:
            from jax.ad_checkpoint import checkpoint_name
            x2 = checkpoint_name(x2, "block_out")
        return (x2, aux2), ncs

    if remat_policy is not None:
        block_body = jax.checkpoint(block_body, policy=remat_policy)

    (x, aux), ncs_blocked = jax.lax.scan(block_body, (x, aux_total), xs_blocked)
    new_slot_caches = None
    if slot_caches is not None:
        new_slot_caches = jax.tree.map(
            lambda a: a.reshape((nb * mi_periods,) + a.shape[2:]), ncs_blocked)
    return x, _pack_caches(cfg, new_pro, new_slot_caches, caches), aux


def stacked_from(trees: List[Any]):
    if trees and trees[0] is None:
        return None
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def _pack_caches(cfg, new_pro, new_slots, caches):
    if caches is None:
        return None
    return {"prologue": new_pro, "slots": new_slots}
