"""Mixture-of-Experts: top-k router + grouped capacity-based dispatch/combine.

GShard/GSPMD-style: tokens are split into groups of ``group_size``; each group
dispatches at most C = group_size*k*capacity_factor/E tokens per expert through
a one-hot einsum, experts run a gated MLP on (G, E, C, d), and a weighted
combine einsum scatters results back. Grouping bounds the dispatch tensor to
T * group_size * k * factor elements (vs T^2-ish ungrouped) and keeps the
group dim aligned with the data mesh axes while experts shard over "model"
(expert parallelism) — GSPMD inserts the all-to-all.

Shared experts (DeepSeek) run as a plain dense MLP on every token.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Param, act_fn, dense_init, init_mlp, mlp
from repro.sharding import constrain


def init_moe(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    E, F = m.num_experts, m.d_ff

    def bank(k, din, dout, axes):
        w = jax.random.normal(k, (E, din, dout), dtype) * (din ** -0.5)
        return Param(w, axes)

    p = {
        "router": dense_init(ks[0], d, E, ("embed", None), dtype),
        "wi": bank(ks[1], d, F, ("experts", "embed", "expert_mlp")),
        "wg": bank(ks[2], d, F, ("experts", "embed", "expert_mlp")),
        "wo": bank(ks[3], F, d, ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, F * m.num_shared_experts, dtype)
    return p


def route_topk(logits, k: int) -> Tuple[jax.Array, jax.Array]:
    """(weights (..., k) softmaxed over the chosen k, indices (..., k))."""
    vals, idx = jax.lax.top_k(logits, k)
    return jax.nn.softmax(vals, axis=-1), idx


def moe_mlp(params, cfg, x, act: str):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    gsz = min(m.group_size, T)
    assert T % gsz == 0, f"tokens {T} not divisible by moe group size {gsz}"
    G = T // gsz
    E, K = m.num_experts, m.experts_per_token
    C = max(K, int(m.capacity_factor * gsz * K / E))

    xt = x.reshape(G, gsz, d)
    xt = constrain(xt, ("batch", None, "embed"))
    logits = jnp.einsum("gtd,de->gte", xt, params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, idx = route_topk(logits, K)                         # (G,gsz,K)

    # per-(group, expert) running count -> position within capacity buffer
    onehot_i = jax.nn.one_hot(idx, E, dtype=jnp.int32)           # (G,gsz,K,E)
    flat = onehot_i.reshape(G, gsz * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gsz, K, E)
    pos = jnp.sum(pos * onehot_i, axis=-1)                       # (G,gsz,K)
    keep = (pos < C).astype(xt.dtype)

    oh_e = jax.nn.one_hot(idx, E, dtype=xt.dtype)                # (G,gsz,K,E)
    oh_c = jax.nn.one_hot(pos, C, dtype=xt.dtype)                # (G,gsz,K,C)
    disp = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c, keep)   # (G,gsz,E,C)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", oh_e, oh_c,
                      keep * weights.astype(xt.dtype))

    ex_in = jnp.einsum("gtd,gtec->gecd", xt, disp)               # (G,E,C,d)
    ex_in = constrain(ex_in, ("batch", "experts", "capacity", "embed"))
    h = act_fn(act)(jnp.einsum("gecd,edf->gecf", ex_in, params["wg"])) * \
        jnp.einsum("gecd,edf->gecf", ex_in, params["wi"])
    h = constrain(h, ("batch", "experts", "capacity", "expert_mlp"))
    ex_out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    ex_out = constrain(ex_out, ("batch", "experts", "capacity", "embed"))
    out = jnp.einsum("gecd,gtec->gtd", ex_out, comb).reshape(B, S, d)

    if "shared" in params:
        out = out + mlp(params["shared"], x, act)

    # Switch-style load-balance aux: E * sum(frac_tokens_e * frac_prob_e)
    frac_tokens = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    frac_prob = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_prob) * m.router_aux_weight
    return out, aux
