"""Cache construction for serving: per-layer-kind cache buffers, stacked over
periods to match the scanned layer stack.

Tier-aware construction (Sentinel-Serve): a cache can be split along the KV
sequence dimension into a *cold prefix* (old tokens, host/slow memory) and a
*hot window* (recent tokens, HBM/fast memory), per the decode-phase
``ServePlan``.  On TPU the cold prefix lives in ``pinned_host`` and streams
over PCIe at read time; on CPU (this repo's CI) the only memory kind is the
host itself, so placement degrades to an explicit no-op while the splice and
merge mechanics stay identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, LSTM, MAMBA, MLA, MLSTM, SHARED_ATTN, SLSTM
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def init_layer_cache(cfg, kind: str, batch: int, max_seq: int, dtype):
    if kind in (ATTN, LOCAL, SHARED_ATTN):
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        # KV heads folded into one dim so odd head counts (5, 15...) still
        # shard over the model axis. Sliding-window layers only ever read the
        # last `window` entries, but we keep the full buffer for uniform
        # indexing (baseline; see §Perf for the windowed-cache optimization).
        return {"k": jnp.zeros((batch, max_seq, KV * hd), dtype),
                "v": jnp.zeros((batch, max_seq, KV * hd), dtype)}
    if kind == MLA:
        return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}
    if kind == MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    if kind == LSTM:
        return xlstm_mod.init_lstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Cache pytree matching stack_forward's expectations: prologue caches are
    per-layer; slot caches carry a leading (num_periods,) dim."""
    pro = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
           for kind in cfg.prologue]

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, max_seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy()
            if cfg.num_periods > 1 else a[None], one)

    return {"prologue": pro, "slots": [stacked(k) for k in cfg.period]}


# ------------------------------------------------------- tiered (serve) ----

HOST_MEMORY_KINDS = ("pinned_host", "unpinned_host")


def host_memory_kind() -> Optional[str]:
    """First host-side memory kind the default device exposes, or None.
    TPU: 'pinned_host'.  CPU: 'unpinned_host' (which is also its default —
    host offload is then an explicit no-op, keeping the code path uniform)."""
    dev = jax.devices()[0]
    try:
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:
        return None
    for k in HOST_MEMORY_KINDS:
        if k in kinds:
            return k
    return None


def to_host(tree):
    """Place every array leaf in host memory (async copy; XLA overlaps it with
    whatever is executing — the migration channel).  Identity when the backend
    exposes no host memory kind."""
    kind = host_memory_kind()
    if kind is None:
        return tree
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)


def to_device(tree):
    """Bring host-resident leaves back to the device's default memory."""
    dev = jax.devices()[0]
    return jax.tree.map(lambda a: jax.device_put(a, dev), tree)


def kv_token_bytes(cfg, dtype_bytes: int = 2) -> float:
    """Mean KV-cache bytes per token per layer, averaged over ALL layer kinds
    (stateful kinds hold O(1) state and contribute zero), so that
    ``kv_token_bytes(cfg) * cfg.num_layers`` is the model's true per-token KV
    growth.  Feeds the serve-trace model and the decode-phase planner."""
    def one(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        if kind == MLA:
            return (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        return 0.0                    # stateful kinds: O(1) state, no KV growth
    total = sum(one(k) for k in cfg.prologue) + \
        cfg.num_periods * sum(one(k) for k in cfg.period)
    return total / cfg.num_layers if cfg.num_layers else 0.0


def _is_seq_leaf(leaf, max_seq: int) -> bool:
    # KV buffers carry the sequence at axis -2: (B, S, H), (P, B, S, H),
    # (B, S, rank).  Stateful caches (mamba/lstm conv+state) never match as
    # long as no state dim equals max_seq — hold for every non-trivial
    # max_seq in this repo.
    return leaf.ndim >= 3 and leaf.shape[-2] == max_seq


def split_seq_cache(caches, max_seq: int, cold_len: int):
    """Split every seq-dim leaf at ``cold_len``: (cold_prefix, hot_window).
    Non-seq leaves stay whole in the hot tree; their cold slot is None."""
    cold = jax.tree.map(
        lambda l: l[..., :cold_len, :] if _is_seq_leaf(l, max_seq) else None,
        caches)
    hot = jax.tree.map(
        lambda l: l[..., cold_len:, :] if _is_seq_leaf(l, max_seq) else l,
        caches)
    return cold, hot


def merge_seq_cache(cold, hot):
    """Inverse of split_seq_cache.  Inside jit, the concatenate reading a
    host-resident cold leaf is exactly the streamed cold-KV fetch."""
    return jax.tree.map(
        lambda c, h: h if c is None else jnp.concatenate([c, h], axis=-2),
        cold, hot, is_leaf=lambda x: x is None)


def splice_slot(big_tree, one_tree, slot: int, batch: int):
    """Write a single-request cache (batch 1) into row ``slot`` of a batched
    cache — the continuous-batching cache splice.  Works on full, cold, and
    hot trees alike (None leaves pass through).  Dispatch is async: the copy
    overlaps with whatever decode work is already enqueued.

    Batch-axis position is decided by cache *structure*, not leaf shapes:
    ``slots`` subtree leaves carry a leading (num_periods,) dim (batch at
    axis 1), ``prologue`` leaves have batch at axis 0 — shape heuristics
    would silently mis-splice when a sliced sequence length collides with
    the slot count."""
    def one_leaf(stacked):
        def f(big, one):
            if big is None:
                return None
            if stacked:                                  # (P, B, ...)
                return big.at[:, slot].set(one[:, 0])
            return big.at[slot].set(one[0])              # (B, ...)
        return f

    none_leaf = lambda x: x is None
    if isinstance(big_tree, dict) and \
            set(big_tree) == {"prologue", "slots"}:      # init_cache layout
        return {"prologue": jax.tree.map(one_leaf(False),
                                         big_tree["prologue"],
                                         one_tree["prologue"],
                                         is_leaf=none_leaf),
                "slots": jax.tree.map(one_leaf(True), big_tree["slots"],
                                      one_tree["slots"], is_leaf=none_leaf)}
    # generic tree: fall back to the shape heuristic
    def guess(big, one):
        if big is None:
            return None
        stacked = big.ndim >= 2 and big.shape[1] == batch
        return one_leaf(stacked)(big, one)
    return jax.tree.map(guess, big_tree, one_tree, is_leaf=none_leaf)


@dataclass
class TieredCache:
    """A cache split into a host-resident cold prefix and a fast hot window."""
    cold: Any
    hot: Any
    cold_len: int
    max_seq: int

    def merged(self):
        return merge_seq_cache(self.cold, self.hot)


def init_tiered_cache(cfg, batch: int, max_seq: int, cold_len: int,
                      dtype=jnp.bfloat16) -> TieredCache:
    """Tier-aware cache construction: the cold KV prefix is placed in host
    memory, the hot window (and all stateful caches) stay in device memory."""
    cold_len = max(0, min(int(cold_len), max_seq))
    full = init_cache(cfg, batch, max_seq, dtype)
    cold, hot = split_seq_cache(full, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


def retier(caches, max_seq: int, cold_len: int) -> TieredCache:
    """Split an existing full cache (e.g. fresh from prefill) into tiers."""
    cold, hot = split_seq_cache(caches, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


def cache_logical_axes(cfg) -> Dict[str, Any]:
    """Logical sharding axes for every cache leaf (mirrors init_cache)."""
    def axes_layer(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return {"k": ("batch", "kv_seq", "kv_heads"),
                    "v": ("batch", "kv_seq", "kv_heads")}
        if kind == MLA:
            return {"ckv": ("batch", "kv_seq", "kv_latent"),
                    "krope": ("batch", "kv_seq", None)}
        if kind == MAMBA:
            return {"h": ("batch", "ssm_heads", None, None),
                    "conv": ("batch", None, "ssm_heads")}
        if kind == MLSTM:
            return {"state": (("batch", "heads", None, None),
                              ("batch", "heads", None),
                              ("batch", "heads")),
                    "conv": ("batch", None, "mlp")}
        if kind == SLSTM:
            return {"state": (("batch", "heads", None),) * 2 +
                             (("batch", "heads", None),) * 2,
                    "conv": ("batch", None, "mlp")}
        if kind == LSTM:
            return {"h": ("batch", "embed"), "c": ("batch", "embed")}
        raise ValueError(kind)

    from repro.sharding import is_axes_leaf
    pro = [axes_layer(k) for k in cfg.prologue]
    slots = [jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_layer(k),
                          is_leaf=is_axes_leaf)
             for k in cfg.period]
    return {"prologue": pro, "slots": slots}
