"""Cache construction for serving: per-layer-kind cache buffers, stacked over
periods to match the scanned layer stack.

Tier-aware construction (Sentinel-Serve): a cache can be split along the KV
sequence dimension into a *cold prefix* (old tokens, host/slow memory) and a
*hot window* (recent tokens, HBM/fast memory), per the decode-phase
``ServePlan``.  On TPU the cold prefix lives in ``pinned_host`` and streams
over PCIe at read time; on CPU (this repo's CI) the only memory kind is the
host itself, so placement degrades to an explicit no-op while the splice and
merge mechanics stay identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, LSTM, MAMBA, MLA, MLSTM, SHARED_ATTN, SLSTM
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def init_layer_cache(cfg, kind: str, batch: int, max_seq: int, dtype):
    if kind in (ATTN, LOCAL, SHARED_ATTN):
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        # KV heads folded into one dim so odd head counts (5, 15...) still
        # shard over the model axis. Sliding-window layers only ever read the
        # last `window` entries, but we keep the full buffer for uniform
        # indexing (baseline; see §Perf for the windowed-cache optimization).
        return {"k": jnp.zeros((batch, max_seq, KV * hd), dtype),
                "v": jnp.zeros((batch, max_seq, KV * hd), dtype)}
    if kind == MLA:
        return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}
    if kind == MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    if kind == LSTM:
        return xlstm_mod.init_lstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Cache pytree matching stack_forward's expectations: prologue caches are
    per-layer; slot caches carry a leading (num_periods,) dim."""
    pro = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
           for kind in cfg.prologue]

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, max_seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy()
            if cfg.num_periods > 1 else a[None], one)

    return {"prologue": pro, "slots": [stacked(k) for k in cfg.period]}


# ------------------------------------------------------- tiered (serve) ----

HOST_MEMORY_KINDS = ("pinned_host", "unpinned_host")


def host_memory_kind() -> Optional[str]:
    """First host-side memory kind the default device exposes, or None.
    TPU: 'pinned_host'.  CPU: 'unpinned_host' (which is also its default —
    host offload is then an explicit no-op, keeping the code path uniform)."""
    dev = jax.devices()[0]
    try:
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:
        return None
    for k in HOST_MEMORY_KINDS:
        if k in kinds:
            return k
    return None


def to_host(tree, device=None):
    """Place every array leaf in host memory (async copy; XLA overlaps it with
    whatever is executing — the migration channel).  Identity when the backend
    exposes no host memory kind.  ``device`` selects whose host path the copy
    rides (and on CPU-style backends, which device the array commits to) —
    a sharded engine pins each shard's cold pool to that shard's device so
    hot<->cold scatters never mix committed devices; default: device 0."""
    kind = host_memory_kind()
    if kind is None:
        return tree
    dev = device if device is not None else jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)


def to_device(tree):
    """Bring host-resident leaves back to the device's default memory."""
    dev = jax.devices()[0]
    return jax.tree.map(lambda a: jax.device_put(a, dev), tree)


def kv_token_bytes(cfg, dtype_bytes: int = 2) -> float:
    """Mean KV-cache bytes per token per layer, averaged over ALL layer kinds
    (stateful kinds hold O(1) state and contribute zero), so that
    ``kv_token_bytes(cfg) * cfg.num_layers`` is the model's true per-token KV
    growth.  Feeds the serve-trace model and the decode-phase planner."""
    def one(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        if kind == MLA:
            return (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        return 0.0                    # stateful kinds: O(1) state, no KV growth
    total = sum(one(k) for k in cfg.prologue) + \
        cfg.num_periods * sum(one(k) for k in cfg.period)
    return total / cfg.num_layers if cfg.num_layers else 0.0


def _is_seq_leaf(leaf, max_seq: int) -> bool:
    # KV buffers carry the sequence at axis -2: (B, S, H), (P, B, S, H),
    # (B, S, rank).  Stateful caches (mamba/lstm conv+state) never match as
    # long as no state dim equals max_seq — hold for every non-trivial
    # max_seq in this repo.
    return leaf.ndim >= 3 and leaf.shape[-2] == max_seq


def split_seq_cache(caches, max_seq: int, cold_len: int):
    """Split every seq-dim leaf at ``cold_len``: (cold_prefix, hot_window).
    Non-seq leaves stay whole in the hot tree; their cold slot is None."""
    cold = jax.tree.map(
        lambda l: l[..., :cold_len, :] if _is_seq_leaf(l, max_seq) else None,
        caches)
    hot = jax.tree.map(
        lambda l: l[..., cold_len:, :] if _is_seq_leaf(l, max_seq) else l,
        caches)
    return cold, hot


def merge_seq_cache(cold, hot):
    """Inverse of split_seq_cache.  Inside jit, the concatenate reading a
    host-resident cold leaf is exactly the streamed cold-KV fetch."""
    return jax.tree.map(
        lambda c, h: h if c is None else jnp.concatenate([c, h], axis=-2),
        cold, hot, is_leaf=lambda x: x is None)


def splice_slot(big_tree, one_tree, slot: int, batch: int):
    """Write a single-request cache (batch 1) into row ``slot`` of a batched
    cache — the continuous-batching cache splice.  Works on full, cold, and
    hot trees alike (None leaves pass through).  Dispatch is async: the copy
    overlaps with whatever decode work is already enqueued.

    Batch-axis position is decided by cache *structure*, not leaf shapes:
    ``slots`` subtree leaves carry a leading (num_periods,) dim (batch at
    axis 1), ``prologue`` leaves have batch at axis 0 — shape heuristics
    would silently mis-splice when a sliced sequence length collides with
    the slot count."""
    def one_leaf(stacked):
        def f(big, one):
            if big is None:
                return None
            if stacked:                                  # (P, B, ...)
                return big.at[:, slot].set(one[:, 0])
            return big.at[slot].set(one[0])              # (B, ...)
        return f

    none_leaf = lambda x: x is None
    if isinstance(big_tree, dict) and \
            set(big_tree) == {"prologue", "slots"}:      # init_cache layout
        return {"prologue": jax.tree.map(one_leaf(False),
                                         big_tree["prologue"],
                                         one_tree["prologue"],
                                         is_leaf=none_leaf),
                "slots": jax.tree.map(one_leaf(True), big_tree["slots"],
                                      one_tree["slots"], is_leaf=none_leaf)}
    # generic tree: fall back to the shape heuristic
    def guess(big, one):
        if big is None:
            return None
        stacked = big.ndim >= 2 and big.shape[1] == batch
        return one_leaf(stacked)(big, one)
    return jax.tree.map(guess, big_tree, one_tree, is_leaf=none_leaf)


@dataclass
class TieredCache:
    """A cache split into a host-resident cold prefix and a fast hot window."""
    cold: Any
    hot: Any
    cold_len: int
    max_seq: int

    def merged(self):
        return merge_seq_cache(self.cold, self.hot)


def init_tiered_cache(cfg, batch: int, max_seq: int, cold_len: int,
                      dtype=jnp.bfloat16) -> TieredCache:
    """Tier-aware cache construction: the cold KV prefix is placed in host
    memory, the hot window (and all stateful caches) stay in device memory."""
    cold_len = max(0, min(int(cold_len), max_seq))
    full = init_cache(cfg, batch, max_seq, dtype)
    cold, hot = split_seq_cache(full, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


def retier(caches, max_seq: int, cold_len: int) -> TieredCache:
    """Split an existing full cache (e.g. fresh from prefill) into tiers."""
    cold, hot = split_seq_cache(caches, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


# ------------------------------------------------------- paged (serve) ----
#
# Per-slot cold boundaries need a representation that splits the KV sequence
# at a *different* point per batch row, which a single slice cannot express.
# Two pieces:
#
#   PageTable        the metadata manager: logical (slot, page) -> physical
#                    page in the hot or cold pool, with alloc/free/splice at
#                    page granularity and the cold-prefix invariant (a slot's
#                    cold pages are always a prefix of its logical pages).
#                    This is the layout kernels/paged_decode.py consumes.
#   PagedTieredCache the pytree storage consumed by the jnp model path on
#                    CPU: full-size hot (device) and cold (host) trees with a
#                    per-slot boundary vector; ``merged()`` is a masked
#                    where-merge that reads cold rows below each slot's
#                    boundary and hot rows above it — bit-identical to the
#                    dense cache because every row was copied from the dense
#                    values when it changed tier.
#
# On TPU the PageTable's pools are the real storage and the paged kernel
# streams cold pages over PCIe; on CPU (CI) the two-buffer masked form is the
# placement simulation, with migration bytes tracked by the serving engine.


class PageTable:
    """Slot-local logical->physical page mapping over two physical pools —
    a true physical-page allocator with reference counting.

    Pages are ``page_tokens`` tokens of KV.  Each slot owns an ordered list
    of logical pages; page i lives either in the hot pool (tier 0) or the
    cold pool (tier 1).  Invariant: the cold pages of a slot form a prefix of
    its logical pages (the cold *boundary*), and within one residency a
    slot's boundary only moves forward — pages are demoted hot->cold as the
    hot window slides, never resurrected until the slot is refilled.

    Sharing (vLLM-style prefix sharing): ``share(dst, src, n)`` maps the
    first n logical pages of ``dst`` onto ``src``'s physical pages, bumping
    per-page refcounts.  A shared page is read-only; the first divergent
    write must go through ``cow`` (copy-on-write: the writer gets a private
    physical page).  Demoting a shared page gives the demoting slot a cold
    *twin* copy — memoized per hot page, so N sharers demoting the same
    logical page move its bytes exactly once.

    ``version`` increments on every mutation; callers caching ``as_arrays``
    output re-upload only when it changes (incremental layout deltas, never
    per-step rebuilds).
    """

    FREE = -1

    def __init__(self, slots: int, pages_per_slot: int, page_tokens: int,
                 hot_pages: Optional[int] = None,
                 cold_pages: Optional[int] = None):
        self.slots, self.pages_per_slot = slots, pages_per_slot
        self.page_tokens = page_tokens
        n = slots * pages_per_slot
        self.n_hot = hot_pages or n
        self.n_cold = cold_pages or n
        self.hot_free = list(range(self.n_hot - 1, -1, -1))
        self.cold_free = list(range(self.n_cold - 1, -1, -1))
        self.hot_ref = [0] * self.n_hot
        self.cold_ref = [0] * self.n_cold
        self.table = [[self.FREE] * pages_per_slot for _ in range(slots)]
        self.tier = [[self.FREE] * pages_per_slot for _ in range(slots)]
        self.n_pages = [0] * slots
        self.cold_twin: Dict[int, int] = {}      # hot phys -> cold twin phys
        self._twin_of: Dict[int, int] = {}       # cold phys -> hot phys
        self.version = 0

    # ------------------------------------------------------------ queries --
    def cold_pages(self, slot: int) -> int:
        """Pages below the slot's cold boundary."""
        t = self.tier[slot]
        n = 0
        while n < self.n_pages[slot] and t[n] == 1:
            n += 1
        return n

    def cold_tokens(self, slot: int) -> int:
        return self.cold_pages(slot) * self.page_tokens

    def _refs(self, tier: int):
        return self.cold_ref if tier == 1 else self.hot_ref

    def _free(self, tier: int):
        return self.cold_free if tier == 1 else self.hot_free

    def refcount(self, slot: int, page_idx: int) -> int:
        return self._refs(self.tier[slot][page_idx])[
            self.table[slot][page_idx]]

    def is_shared(self, slot: int, page_idx: int) -> bool:
        return self.refcount(slot, page_idx) > 1

    def pages_in_use(self) -> int:
        """Distinct physical pages currently allocated across both pools."""
        return (self.n_hot - len(self.hot_free)
                + self.n_cold - len(self.cold_free))

    def as_arrays(self):
        """(page_table, page_tier) int32 arrays for kernels/paged_decode.py."""
        return (jnp.asarray(self.table, jnp.int32),
                jnp.asarray(self.tier, jnp.int32))

    # ---------------------------------------------------------- mutations --
    def _acquire(self, tier: int) -> int:
        pool = self._free(tier)
        if not pool:
            raise ValueError(f"{'cold' if tier else 'hot'} pool exhausted")
        phys = pool.pop()
        self._refs(tier)[phys] = 1
        return phys

    def _release(self, tier: int, phys: int) -> None:
        refs = self._refs(tier)
        refs[phys] -= 1
        assert refs[phys] >= 0, f"tier {tier} page {phys}: negative refcount"
        if refs[phys] == 0:
            self._free(tier).append(phys)
            if tier == 0:
                # hot page gone: its cold twin (if any) lives on through its
                # own refs, but can no longer be reached for dedup
                twin = self.cold_twin.pop(phys, None)
                if twin is not None:
                    self._twin_of.pop(twin, None)
            else:
                src = self._twin_of.pop(phys, None)
                if src is not None:
                    self.cold_twin.pop(src, None)

    def alloc(self, slot: int, tier: int) -> int:
        """Append one logical page to ``slot`` in the given tier; returns the
        physical page id.  Raises when the slot or the pool is exhausted."""
        i = self.n_pages[slot]
        if i >= self.pages_per_slot:
            raise ValueError(f"slot {slot}: pages_per_slot exhausted")
        if tier == 1 and i != self.cold_pages(slot):
            raise ValueError(f"slot {slot}: cold alloc would break the "
                             "cold-prefix invariant")
        phys = self._acquire(tier)
        self.table[slot][i] = phys
        self.tier[slot][i] = tier
        self.n_pages[slot] = i + 1
        self.version += 1
        return phys

    def share(self, dst: int, src: int, n: int) -> int:
        """Map the first ``n`` logical pages of empty slot ``dst`` onto
        ``src``'s physical pages (prefix sharing).  Refcounts bump; tiers are
        inherited from ``src`` (a prefix of src's tier row is itself a valid
        cold-prefix pattern).  Returns the number of pages shared."""
        if self.n_pages[dst]:
            raise ValueError(f"slot {dst}: share requires an empty slot")
        if n > self.n_pages[src]:
            raise ValueError(f"slot {src}: only {self.n_pages[src]} pages "
                             f"allocated, cannot share {n}")
        for i in range(n):
            phys, tier = self.table[src][i], self.tier[src][i]
            self._refs(tier)[phys] += 1
            self.table[dst][i] = phys
            self.tier[dst][i] = tier
        self.n_pages[dst] = n
        if n:
            self.version += 1
        return n

    def cow(self, slot: int, page_idx: int) -> Optional[tuple]:
        """Copy-on-write: give ``slot`` a private physical page for logical
        page ``page_idx`` before a divergent write.  No-op (returns None)
        when the page is already exclusive; otherwise returns
        ``(src_phys, new_phys, tier)`` — the caller must copy the page's
        data from src to new in that tier's pool."""
        if page_idx >= self.n_pages[slot]:
            raise ValueError(f"slot {slot}: page {page_idx} not allocated")
        if not self.is_shared(slot, page_idx):
            return None
        tier = self.tier[slot][page_idx]
        src = self.table[slot][page_idx]
        new = self._acquire(tier)
        self._refs(tier)[src] -= 1
        self.table[slot][page_idx] = new
        self.version += 1
        return (src, new, tier)

    def free_slot(self, slot: int) -> int:
        """Release every page reference of ``slot`` (slot refill / request
        completion); a physical page returns to its free list only when its
        last reference drops.  Returns the number of references released."""
        n = self.n_pages[slot]
        for i in range(n):
            self._release(self.tier[slot][i], self.table[slot][i])
            self.table[slot][i] = self.tier[slot][i] = self.FREE
        self.n_pages[slot] = 0
        if n:
            self.version += 1
        return n

    def demote(self, slot: int, page_idx: int) -> tuple:
        """Move one page of ``slot`` hot->cold.  Only the page at the cold
        boundary may move (prefix invariant).

        Exclusive page: the classic move (hot page freed, cold page
        allocated, data must be copied).  Shared page: the demoting slot
        gets a cold *twin* — allocated and copied on the first demotion,
        reused (refcount bump, no copy) by every later sharer, so shared
        bytes migrate exactly once.  Returns ``(cold_phys, src_hot_phys,
        copied)``; the caller copies pool data src->cold iff ``copied``.
        """
        if page_idx != self.cold_pages(slot):
            raise ValueError(f"slot {slot}: demote({page_idx}) is not the "
                             f"cold boundary {self.cold_pages(slot)}")
        if page_idx >= self.n_pages[slot]:
            raise ValueError(f"slot {slot}: page {page_idx} not allocated")
        src = self.table[slot][page_idx]
        twin = self.cold_twin.get(src)
        if twin is not None and self.cold_ref[twin] > 0:
            self.cold_ref[twin] += 1
            cold_phys, copied = twin, False
        else:
            if not self.cold_free:
                raise ValueError("cold pool exhausted")
            cold_phys = self._acquire(1)
            copied = True
            if self.hot_ref[src] > 1:        # others still share: memoize
                self.cold_twin[src] = cold_phys
                self._twin_of[cold_phys] = src
        self._release(0, src)
        self.table[slot][page_idx] = cold_phys
        self.tier[slot][page_idx] = 1
        self.version += 1
        return (cold_phys, src, copied)

    def splice_slot(self, slot: int, tokens: int, cold_tokens: int) -> int:
        """Refill ``slot`` with a fresh request: free its pages, then allocate
        ceil(tokens/page) pages with the first ``cold_tokens`` worth cold.
        Returns the number of cold pages allocated."""
        self.free_slot(slot)
        n = -(-tokens // self.page_tokens) if tokens else 0
        n_cold = min(n, cold_tokens // self.page_tokens)
        for i in range(n):
            self.alloc(slot, 1 if i < n_cold else 0)
        return n_cold

    def check(self) -> None:
        """Assert structural invariants (used by the property tests)."""
        import collections as _c
        for tier, pool, refs in ((0, self.hot_free, self.hot_ref),
                                 (1, self.cold_free, self.cold_ref)):
            used = _c.Counter(self.table[s][i] for s in range(self.slots)
                              for i in range(self.n_pages[s])
                              if self.tier[s][i] == tier)
            for phys, r in enumerate(refs):
                assert r >= 0, f"tier {tier}: negative refcount at {phys}"
                assert used.get(phys, 0) == r, \
                    f"tier {tier}: page {phys} refcount {r} != " \
                    f"{used.get(phys, 0)} references (double alloc / leak)"
            assert not (set(used) & set(pool)), f"tier {tier}: used page free"
        for s in range(self.slots):
            n, nc = self.n_pages[s], self.cold_pages(s)
            assert all(self.tier[s][i] == 1 for i in range(nc))
            assert all(self.tier[s][i] == 0 for i in range(nc, n))
            assert all(self.table[s][i] == self.FREE for i in
                       range(n, self.pages_per_slot))
        for src, twin in self.cold_twin.items():
            assert self.hot_ref[src] > 0, "twin memo for a freed hot page"
            assert self.cold_ref[twin] > 0, "twin memo for a freed cold page"
            assert self._twin_of.get(twin) == src


class MeshPageTable:
    """N per-device ``PageTable``s under one global logical slot namespace.

    The tier-graph runtime's allocator view of a device mesh: device ``d``'s
    hot pool is its own HBM, its cold pool a region of the one shared host
    memory.  Global slot ids are ``gslot = offset[d] + local_slot`` (offsets
    cumulative over per-device slot counts), so every logical slot names
    exactly one ``(device, slot)`` pair — the namespace-uniqueness
    invariant the property suite holds.

    Intra-device operations (alloc/share/cow/demote/free) delegate to the
    owning table unchanged, keeping all its refcount/CoW/twin semantics.
    ``migrate_slot`` is the new first-class tier transition: a slot's pages
    move to a slot on another device, hot pages crossing the device↔device
    edge, cold pages re-homing *inside* host memory (their bytes never touch
    a device link).  A shared page's mover pays a full private copy on the
    destination — the source physical page lives on for its remaining
    sharers (refcounts preserved, CoW memos cleaned by ``_release``) — so
    after migration every migrated page is exclusive.

    Byte conservation: every migrated page's payload is attributed to
    exactly one ledger entry — ``edge_bytes[(src_dev, dst_dev)]`` for hot
    pages, ``host_internal_bytes`` for cold — and the per-device
    ``bytes_out``/``bytes_in`` ledgers must always equal the edge sums
    (asserted by ``check()``).
    """

    def __init__(self, tables, names=None, page_bytes: float = 1.0):
        if not tables:
            raise ValueError("MeshPageTable needs at least one PageTable")
        self.tables = list(tables)
        self.names = list(names) if names is not None else \
            [f"dev{d}" for d in range(len(self.tables))]
        if len(self.names) != len(self.tables):
            raise ValueError(f"{len(self.tables)} tables but "
                             f"{len(self.names)} names")
        if len(set(self.names)) != len(self.names):
            raise ValueError(f"duplicate device names: {self.names}")
        pts = {t.page_tokens for t in self.tables}
        if len(pts) != 1:
            raise ValueError(f"tables disagree on page_tokens: {pts}")
        self.page_tokens = pts.pop()
        self.page_bytes = float(page_bytes)
        self.offsets = [0]
        for t in self.tables:
            self.offsets.append(self.offsets[-1] + t.slots)
        self.edge_bytes: Dict[tuple, float] = {}
        self.host_internal_bytes = 0.0
        self.bytes_out = {n: 0.0 for n in self.names}
        self.bytes_in = {n: 0.0 for n in self.names}

    # ------------------------------------------------------ the namespace --
    @property
    def num_devices(self) -> int:
        return len(self.tables)

    @property
    def slots(self) -> int:
        """Global logical slots across the mesh."""
        return self.offsets[-1]

    def gslot(self, dev: int, slot: int) -> int:
        if not 0 <= slot < self.tables[dev].slots:
            raise ValueError(f"device {dev}: no slot {slot}")
        return self.offsets[dev] + slot

    def owner(self, gslot: int) -> tuple:
        """The unique ``(device, local_slot)`` a global slot names."""
        if not 0 <= gslot < self.slots:
            raise ValueError(f"global slot {gslot} outside [0, {self.slots})")
        for d in range(len(self.tables)):
            if gslot < self.offsets[d + 1]:
                return d, gslot - self.offsets[d]
        raise AssertionError("unreachable")

    def _at(self, gslot: int):
        d, s = self.owner(gslot)
        return self.tables[d], d, s

    # ------------------------------------------- delegated intra-device ops --
    def n_pages(self, gslot: int) -> int:
        t, _, s = self._at(gslot)
        return t.n_pages[s]

    def cold_pages(self, gslot: int) -> int:
        t, _, s = self._at(gslot)
        return t.cold_pages(s)

    def refcount(self, gslot: int, page_idx: int) -> int:
        t, _, s = self._at(gslot)
        return t.refcount(s, page_idx)

    def alloc(self, gslot: int, tier: int) -> int:
        t, _, s = self._at(gslot)
        return t.alloc(s, tier)

    def share(self, dst: int, src: int, n: int) -> int:
        """Prefix sharing — intra-device only: a shared physical page can
        only be mapped by slots on the device whose pool holds it."""
        td, dd, sd = self._at(dst)
        ts, ds, ss = self._at(src)
        if dd != ds:
            raise ValueError(
                f"share across devices ({self.names[ds]} -> "
                f"{self.names[dd]}): physical pages cannot alias across "
                "HBMs — migrate_slot copies instead")
        return td.share(sd, ss, n)

    def cow(self, gslot: int, page_idx: int):
        t, _, s = self._at(gslot)
        return t.cow(s, page_idx)

    def demote(self, gslot: int, page_idx: int) -> tuple:
        t, _, s = self._at(gslot)
        return t.demote(s, page_idx)

    def free_slot(self, gslot: int) -> int:
        t, _, s = self._at(gslot)
        return t.free_slot(s)

    # -------------------------------------------- the cross-device transition --
    def migrate_slot(self, src: int, dst: int) -> dict:
        """Move every page of global slot ``src`` to global slot ``dst`` on
        another device, appending after ``dst``'s existing pages (a shared
        prefix admitted on the destination stays put; only the private tail
        crosses).  Tiers are preserved per page.  Validates capacity and the
        destination's cold-prefix invariant up front, so it either moves the
        whole slot or raises without mutating.  Returns the accounting
        summary ``{"pages", "hot_bytes", "cold_bytes"}``."""
        st, sd, ss = self._at(src)
        dt, dd, ds = self._at(dst)
        if sd == dd:
            raise ValueError(f"migrate_slot within device "
                             f"{self.names[sd]}: use share/splice instead")
        n = st.n_pages[ss]
        n_cold = st.cold_pages(ss)
        n_hot = n - n_cold
        if dt.n_pages[ds] + n > dt.pages_per_slot:
            raise ValueError(f"dst slot {ds} on {self.names[dd]}: "
                             f"{dt.n_pages[ds]}+{n} pages exceed "
                             f"pages_per_slot {dt.pages_per_slot}")
        if n_cold and dt.n_pages[ds] > dt.cold_pages(ds):
            raise ValueError(f"dst slot {ds} on {self.names[dd]}: cold "
                             "pages would land above its hot pages")
        if len(dt.hot_free) < n_hot or len(dt.cold_free) < n_cold:
            raise ValueError(f"{self.names[dd]}: pool exhausted "
                             f"(need {n_hot} hot / {n_cold} cold)")
        for i in range(n):
            tier = st.tier[ss][i]
            dt.alloc(ds, tier)     # the caller copies pool data per page
            if tier == 0:
                self.edge_bytes[(self.names[sd], self.names[dd])] = \
                    self.edge_bytes.get(
                        (self.names[sd], self.names[dd]), 0.0) \
                    + self.page_bytes
                self.bytes_out[self.names[sd]] += self.page_bytes
                self.bytes_in[self.names[dd]] += self.page_bytes
            else:
                # cold pools are regions of the one host memory: re-homing
                # copies inside it, no device link is touched
                self.host_internal_bytes += self.page_bytes
        st.free_slot(ss)
        return {"pages": n,
                "hot_bytes": n_hot * self.page_bytes,
                "cold_bytes": n_cold * self.page_bytes}

    # ----------------------------------------------------------- invariants --
    def pages_in_use(self) -> int:
        return sum(t.pages_in_use() for t in self.tables)

    def check(self) -> None:
        """Per-device structural invariants plus the mesh ledgers: every
        byte that left a device landed on exactly one edge."""
        for t in self.tables:
            t.check()
        out_sum = {n: 0.0 for n in self.names}
        in_sum = {n: 0.0 for n in self.names}
        for (s, d), b in self.edge_bytes.items():
            assert s in out_sum and d in in_sum, f"edge {(s, d)} names an " \
                f"unknown device"
            assert b >= 0, f"edge {(s, d)}: negative bytes"
            out_sum[s] += b
            in_sum[d] += b
        for name in self.names:
            assert out_sum[name] == self.bytes_out[name], \
                f"{name}: {self.bytes_out[name]} bytes departed but " \
                f"{out_sum[name]} attributed to edges"
            assert in_sum[name] == self.bytes_in[name], \
                f"{name}: {self.bytes_in[name]} bytes arrived but " \
                f"{in_sum[name]} attributed to edges"
        assert self.host_internal_bytes >= 0


def copy_slot_rows(dst_tree, src_tree, slot: int, lo: int, hi: int,
                   max_seq: int):
    """dst[slot, lo:hi] = src[slot, lo:hi] on every seq-dim leaf; None and
    non-seq leaves pass through.  Both trees are full-size batched caches in
    the init_cache layout (batch-axis position decided by structure, as in
    splice_slot).  This is the per-slot page demotion / re-host primitive:
    only the named slot's rows move, nothing else is touched.  The seq-leaf
    test runs on ``src`` (always a full ``max_seq`` cache), so ``dst`` may be
    a cold *slice* whose seq dim is shorter — rows [lo, hi) must be valid in
    both."""
    def one(stacked):
        def f(dst, src):
            if dst is None or src is None or not _is_seq_leaf(src, max_seq):
                return dst
            if stacked:                                   # (P, B, S, H)
                return dst.at[:, slot, lo:hi].set(src[:, slot, lo:hi])
            return dst.at[slot, lo:hi].set(src[slot, lo:hi])
        return f

    none_leaf = lambda x: x is None
    assert isinstance(dst_tree, dict) and set(dst_tree) == {"prologue",
                                                            "slots"}
    return {"prologue": jax.tree.map(one(False), dst_tree["prologue"],
                                     src_tree["prologue"], is_leaf=none_leaf),
            "slots": jax.tree.map(one(True), dst_tree["slots"],
                                  src_tree["slots"], is_leaf=none_leaf)}


@dataclass
class PagedTieredCache:
    """Cache with per-slot cold boundaries at page granularity.

    ``hot`` is the full-size device tree (the working copy every decode step
    writes into); ``cold`` holds host-resident copies of each slot's rows
    below its boundary.  ``boundaries[b]`` is slot b's cold-token count,
    always a multiple of ``page_tokens`` and monotone within one residency.
    """
    cold: Any
    hot: Any
    boundaries: Any               # (B,) int32 cold tokens per slot
    page_tokens: int
    max_seq: int
    # host-side mirror of ``boundaries``: updated incrementally on admit /
    # demote so per-step planning never round-trips the device array
    host_boundaries: Optional[list] = None

    def merged(self):
        """Masked where-merge: rows below each slot's boundary read the cold
        (host) copy — inside jit this read IS the streamed cold-KV fetch —
        rows above it read the hot tree.  Bit-identical to the dense cache."""
        b = jnp.asarray(self.boundaries, jnp.int32)
        pos = jnp.arange(self.max_seq)

        def one(stacked):
            def f(c, h):
                if c is None or not _is_seq_leaf(h, self.max_seq):
                    return h
                mask = pos[None, :, None] < b[:, None, None]   # (B, S, 1)
                if stacked:
                    mask = mask[None]                          # (1, B, S, 1)
                return jnp.where(mask, c, h)
            return f

        none_leaf = lambda x: x is None
        return {"prologue": jax.tree.map(one(False), self.cold["prologue"],
                                         self.hot["prologue"],
                                         is_leaf=none_leaf),
                "slots": jax.tree.map(one(True), self.cold["slots"],
                                      self.hot["slots"], is_leaf=none_leaf)}

    def set_boundary(self, slot: int, cold_tokens: int):
        assert cold_tokens % self.page_tokens == 0
        if self.host_boundaries is None:
            self.host_boundaries = [0] * len(jnp.asarray(self.boundaries))
        self.host_boundaries[slot] = int(cold_tokens)
        self.boundaries = jnp.asarray(self.boundaries).at[slot].set(
            cold_tokens)

    def demote_rows(self, slot: int, new_cold_tokens: int):
        """Advance slot's boundary: copy rows [old, new) from hot into the
        host-resident cold tree — only this slot's pages move.  The old
        boundary comes from the host-side mirror (no device round-trip)."""
        old = self.host_boundaries[slot] if self.host_boundaries is not None \
            else int(jnp.asarray(self.boundaries)[slot])
        if new_cold_tokens <= old:
            return 0
        self.cold = to_host(copy_slot_rows(self.cold, self.hot, slot, old,
                                           new_cold_tokens, self.max_seq))
        self.set_boundary(slot, new_cold_tokens)
        return new_cold_tokens - old


def init_paged_cache(cfg, batch: int, max_seq: int, page_tokens: int,
                     dtype=jnp.bfloat16) -> PagedTieredCache:
    """Paged tier-aware construction: boundaries start at zero (everything
    hot); the cold tree mirrors the seq-leaf structure in host memory."""
    assert max_seq % page_tokens == 0, (max_seq, page_tokens)
    hot = init_cache(cfg, batch, max_seq, dtype)
    cold = jax.tree.map(
        lambda l: l if _is_seq_leaf(l, max_seq) else None, hot)
    return PagedTieredCache(to_host(cold), hot,
                            jnp.zeros((batch,), jnp.int32), page_tokens,
                            max_seq, host_boundaries=[0] * batch)


# ------------------------------------------------- persistent pools (serve) --

ATTN_KINDS = (ATTN, LOCAL, SHARED_ATTN)


class PagedKVPools:
    """Persistent physical KV page pools — the storage paged decode consumes.

    This inverts the ownership between logical caches and physical memory:
    instead of a dense per-slot cache that gets re-packed into pools every
    step, the pools ARE the cache.  Per attention layer the storage is

      k_hot / v_hot    (n_hot+1, page, KV*hd)   device memory (HBM)
      v_cold / k_cold  (n_cold,  page, KV*hd)   host memory

    addressed through one layer-independent :class:`PageTable`.  Decode
    *writes into the pools* via the table (models/attention.py resolves each
    slot's write position to a physical hot page); admit / demote / free are
    incremental per-page deltas; ``as_arrays`` uploads of the table happen
    only when the table's ``version`` changes.  Non-attention layer caches
    (stateful kinds, MLA) keep their dense batched form inside ``tree``.

    The extra hot page at index ``garbage`` (= n_hot) absorbs the lockstep
    writes of inactive batch slots, so a finished slot can never scribble
    over a physical page a live slot still references.

    ``stats`` counts the events the steady-state acceptance test pins to
    zero: ``repacks`` (dense->pool re-packs — never in this design),
    ``table_uploads`` (layout deltas), ``page_copies`` (demote/CoW data
    movement), ``admit_page_writes`` (prefill landing in the pools).
    """

    def __init__(self, cfg, slots: int, max_seq: int, page_tokens: int,
                 dtype=jnp.bfloat16, hot_pages: Optional[int] = None,
                 cold_pages: Optional[int] = None, device=None):
        assert max_seq % page_tokens == 0, (max_seq, page_tokens)
        # the device whose host path cold pages ride (None = device 0): a
        # sharded engine gives each shard's pool its own device so demotes
        # never scatter across committed devices
        self.device = device
        self.cfg, self.num_slots = cfg, slots
        self.max_seq, self.page_tokens = max_seq, page_tokens
        self.num_pages = max_seq // page_tokens
        self.table = PageTable(slots, self.num_pages, page_tokens,
                               hot_pages, cold_pages)
        self.garbage = self.table.n_hot          # scratch page, never mapped
        self.tree = self._init_tree(dtype)
        self._cached_arrays = None
        self._cached_version = -1
        self.stats = {"repacks": 0, "table_uploads": 0, "page_copies": 0,
                      "admit_page_writes": 0}
        self.peak_pages = 0

    # --------------------------------------------------------- construction --
    def _pool_layer(self, kind: str, dtype):
        cfg = self.cfg
        if kind in ATTN_KINDS:
            D = cfg.num_kv_heads * cfg.head_dim
            hot = jnp.zeros((self.table.n_hot + 1, self.page_tokens, D), dtype)
            cold = jnp.zeros((self.table.n_cold, self.page_tokens, D), dtype)
            return {"k_hot": hot, "v_hot": hot,
                    "k_cold": cold, "v_cold": cold}
        return init_layer_cache(cfg, kind, self.num_slots, self.max_seq, dtype)

    def _init_tree(self, dtype):
        cfg = self.cfg

        def host_cold(entry, kind):
            if kind in ATTN_KINDS:
                entry["k_cold"] = to_host(entry["k_cold"], self.device)
                entry["v_cold"] = to_host(entry["v_cold"], self.device)
            return entry

        pro = [host_cold(self._pool_layer(k, dtype), k) for k in cfg.prologue]

        def stacked(kind):
            one = self._pool_layer(kind, dtype)
            d = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (cfg.num_periods,) + a.shape).copy()
                if cfg.num_periods > 1 else a[None], one)
            return host_cold(d, kind)

        return {"prologue": pro, "slots": [stacked(k) for k in cfg.period]}

    def _attn_entries(self, *others):
        """Yield (stacked, pool_entry[, other_entry...]) per attention layer."""
        for i, kind in enumerate(self.cfg.prologue):
            if kind in ATTN_KINDS:
                yield (False, self.tree["prologue"][i],
                       *(o["prologue"][i] for o in others))
        for s, kind in enumerate(self.cfg.period):
            if kind in ATTN_KINDS:
                yield (True, self.tree["slots"][s],
                       *(o["slots"][s] for o in others))

    def _note(self):
        self.peak_pages = max(self.peak_pages, self.table.pages_in_use())

    # -------------------------------------------------------------- layout --
    def arrays(self):
        """(page_table, page_tier) device arrays, re-uploaded only when the
        PageTable mutated since the last call (incremental layout deltas)."""
        if self._cached_version != self.table.version:
            self._cached_arrays = self.table.as_arrays()
            self._cached_version = self.table.version
            self.stats["table_uploads"] += 1
        return self._cached_arrays

    def paged_view(self, active_mask) -> Dict[str, Any]:
        """The per-step view models/attention.py consumes.  Everything in it
        is either cached (table/tier arrays, active mask) or a python
        constant — building it costs no transfers in steady state."""
        table_arr, tier_arr = self.arrays()
        return {"page_table": table_arr, "page_tier": tier_arr,
                "page_tokens": self.page_tokens, "active": active_mask,
                "garbage_page": self.garbage}

    # ----------------------------------------------------------- mutations --
    def free_slot(self, slot: int) -> int:
        n = self.table.free_slot(slot)
        self._note()
        return n

    def share(self, dst: int, src: int, n: int) -> int:
        n = self.table.share(dst, src, n)
        self._note()
        return n

    def ensure_write_page(self, slot: int, pos: int) -> None:
        """Pre-step guarantee for the decode write at token ``pos``: the page
        holding ``pos`` exists and is private (CoW on a shared page — the
        first divergent write past a shared-prefix fork point)."""
        while self.table.n_pages[slot] * self.page_tokens < pos + 1:
            self.table.alloc(slot, 0)
        self._note()
        self.cow_for_write(slot, pos)

    def cow_for_write(self, slot: int, pos: int) -> bool:
        """Copy-on-write before a divergent write at token ``pos``; no-op on
        exclusive pages.  Returns True when a private copy was made."""
        idx = pos // self.page_tokens
        if idx >= self.table.n_pages[slot]:
            return False
        r = self.table.cow(slot, idx)
        if r is None:
            return False
        src, new, tier = r
        self._note()
        kk, vv = ("k_cold", "v_cold") if tier == 1 else ("k_hot", "v_hot")
        for entry in self._attn_entries():
            stacked, pool = entry[0], entry[1]
            if stacked:
                k2 = pool[kk].at[:, new].set(pool[kk][:, src])
                v2 = pool[vv].at[:, new].set(pool[vv][:, src])
            else:
                k2 = pool[kk].at[new].set(pool[kk][src])
                v2 = pool[vv].at[new].set(pool[vv][src])
            if tier == 1:
                k2, v2 = to_host(k2, self.device), to_host(v2, self.device)
            pool[kk], pool[vv] = k2, v2
        self.stats["page_copies"] += 1
        return True

    def admit_rows(self, fresh, slot: int, pages) -> None:
        """Write whole pages of a batch-1 prefilled dense cache into the
        slot's private hot pages.  Shared pages are skipped by the caller —
        their physical pages already hold bit-identical data."""
        pages = list(pages)
        if not pages:
            return
        assert all(self.table.tier[slot][i] == 0 for i in pages), \
            "admit writes land in the hot pool"
        phys = [self.table.table[slot][i] for i in pages]
        pg = self.page_tokens
        for entry in self._attn_entries(fresh):
            stacked, pool, fr = entry
            kh, vh = pool["k_hot"], pool["v_hot"]
            for i, ph in zip(pages, phys):
                lo = i * pg
                if stacked:                  # pool (P,n,pg,D), fresh (P,1,S,D)
                    kh = kh.at[:, ph].set(fr["k"][:, 0, lo:lo + pg])
                    vh = vh.at[:, ph].set(fr["v"][:, 0, lo:lo + pg])
                else:                        # pool (n,pg,D),   fresh (1,S,D)
                    kh = kh.at[ph].set(fr["k"][0, lo:lo + pg])
                    vh = vh.at[ph].set(fr["v"][0, lo:lo + pg])
            pool["k_hot"], pool["v_hot"] = kh, vh
        self.stats["admit_page_writes"] += len(pages)

    def splice_other(self, fresh, slot: int) -> None:
        """Row-splice the non-attention layer caches (stateful kinds, MLA) of
        a fresh batch-1 cache into the pool tree — same semantics as
        ``splice_slot`` on the dense layout."""
        def one(stacked):
            def f(big, small):
                if big is None:
                    return None
                if stacked:
                    return big.at[:, slot].set(small[:, 0])
                return big.at[slot].set(small[0])
            return f

        for i, kind in enumerate(self.cfg.prologue):
            if kind not in ATTN_KINDS:
                self.tree["prologue"][i] = jax.tree.map(
                    one(False), self.tree["prologue"][i],
                    fresh["prologue"][i])
        for s, kind in enumerate(self.cfg.period):
            if kind not in ATTN_KINDS:
                self.tree["slots"][s] = jax.tree.map(
                    one(True), self.tree["slots"][s], fresh["slots"][s])

    def demote_boundary(self, slot: int) -> bool:
        """Advance the slot's cold boundary one page.  Pool data moves
        hot->cold only when the PageTable allocated a fresh cold copy
        (exclusive page, or the first sharer to demote) — twin reuse by
        later sharers moves zero bytes, which is how shared pages' migration
        bytes are counted exactly once.  Returns whether data was copied."""
        idx = self.table.cold_pages(slot)
        cold_phys, src, copied = self.table.demote(slot, idx)
        self._note()
        if copied:
            for entry in self._attn_entries():
                stacked, pool = entry
                if stacked:
                    kc = pool["k_cold"].at[:, cold_phys].set(
                        pool["k_hot"][:, src])
                    vc = pool["v_cold"].at[:, cold_phys].set(
                        pool["v_hot"][:, src])
                else:
                    kc = pool["k_cold"].at[cold_phys].set(pool["k_hot"][src])
                    vc = pool["v_cold"].at[cold_phys].set(pool["v_hot"][src])
                pool["k_cold"], pool["v_cold"] = \
                    to_host(kc, self.device), to_host(vc, self.device)
            self.stats["page_copies"] += 1
        return copied


def cache_logical_axes(cfg) -> Dict[str, Any]:
    """Logical sharding axes for every cache leaf (mirrors init_cache)."""
    def axes_layer(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return {"k": ("batch", "kv_seq", "kv_heads"),
                    "v": ("batch", "kv_seq", "kv_heads")}
        if kind == MLA:
            return {"ckv": ("batch", "kv_seq", "kv_latent"),
                    "krope": ("batch", "kv_seq", None)}
        if kind == MAMBA:
            return {"h": ("batch", "ssm_heads", None, None),
                    "conv": ("batch", None, "ssm_heads")}
        if kind == MLSTM:
            return {"state": (("batch", "heads", None, None),
                              ("batch", "heads", None),
                              ("batch", "heads")),
                    "conv": ("batch", None, "mlp")}
        if kind == SLSTM:
            return {"state": (("batch", "heads", None),) * 2 +
                             (("batch", "heads", None),) * 2,
                    "conv": ("batch", None, "mlp")}
        if kind == LSTM:
            return {"h": ("batch", "embed"), "c": ("batch", "embed")}
        raise ValueError(kind)

    from repro.sharding import is_axes_leaf
    pro = [axes_layer(k) for k in cfg.prologue]
    slots = [jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_layer(k),
                          is_leaf=is_axes_leaf)
             for k in cfg.period]
    return {"prologue": pro, "slots": slots}
