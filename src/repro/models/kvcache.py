"""Cache construction for serving: per-layer-kind cache buffers, stacked over
periods to match the scanned layer stack.

Tier-aware construction (Sentinel-Serve): a cache can be split along the KV
sequence dimension into a *cold prefix* (old tokens, host/slow memory) and a
*hot window* (recent tokens, HBM/fast memory), per the decode-phase
``ServePlan``.  On TPU the cold prefix lives in ``pinned_host`` and streams
over PCIe at read time; on CPU (this repo's CI) the only memory kind is the
host itself, so placement degrades to an explicit no-op while the splice and
merge mechanics stay identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, LSTM, MAMBA, MLA, MLSTM, SHARED_ATTN, SLSTM
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def init_layer_cache(cfg, kind: str, batch: int, max_seq: int, dtype):
    if kind in (ATTN, LOCAL, SHARED_ATTN):
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        # KV heads folded into one dim so odd head counts (5, 15...) still
        # shard over the model axis. Sliding-window layers only ever read the
        # last `window` entries, but we keep the full buffer for uniform
        # indexing (baseline; see §Perf for the windowed-cache optimization).
        return {"k": jnp.zeros((batch, max_seq, KV * hd), dtype),
                "v": jnp.zeros((batch, max_seq, KV * hd), dtype)}
    if kind == MLA:
        return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}
    if kind == MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    if kind == LSTM:
        return xlstm_mod.init_lstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Cache pytree matching stack_forward's expectations: prologue caches are
    per-layer; slot caches carry a leading (num_periods,) dim."""
    pro = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
           for kind in cfg.prologue]

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, max_seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy()
            if cfg.num_periods > 1 else a[None], one)

    return {"prologue": pro, "slots": [stacked(k) for k in cfg.period]}


# ------------------------------------------------------- tiered (serve) ----

HOST_MEMORY_KINDS = ("pinned_host", "unpinned_host")


def host_memory_kind() -> Optional[str]:
    """First host-side memory kind the default device exposes, or None.
    TPU: 'pinned_host'.  CPU: 'unpinned_host' (which is also its default —
    host offload is then an explicit no-op, keeping the code path uniform)."""
    dev = jax.devices()[0]
    try:
        kinds = {m.kind for m in dev.addressable_memories()}
    except Exception:
        return None
    for k in HOST_MEMORY_KINDS:
        if k in kinds:
            return k
    return None


def to_host(tree):
    """Place every array leaf in host memory (async copy; XLA overlaps it with
    whatever is executing — the migration channel).  Identity when the backend
    exposes no host memory kind."""
    kind = host_memory_kind()
    if kind is None:
        return tree
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)


def to_device(tree):
    """Bring host-resident leaves back to the device's default memory."""
    dev = jax.devices()[0]
    return jax.tree.map(lambda a: jax.device_put(a, dev), tree)


def kv_token_bytes(cfg, dtype_bytes: int = 2) -> float:
    """Mean KV-cache bytes per token per layer, averaged over ALL layer kinds
    (stateful kinds hold O(1) state and contribute zero), so that
    ``kv_token_bytes(cfg) * cfg.num_layers`` is the model's true per-token KV
    growth.  Feeds the serve-trace model and the decode-phase planner."""
    def one(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return 2 * cfg.num_kv_heads * cfg.head_dim * dtype_bytes
        if kind == MLA:
            return (cfg.kv_lora_rank + cfg.qk_rope_dim) * dtype_bytes
        return 0.0                    # stateful kinds: O(1) state, no KV growth
    total = sum(one(k) for k in cfg.prologue) + \
        cfg.num_periods * sum(one(k) for k in cfg.period)
    return total / cfg.num_layers if cfg.num_layers else 0.0


def _is_seq_leaf(leaf, max_seq: int) -> bool:
    # KV buffers carry the sequence at axis -2: (B, S, H), (P, B, S, H),
    # (B, S, rank).  Stateful caches (mamba/lstm conv+state) never match as
    # long as no state dim equals max_seq — hold for every non-trivial
    # max_seq in this repo.
    return leaf.ndim >= 3 and leaf.shape[-2] == max_seq


def split_seq_cache(caches, max_seq: int, cold_len: int):
    """Split every seq-dim leaf at ``cold_len``: (cold_prefix, hot_window).
    Non-seq leaves stay whole in the hot tree; their cold slot is None."""
    cold = jax.tree.map(
        lambda l: l[..., :cold_len, :] if _is_seq_leaf(l, max_seq) else None,
        caches)
    hot = jax.tree.map(
        lambda l: l[..., cold_len:, :] if _is_seq_leaf(l, max_seq) else l,
        caches)
    return cold, hot


def merge_seq_cache(cold, hot):
    """Inverse of split_seq_cache.  Inside jit, the concatenate reading a
    host-resident cold leaf is exactly the streamed cold-KV fetch."""
    return jax.tree.map(
        lambda c, h: h if c is None else jnp.concatenate([c, h], axis=-2),
        cold, hot, is_leaf=lambda x: x is None)


def splice_slot(big_tree, one_tree, slot: int, batch: int):
    """Write a single-request cache (batch 1) into row ``slot`` of a batched
    cache — the continuous-batching cache splice.  Works on full, cold, and
    hot trees alike (None leaves pass through).  Dispatch is async: the copy
    overlaps with whatever decode work is already enqueued.

    Batch-axis position is decided by cache *structure*, not leaf shapes:
    ``slots`` subtree leaves carry a leading (num_periods,) dim (batch at
    axis 1), ``prologue`` leaves have batch at axis 0 — shape heuristics
    would silently mis-splice when a sliced sequence length collides with
    the slot count."""
    def one_leaf(stacked):
        def f(big, one):
            if big is None:
                return None
            if stacked:                                  # (P, B, ...)
                return big.at[:, slot].set(one[:, 0])
            return big.at[slot].set(one[0])              # (B, ...)
        return f

    none_leaf = lambda x: x is None
    if isinstance(big_tree, dict) and \
            set(big_tree) == {"prologue", "slots"}:      # init_cache layout
        return {"prologue": jax.tree.map(one_leaf(False),
                                         big_tree["prologue"],
                                         one_tree["prologue"],
                                         is_leaf=none_leaf),
                "slots": jax.tree.map(one_leaf(True), big_tree["slots"],
                                      one_tree["slots"], is_leaf=none_leaf)}
    # generic tree: fall back to the shape heuristic
    def guess(big, one):
        if big is None:
            return None
        stacked = big.ndim >= 2 and big.shape[1] == batch
        return one_leaf(stacked)(big, one)
    return jax.tree.map(guess, big_tree, one_tree, is_leaf=none_leaf)


@dataclass
class TieredCache:
    """A cache split into a host-resident cold prefix and a fast hot window."""
    cold: Any
    hot: Any
    cold_len: int
    max_seq: int

    def merged(self):
        return merge_seq_cache(self.cold, self.hot)


def init_tiered_cache(cfg, batch: int, max_seq: int, cold_len: int,
                      dtype=jnp.bfloat16) -> TieredCache:
    """Tier-aware cache construction: the cold KV prefix is placed in host
    memory, the hot window (and all stateful caches) stay in device memory."""
    cold_len = max(0, min(int(cold_len), max_seq))
    full = init_cache(cfg, batch, max_seq, dtype)
    cold, hot = split_seq_cache(full, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


def retier(caches, max_seq: int, cold_len: int) -> TieredCache:
    """Split an existing full cache (e.g. fresh from prefill) into tiers."""
    cold, hot = split_seq_cache(caches, max_seq, cold_len)
    return TieredCache(to_host(cold), hot, cold_len, max_seq)


# ------------------------------------------------------- paged (serve) ----
#
# Per-slot cold boundaries need a representation that splits the KV sequence
# at a *different* point per batch row, which a single slice cannot express.
# Two pieces:
#
#   PageTable        the metadata manager: logical (slot, page) -> physical
#                    page in the hot or cold pool, with alloc/free/splice at
#                    page granularity and the cold-prefix invariant (a slot's
#                    cold pages are always a prefix of its logical pages).
#                    This is the layout kernels/paged_decode.py consumes.
#   PagedTieredCache the pytree storage consumed by the jnp model path on
#                    CPU: full-size hot (device) and cold (host) trees with a
#                    per-slot boundary vector; ``merged()`` is a masked
#                    where-merge that reads cold rows below each slot's
#                    boundary and hot rows above it — bit-identical to the
#                    dense cache because every row was copied from the dense
#                    values when it changed tier.
#
# On TPU the PageTable's pools are the real storage and the paged kernel
# streams cold pages over PCIe; on CPU (CI) the two-buffer masked form is the
# placement simulation, with migration bytes tracked by the serving engine.


class PageTable:
    """Slot-local logical->physical page mapping over two physical pools.

    Pages are ``page_tokens`` tokens of KV.  Each slot owns an ordered list
    of logical pages; page i lives either in the hot pool (tier 0) or the
    cold pool (tier 1).  Invariant: the cold pages of a slot form a prefix of
    its logical pages (the cold *boundary*), and within one residency a
    slot's boundary only moves forward — pages are demoted hot->cold as the
    hot window slides, never resurrected until the slot is refilled.
    """

    FREE = -1

    def __init__(self, slots: int, pages_per_slot: int, page_tokens: int,
                 hot_pages: Optional[int] = None,
                 cold_pages: Optional[int] = None):
        self.slots, self.pages_per_slot = slots, pages_per_slot
        self.page_tokens = page_tokens
        n = slots * pages_per_slot
        self.hot_free = list(range((hot_pages or n) - 1, -1, -1))
        self.cold_free = list(range((cold_pages or n) - 1, -1, -1))
        self.table = [[self.FREE] * pages_per_slot for _ in range(slots)]
        self.tier = [[self.FREE] * pages_per_slot for _ in range(slots)]
        self.n_pages = [0] * slots

    # ------------------------------------------------------------ queries --
    def cold_pages(self, slot: int) -> int:
        """Pages below the slot's cold boundary."""
        t = self.tier[slot]
        n = 0
        while n < self.n_pages[slot] and t[n] == 1:
            n += 1
        return n

    def cold_tokens(self, slot: int) -> int:
        return self.cold_pages(slot) * self.page_tokens

    def as_arrays(self):
        """(page_table, page_tier) int32 arrays for kernels/paged_decode.py."""
        return (jnp.asarray(self.table, jnp.int32),
                jnp.asarray(self.tier, jnp.int32))

    # ---------------------------------------------------------- mutations --
    def alloc(self, slot: int, tier: int) -> int:
        """Append one logical page to ``slot`` in the given tier; returns the
        physical page id.  Raises when the slot or the pool is exhausted."""
        i = self.n_pages[slot]
        if i >= self.pages_per_slot:
            raise ValueError(f"slot {slot}: pages_per_slot exhausted")
        if tier == 1 and i != self.cold_pages(slot):
            raise ValueError(f"slot {slot}: cold alloc would break the "
                             "cold-prefix invariant")
        pool = self.cold_free if tier == 1 else self.hot_free
        if not pool:
            raise ValueError(f"{'cold' if tier else 'hot'} pool exhausted")
        phys = pool.pop()
        self.table[slot][i] = phys
        self.tier[slot][i] = tier
        self.n_pages[slot] = i + 1
        return phys

    def free_slot(self, slot: int) -> int:
        """Release every page of ``slot`` back to its pool (slot refill /
        request completion).  Returns the number of pages released."""
        n = self.n_pages[slot]
        for i in range(n):
            (self.cold_free if self.tier[slot][i] == 1
             else self.hot_free).append(self.table[slot][i])
            self.table[slot][i] = self.tier[slot][i] = self.FREE
        self.n_pages[slot] = 0
        return n

    def demote(self, slot: int, page_idx: int) -> int:
        """Move one page hot->cold.  Only the page at the cold boundary may
        move (prefix invariant).  Returns the new cold physical id."""
        if page_idx != self.cold_pages(slot):
            raise ValueError(f"slot {slot}: demote({page_idx}) is not the "
                             f"cold boundary {self.cold_pages(slot)}")
        if page_idx >= self.n_pages[slot]:
            raise ValueError(f"slot {slot}: page {page_idx} not allocated")
        if not self.cold_free:
            raise ValueError("cold pool exhausted")
        self.hot_free.append(self.table[slot][page_idx])
        phys = self.cold_free.pop()
        self.table[slot][page_idx] = phys
        self.tier[slot][page_idx] = 1
        return phys

    def splice_slot(self, slot: int, tokens: int, cold_tokens: int) -> int:
        """Refill ``slot`` with a fresh request: free its pages, then allocate
        ceil(tokens/page) pages with the first ``cold_tokens`` worth cold.
        Returns the number of cold pages allocated."""
        self.free_slot(slot)
        n = -(-tokens // self.page_tokens) if tokens else 0
        n_cold = min(n, cold_tokens // self.page_tokens)
        for i in range(n):
            self.alloc(slot, 1 if i < n_cold else 0)
        return n_cold

    def check(self) -> None:
        """Assert structural invariants (used by the property tests)."""
        for tier, pool in ((0, self.hot_free), (1, self.cold_free)):
            used = [self.table[s][i] for s in range(self.slots)
                    for i in range(self.n_pages[s])
                    if self.tier[s][i] == tier]
            assert len(used) == len(set(used)), f"tier {tier}: double alloc"
            assert not (set(used) & set(pool)), f"tier {tier}: used page free"
        for s in range(self.slots):
            n, nc = self.n_pages[s], self.cold_pages(s)
            assert all(self.tier[s][i] == 1 for i in range(nc))
            assert all(self.tier[s][i] == 0 for i in range(nc, n))
            assert all(self.table[s][i] == self.FREE for i in
                       range(n, self.pages_per_slot))


def copy_slot_rows(dst_tree, src_tree, slot: int, lo: int, hi: int,
                   max_seq: int):
    """dst[slot, lo:hi] = src[slot, lo:hi] on every seq-dim leaf; None and
    non-seq leaves pass through.  Both trees are full-size batched caches in
    the init_cache layout (batch-axis position decided by structure, as in
    splice_slot).  This is the per-slot page demotion / re-host primitive:
    only the named slot's rows move, nothing else is touched.  The seq-leaf
    test runs on ``src`` (always a full ``max_seq`` cache), so ``dst`` may be
    a cold *slice* whose seq dim is shorter — rows [lo, hi) must be valid in
    both."""
    def one(stacked):
        def f(dst, src):
            if dst is None or src is None or not _is_seq_leaf(src, max_seq):
                return dst
            if stacked:                                   # (P, B, S, H)
                return dst.at[:, slot, lo:hi].set(src[:, slot, lo:hi])
            return dst.at[slot, lo:hi].set(src[slot, lo:hi])
        return f

    none_leaf = lambda x: x is None
    assert isinstance(dst_tree, dict) and set(dst_tree) == {"prologue",
                                                            "slots"}
    return {"prologue": jax.tree.map(one(False), dst_tree["prologue"],
                                     src_tree["prologue"], is_leaf=none_leaf),
            "slots": jax.tree.map(one(True), dst_tree["slots"],
                                  src_tree["slots"], is_leaf=none_leaf)}


@dataclass
class PagedTieredCache:
    """Cache with per-slot cold boundaries at page granularity.

    ``hot`` is the full-size device tree (the working copy every decode step
    writes into); ``cold`` holds host-resident copies of each slot's rows
    below its boundary.  ``boundaries[b]`` is slot b's cold-token count,
    always a multiple of ``page_tokens`` and monotone within one residency.
    """
    cold: Any
    hot: Any
    boundaries: Any               # (B,) int32 cold tokens per slot
    page_tokens: int
    max_seq: int

    def merged(self):
        """Masked where-merge: rows below each slot's boundary read the cold
        (host) copy — inside jit this read IS the streamed cold-KV fetch —
        rows above it read the hot tree.  Bit-identical to the dense cache."""
        b = jnp.asarray(self.boundaries, jnp.int32)
        pos = jnp.arange(self.max_seq)

        def one(stacked):
            def f(c, h):
                if c is None or not _is_seq_leaf(h, self.max_seq):
                    return h
                mask = pos[None, :, None] < b[:, None, None]   # (B, S, 1)
                if stacked:
                    mask = mask[None]                          # (1, B, S, 1)
                return jnp.where(mask, c, h)
            return f

        none_leaf = lambda x: x is None
        return {"prologue": jax.tree.map(one(False), self.cold["prologue"],
                                         self.hot["prologue"],
                                         is_leaf=none_leaf),
                "slots": jax.tree.map(one(True), self.cold["slots"],
                                      self.hot["slots"], is_leaf=none_leaf)}

    def set_boundary(self, slot: int, cold_tokens: int):
        assert cold_tokens % self.page_tokens == 0
        self.boundaries = jnp.asarray(self.boundaries).at[slot].set(
            cold_tokens)

    def demote_rows(self, slot: int, new_cold_tokens: int):
        """Advance slot's boundary: copy rows [old, new) from hot into the
        host-resident cold tree — only this slot's pages move."""
        old = int(jnp.asarray(self.boundaries)[slot])
        if new_cold_tokens <= old:
            return 0
        self.cold = to_host(copy_slot_rows(self.cold, self.hot, slot, old,
                                           new_cold_tokens, self.max_seq))
        self.set_boundary(slot, new_cold_tokens)
        return new_cold_tokens - old


def init_paged_cache(cfg, batch: int, max_seq: int, page_tokens: int,
                     dtype=jnp.bfloat16) -> PagedTieredCache:
    """Paged tier-aware construction: boundaries start at zero (everything
    hot); the cold tree mirrors the seq-leaf structure in host memory."""
    assert max_seq % page_tokens == 0, (max_seq, page_tokens)
    hot = init_cache(cfg, batch, max_seq, dtype)
    cold = jax.tree.map(
        lambda l: l if _is_seq_leaf(l, max_seq) else None, hot)
    return PagedTieredCache(to_host(cold), hot,
                            jnp.zeros((batch,), jnp.int32), page_tokens,
                            max_seq)


def cache_logical_axes(cfg) -> Dict[str, Any]:
    """Logical sharding axes for every cache leaf (mirrors init_cache)."""
    def axes_layer(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return {"k": ("batch", "kv_seq", "kv_heads"),
                    "v": ("batch", "kv_seq", "kv_heads")}
        if kind == MLA:
            return {"ckv": ("batch", "kv_seq", "kv_latent"),
                    "krope": ("batch", "kv_seq", None)}
        if kind == MAMBA:
            return {"h": ("batch", "ssm_heads", None, None),
                    "conv": ("batch", None, "ssm_heads")}
        if kind == MLSTM:
            return {"state": (("batch", "heads", None, None),
                              ("batch", "heads", None),
                              ("batch", "heads")),
                    "conv": ("batch", None, "mlp")}
        if kind == SLSTM:
            return {"state": (("batch", "heads", None),) * 2 +
                             (("batch", "heads", None),) * 2,
                    "conv": ("batch", None, "mlp")}
        if kind == LSTM:
            return {"h": ("batch", "embed"), "c": ("batch", "embed")}
        raise ValueError(kind)

    from repro.sharding import is_axes_leaf
    pro = [axes_layer(k) for k in cfg.prologue]
    slots = [jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_layer(k),
                          is_leaf=is_axes_leaf)
             for k in cfg.period]
    return {"prologue": pro, "slots": slots}
