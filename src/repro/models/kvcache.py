"""Cache construction for serving: per-layer-kind cache buffers, stacked over
periods to match the scanned layer stack."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, LSTM, MAMBA, MLA, MLSTM, SHARED_ATTN, SLSTM
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def init_layer_cache(cfg, kind: str, batch: int, max_seq: int, dtype):
    if kind in (ATTN, LOCAL, SHARED_ATTN):
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        # KV heads folded into one dim so odd head counts (5, 15...) still
        # shard over the model axis. Sliding-window layers only ever read the
        # last `window` entries, but we keep the full buffer for uniform
        # indexing (baseline; see §Perf for the windowed-cache optimization).
        return {"k": jnp.zeros((batch, max_seq, KV * hd), dtype),
                "v": jnp.zeros((batch, max_seq, KV * hd), dtype)}
    if kind == MLA:
        return {"ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dtype)}
    if kind == MAMBA:
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    if kind == LSTM:
        return xlstm_mod.init_lstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Cache pytree matching stack_forward's expectations: prologue caches are
    per-layer; slot caches carry a leading (num_periods,) dim."""
    pro = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
           for kind in cfg.prologue]

    def stacked(kind):
        one = init_layer_cache(cfg, kind, batch, max_seq, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape).copy()
            if cfg.num_periods > 1 else a[None], one)

    return {"prologue": pro, "slots": [stacked(k) for k in cfg.period]}


def cache_logical_axes(cfg) -> Dict[str, Any]:
    """Logical sharding axes for every cache leaf (mirrors init_cache)."""
    def axes_layer(kind):
        if kind in (ATTN, LOCAL, SHARED_ATTN):
            return {"k": ("batch", "kv_seq", "kv_heads"),
                    "v": ("batch", "kv_seq", "kv_heads")}
        if kind == MLA:
            return {"ckv": ("batch", "kv_seq", "kv_latent"),
                    "krope": ("batch", "kv_seq", None)}
        if kind == MAMBA:
            return {"h": ("batch", "ssm_heads", None, None),
                    "conv": ("batch", None, "ssm_heads")}
        if kind == MLSTM:
            return {"state": (("batch", "heads", None, None),
                              ("batch", "heads", None),
                              ("batch", "heads")),
                    "conv": ("batch", None, "mlp")}
        if kind == SLSTM:
            return {"state": (("batch", "heads", None),) * 2 +
                             (("batch", "heads", None),) * 2,
                    "conv": ("batch", None, "mlp")}
        if kind == LSTM:
            return {"h": ("batch", "embed"), "c": ("batch", "embed")}
        raise ValueError(kind)

    from repro.sharding import is_axes_leaf
    pro = [axes_layer(k) for k in cfg.prologue]
    slots = [jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_layer(k),
                          is_leaf=is_axes_leaf)
             for k in cfg.period]
    return {"prologue": pro, "slots": slots}
