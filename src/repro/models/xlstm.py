"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory,
true recurrence) — per xLSTM [arXiv:2405.04517]; 7:1 pattern for xlstm-1.3b.

The assigned config has d_ff=0: blocks carry their own up/down projections
(projection factor 2 for mLSTM), no separate MLP.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import Param, dense_init, rmsnorm
from repro.models.ssm import _causal_conv
from repro.sharding import constrain

CONV_K = 4
PROJ = 2          # mLSTM up-projection factor
QK_FACTOR = 2     # qk dim = d_inner // QK_FACTOR (official qk_dim_factor=0.5)


def mlstm_dims(cfg):
    d_inner = PROJ * cfg.d_model
    H = cfg.num_heads
    dv = d_inner // H
    dk = d_inner // QK_FACTOR // H
    return d_inner, H, dk, dv


def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, dk, dv = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, d_inner, ("embed", "mlp"), dtype),
        "w_gate": dense_init(ks[1], d, d_inner, ("embed", "mlp"), dtype),
        "conv_w": Param(jax.random.normal(ks[2], (CONV_K, d_inner), dtype) * 0.5,
                        (None, "mlp")),
        "conv_b": Param(jnp.zeros((d_inner,), dtype), ("mlp",)),
        "wq": dense_init(ks[3], d_inner, H * dk, ("mlp", "heads"), dtype),
        "wk": dense_init(ks[4], d_inner, H * dk, ("mlp", "heads"), dtype),
        "wv": dense_init(ks[5], d_inner, H * dv, ("mlp", "heads"), dtype),
        "w_if": dense_init(ks[6], d_inner, 2 * H, ("mlp", None), dtype),
        "norm": Param(jnp.ones((d_inner,), dtype), ("mlp",)),
        "w_down": dense_init(jax.random.fold_in(key, 7), d_inner, d,
                             ("mlp", "embed"), dtype),
    }


def mlstm_block(params, cfg, x, *, cache: Optional[dict] = None,
                decode: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    d_inner, H, dk, dv = mlstm_dims(cfg)

    xm = x @ params["w_up"]
    z = x @ params["w_gate"]
    tail = cache["conv"] if cache is not None and decode else None
    xc, new_tail = _causal_conv(xm, params["conv_w"], params["conv_b"], tail)

    q = (xc @ params["wq"]).reshape(B, S, H, dk)
    k = (xc @ params["wk"]).reshape(B, S, H, dk)
    v = (xm @ params["wv"]).reshape(B, S, H, dv)
    q = constrain(q, ("batch", "seq", "heads", None))
    gates = (xc @ params["w_if"]).astype(jnp.float32)
    log_i = gates[..., :H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])

    state = cache["state"] if cache is not None else None
    h, new_state = kops.mlstm(q, k, v, log_i, log_f, state=state)
    h = h.reshape(B, S, d_inner)
    h = rmsnorm(h, params["norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"]

    new_cache = None
    if cache is not None or decode:
        new_cache = {"state": new_state, "conv": new_tail}
    return out, new_cache


def init_mlstm_cache(cfg, batch: int, dtype=jnp.float32):
    d_inner, H, dk, dv = mlstm_dims(cfg)
    return {"state": (jnp.zeros((batch, H, dk, dv), jnp.float32),
                      jnp.zeros((batch, H, dk), jnp.float32),
                      jnp.full((batch, H), -jnp.inf, jnp.float32)),
            "conv": jnp.zeros((batch, CONV_K - 1, d_inner), dtype)}


# ----------------------------------------------------------------- sLSTM ----

def slstm_dims(cfg):
    H = cfg.num_heads
    return H, cfg.d_model // H


def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H, D = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "conv_w": Param(jax.random.normal(ks[0], (CONV_K, d), dtype) * 0.5, (None, "mlp")),
        "conv_b": Param(jnp.zeros((d,), dtype), ("mlp",)),
        "w_ifzo": dense_init(ks[1], d, H * 4 * D, ("embed", "heads"), dtype),
        "r_ifzo": Param(jax.random.normal(ks[2], (H, 4, D, D), dtype) * (D ** -0.5),
                        ("heads", None, None, None)),
        "norm": Param(jnp.ones((d,), dtype), ("mlp",)),
        "w_out": dense_init(ks[3], d, d, ("embed", "embed"), dtype),
    }


def slstm_block(params, cfg, x, *, cache: Optional[dict] = None,
                decode: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H, D = slstm_dims(cfg)
    tail = cache["conv"] if cache is not None and decode else None
    xc, new_tail = _causal_conv(x, params["conv_w"], params["conv_b"], tail)
    pre = (xc @ params["w_ifzo"]).reshape(B, S, H, 4, D)
    state = cache["state"] if cache is not None else None
    h, new_state = kops.slstm(pre, state=state, r_ifzo=params["r_ifzo"])
    h = h.reshape(B, S, d)
    out = rmsnorm(h, params["norm"], cfg.norm_eps) @ params["w_out"]
    new_cache = None
    if cache is not None or decode:
        new_cache = {"state": new_state, "conv": new_tail}
    return out, new_cache


def init_slstm_cache(cfg, batch: int, dtype=jnp.float32):
    H, D = slstm_dims(cfg)
    z = jnp.zeros((batch, H, D), jnp.float32)
    return {"state": (z, z, jnp.full((batch, H, D), -jnp.inf, jnp.float32), z),
            "conv": jnp.zeros((batch, CONV_K - 1, cfg.d_model), dtype)}


# ------------------------------------------------------------ classic LSTM --

def init_lstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "w": dense_init(k1, 2 * d, 4 * d, ("embed", "heads"), dtype),
        "b": Param(jnp.zeros((4 * d,), dtype), ("heads",)),
    }


def lstm_block(params, cfg, x, *, cache=None, decode: bool = False):
    """Classic LSTM (the paper's PTB model). cache: {"h","c"} (B, d)."""
    B, S, d = x.shape
    if cache is not None:
        h0, c0 = cache["h"], cache["c"]
    else:
        h0 = jnp.zeros((B, d), x.dtype)
        c0 = jnp.zeros((B, d), jnp.float32)

    def step(carry, xt):
        h, c = carry
        gates = jnp.concatenate([xt, h], axis=-1) @ params["w"] + params["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f).astype(jnp.float32) * c + \
            (jax.nn.sigmoid(i) * jnp.tanh(g)).astype(jnp.float32)
        h = (jax.nn.sigmoid(o) * jnp.tanh(c).astype(x.dtype))
        return (h, c), h

    (h, c), hs = jax.lax.scan(step, (h0, c0), x.swapaxes(0, 1))
    out = hs.swapaxes(0, 1)
    new_cache = {"h": h, "c": c} if (cache is not None or decode) else None
    return out, new_cache


def init_lstm_cache(cfg, batch: int, dtype=jnp.float32):
    return {"h": jnp.zeros((batch, cfg.d_model), dtype),
            "c": jnp.zeros((batch, cfg.d_model), jnp.float32)}
