"""Mamba2 (SSD) block — zamba2's backbone mixer and the long-context decode path.

State per layer: {"h": (B, H, P, N) SSM state, "conv": (B, K-1, d_conv)} where
d_conv = d_inner + 2N (the conv runs over x, B, C channels as in Mamba2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import Param, dense_init, rmsnorm
from repro.sharding import constrain


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = s.num_heads or d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.state_dim, s.conv_kernel


def init_mamba(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, H, P, N, K = dims(cfg)
    d_conv = d_inner + 2 * N
    ks = jax.random.split(key, 5)
    return {
        # in_proj emits [z (d_inner) | xBC (d_conv) | dt (H)]
        "w_in": dense_init(ks[0], d, d_inner + d_conv + H, ("embed", "ssm_heads"), dtype),
        "conv_w": Param(jax.random.normal(ks[1], (K, d_conv), dtype) * (K ** -0.5),
                        (None, "ssm_heads")),
        "conv_b": Param(jnp.zeros((d_conv,), dtype), ("ssm_heads",)),
        "dt_bias": Param(jnp.zeros((H,), dtype), ("ssm_heads",)),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)), ("ssm_heads",)),
        "D": Param(jnp.ones((H,), dtype), ("ssm_heads",)),
        "norm": Param(jnp.ones((d_inner,), dtype), ("ssm_heads",)),
        "w_out": dense_init(ks[4], d_inner, d, ("ssm_heads", "embed"), dtype),
    }


def _causal_conv(x, w, b, tail=None):
    """x: (B, S, C); w: (K, C) depthwise; tail: (B, K-1, C) left context."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b), xp[:, -(K - 1):]


def mamba_block(params, cfg, x, *, cache: Optional[dict] = None,
                decode: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, S, d) -> (out, new_cache). decode=True requires S == 1."""
    B, S, d = x.shape
    d_inner, H, P, N, K = dims(cfg)
    d_conv = d_inner + 2 * N

    zxd = x @ params["w_in"]
    z, xBC, dt = jnp.split(zxd, [d_inner, d_inner + d_conv], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])              # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))         # (H,)

    tail = cache["conv"] if cache is not None and decode else None
    xBC, new_tail = _causal_conv(xBC, params["conv_w"], params["conv_b"], tail)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xh = xs.reshape(B, S, H, P)
    xh = constrain(xh, ("batch", "seq", "ssm_heads", None))

    if decode:
        y, h = kops.ssd_decode(cache["h"], xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]
    else:
        h0 = cache["h"] if cache is not None else None
        y, h = kops.ssd(xh, dt, A, Bm, Cm, chunk=cfg.ssm.chunk, h0=h0)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_inner)

    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    new_cache = None
    if cache is not None or decode:
        new_cache = {"h": constrain(h, ("batch", "ssm_heads", None, None)),
                     "conv": new_tail}
    return out, new_cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32):
    d_inner, H, P, N, K = dims(cfg)
    return {"h": jnp.zeros((batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, d_inner + 2 * N), dtype)}
