"""Top-level language model: embeddings -> layer stack -> head, plus the
train / prefill / decode entry points used by the launcher and serve engine.

Modality stubs per the assignment: musicgen consumes 4-codebook token ids
(EnCodec frontend stubbed); paligemma consumes precomputed SigLIP patch
embeddings as a bidirectional prefix + text tokens.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.layers import (Param, dense_init, embed, init_embedding,
                                 rmsnorm, split_params, unembed)
from repro.sharding import constrain


def param_dtype(cfg):
    return jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16


def init_params(key, cfg):
    """Returns a Param tree; use layers.split_params to get (values, axes)."""
    dtype = param_dtype(cfg)
    k_embed, k_stack, k_head, k_vis = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": init_embedding(k_embed, cfg, dtype),
        "final_norm": Param(jnp.zeros((cfg.d_model,), dtype), ("embed",)),
        "stack": transformer.init_stack(k_stack, cfg, dtype),
    }
    if not cfg.tie_embeddings:
        n = cfg.padded_vocab
        shape = (cfg.num_codebooks, n, cfg.d_model) if cfg.num_codebooks \
            else (n, cfg.d_model)
        axes = (None, "vocab", "embed") if cfg.num_codebooks else ("vocab", "embed")
        p["head"] = Param(jax.random.normal(k_head, shape, dtype) *
                          (cfg.d_model ** -0.5), axes)
    if cfg.num_prefix_tokens:  # paligemma: projection of the (stub) patch embeds
        p["vision_proj"] = dense_init(k_vis, cfg.d_model, cfg.d_model,
                                      ("embed", "embed"), dtype)
    return p


def _inputs_to_h(params, cfg, batch):
    """batch: {"tokens": ...} (+ "prefix_embed" for vlm). Returns (h, positions)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], cfg, tokens)
    B = x.shape[0]
    if cfg.num_prefix_tokens and "prefix_embed" in batch:
        pre = batch["prefix_embed"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def forward(params, cfg, batch, *, caches=None, cache_index=None,
            decode: bool = False, remat_policy=None, unroll_periods: bool = False,
            mi_periods: int = 1, tag_block_out: bool = False,
            positions=None, paged_view=None) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (logits, new_caches, aux_loss).

    paged_view: with ``cfg.use_paged_decode``, the serving engine's page
    layout ({"boundaries", "page_tokens"}); decode attention then reads KV
    through the tiered page pools (models/attention._paged_decode_core)."""
    with jax.named_scope("boundary_in"):
        if decode:
            x = embed(params["embed"], cfg, batch["tokens"])
            B, S = x.shape[:2]
            if positions is None:
                ci = jnp.asarray(cache_index, jnp.int32)
                positions = (ci[:, None] if ci.ndim >= 1 else
                             jnp.broadcast_to(ci[None, None], (B, S)))
        else:
            x, pos0 = _inputs_to_h(params, cfg, batch)
            if positions is None:      # suffix prefill supplies its own
                positions = pos0

    x, new_caches, aux = transformer.stack_forward(
        params["stack"], cfg, x, positions, caches=caches,
        cache_index=cache_index, decode=decode, remat_policy=remat_policy,
        unroll_periods=unroll_periods, mi_periods=mi_periods,
        tag_block_out=tag_block_out, paged_view=paged_view)

    with jax.named_scope("boundary_head"):
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
        head = params.get("head")
        table = head if head is not None else (
            params["embed"]["table"])
        logits = unembed(params["embed"], cfg, x, head=table)
        logits = constrain(logits, ("batch", "seq", "vocab")
                           if not cfg.num_codebooks else ("batch", "seq", None, "vocab"))
    return logits, new_caches, aux


def loss_fn(params, cfg, batch, *, remat_policy=None, unroll_periods=False,
            mi_periods: int = 1, tag_block_out: bool = False):
    """Causal LM loss (masked to the real vocab; padded logits excluded)."""
    logits, _, aux = forward(params, cfg, batch, remat_policy=remat_policy,
                             unroll_periods=unroll_periods,
                             mi_periods=mi_periods, tag_block_out=tag_block_out)
    labels = batch["labels"]
    V = cfg.padded_vocab
    logits = logits.astype(jnp.float32)
    if V != cfg.vocab_size:  # mask padded vocab entries out of the softmax
        pad = jnp.full((V - cfg.vocab_size,), -1e9, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].add(pad)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if cfg.num_prefix_tokens:  # vlm: loss only over the text suffix
        nll = nll[:, cfg.num_prefix_tokens:]
    return jnp.mean(nll) + aux


def prefill(params, cfg, batch, max_seq: Optional[int] = None):
    """Run the full prompt, returning (last_logits, caches)."""
    from repro.models import kvcache
    tokens = batch["tokens"]
    B = tokens.shape[0]
    S = tokens.shape[1] + (cfg.num_prefix_tokens if "prefix_embed" in batch else 0)
    caches = kvcache.init_cache(cfg, B, max_seq or S, param_dtype(cfg))
    # prefill writes the first S positions; attention uses full-seq buffers
    logits, new_caches, _ = forward(params, cfg, batch, caches=caches)
    return logits[:, -1], new_caches


def prefill_suffix(params, cfg, batch, *, caches, start, paged_view=None):
    """Run prefill over a prompt *suffix* against pre-existing KV state.

    ``batch["tokens"]`` holds only ``tokens[start:]`` of the prompt; the KV
    of the first ``start`` tokens is already materialized in ``caches`` (a
    shared-prefix donor's physical pages on the pools layout, or a dense
    cache a previous chunk wrote into).  Positions and the cache write
    offset both begin at ``start``, and each new row attends back over the
    whole valid prefix, so the computed rows are bit-identical to the same
    rows of a full-prompt ``prefill`` — the shared-prefix compute skip and
    chunked prefill both reduce to calling this per suffix/chunk.

    On the pools layout ``paged_view`` carries the admitted slot's page-
    table row (plus ``{"prefill": True}``) and ``caches`` is the live
    ``PagedKVPools`` tree: attention writes the suffix KV straight into the
    slot's physical hot pages and reads back through the table
    (models/attention._pool_prefill_core).  Returns
    ``(last_row_logits, new_caches)``.
    """
    tokens = batch["tokens"]
    B, L = tokens.shape[0], tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None],
                                 (B, L)) + start
    logits, new_caches, _ = forward(params, cfg, batch, caches=caches,
                                    cache_index=start, positions=positions,
                                    paged_view=paged_view)
    return logits[:, -1], new_caches


def decode_step(params, cfg, tokens, caches, cache_index):
    """One token for every sequence. tokens: (B, 1) (or (B, 1, K))."""
    logits, new_caches, _ = forward(params, cfg, {"tokens": tokens},
                                    caches=caches, cache_index=cache_index,
                                    decode=True)
    return logits[:, -1], new_caches
