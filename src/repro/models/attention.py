"""Attention: GQA/MQA with causal / sliding-window / prefix-LM masks, logit
softcap, QK-norm, RoPE, KV caches — plus DeepSeek MLA (compressed latent cache).

One code path serves train (full seq), prefill (full seq + cache write) and
decode (q_len=1 against a cache). Grouped einsums avoid materializing repeated
KV heads.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MLA
from repro.models.layers import Param, apply_rope, dense_init, rmsnorm, softcap
from repro.sharding import constrain

NEG_INF = -2.0 ** 20


# ----------------------------------------------------------------- masks ----

def attn_bias(q_pos, kv_pos, *, window: int = 0, prefix_len: int = 0,
              kv_len_valid=None):
    """Additive bias (..., Sq, Skv) from position vectors.

    q_pos: (B, Sq) or (Sq,); kv_pos: (Skv,).
    window > 0: sliding-window causal. prefix_len > 0: bidirectional prefix.
    kv_len_valid: (B,) number of valid cache entries (decode).
    """
    q = q_pos[..., :, None].astype(jnp.int32)
    k = kv_pos[None, :].astype(jnp.int32)
    ok = k <= q
    if window:
        ok &= (q - k) < window
    if prefix_len:
        ok |= k < prefix_len
    if kv_len_valid is not None:
        ok &= k < kv_len_valid[..., None, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------------------------- GQA ----

def init_attention(key, cfg, dtype=jnp.float32):
    H, KV, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, H * hd, ("embed", "heads"), dtype),
        "wk": dense_init(ks[1], d, KV * hd, ("embed", "kv_heads"), dtype),
        "wv": dense_init(ks[2], d, KV * hd, ("embed", "kv_heads"), dtype),
        "wo": dense_init(ks[3], H * hd, d, ("heads", "embed"), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = Param(jnp.ones((hd,), dtype), (None,))
        p["k_norm"] = Param(jnp.ones((hd,), dtype), (None,))
    return p


def _gqa_core(q, k, v, bias, softcap_val: float):
    """q: (B,Sq,KV,G,hd); k,v: (B,Skv,KV,hd); bias: (B|1, Sq, Skv)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, softcap_val)
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def attention(params, cfg, x, positions, *, kind: str = ATTN,
              cache: Optional[dict] = None, cache_index=None,
              theta: Optional[float] = None,
              paged_view: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, Sq, d). cache: {"k","v"} fixed (B, Smax, KV, hd) buffers.

    Returns (out, updated_cache). cache_index: scalar write offset (decode).

    paged_view (decode only, ``cfg.use_paged_decode``): the serving engine's
    page layout — {"boundaries": per-slot cold tokens (python ints),
    "page_tokens": page size}.  The attention core then reads KV through
    ``ops.paged_decode_attention``: the updated cache is packed into a
    device-resident hot pool and a host-resident cold pool addressed by a
    per-slot page table, instead of attending over the dense merged buffer.
    """
    B, Sq, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    window = cfg.sliding_window if kind == LOCAL else 0
    if theta is None:
        theta = cfg.rope_theta if (kind == LOCAL or not cfg.rope_theta_global) \
            else cfg.rope_theta_global

    q = (x @ params["wq"]).reshape(B, Sq, H, hd)
    k = (x @ params["wk"]).reshape(B, Sq, KV, hd)
    v = (x @ params["wv"]).reshape(B, Sq, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    q = q.reshape(B, Sq, KV, G, hd)

    if cache is not None and "k_hot" in cache:
        # persistent page pools ARE the cache (kvcache.PagedKVPools): write
        # the new KV straight into its physical hot pages and read back
        # through the page table — no dense buffer exists on this path
        core = _pool_prefill_core if (
            paged_view is not None and paged_view.get("prefill")) \
            else _pool_decode_core
        out, new_cache = core(cfg, q, k, v, cache, cache_index,
                              paged_view, window, positions)
        out = out.reshape(B, Sq, H * hd)
        out = constrain(out, ("batch", "seq", "heads"))
        return out @ params["wo"], new_cache

    if cache is not None:
        # cache stores K/V with heads folded (B, Smax, KV*hd) for shardability
        kf = k.reshape(B, Sq, KV * hd)
        vf = v.reshape(B, Sq, KV * hd)
        if cache_index is not None and getattr(cache_index, "ndim", 0) >= 1:
            # per-slot write offsets (continuous batching): scatter row-wise
            rows = jnp.arange(B)
            k_all = cache["k"].at[rows, cache_index].set(kf[:, 0])
            v_all = cache["v"].at[rows, cache_index].set(vf[:, 0])
        else:
            off = cache_index if cache_index is not None else 0
            k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], kf, off, 1)
            v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], vf, off, 1)
        kv_pos = jnp.arange(k_all.shape[1])
        bias = attn_bias(positions, kv_pos, window=window,
                         prefix_len=cfg.num_prefix_tokens if cfg.prefix_lm else 0)
        new_cache = {"k": constrain(k_all, ("batch", "kv_seq", "kv_heads")),
                     "v": constrain(v_all, ("batch", "kv_seq", "kv_heads"))}
        Smax = k_all.shape[1]
        if (paged_view is not None and cfg.use_paged_decode and Sq == 1
                and cache_index is not None and not cfg.prefix_lm):
            out = _paged_decode_core(cfg, q, k_all, v_all, cache_index,
                                     paged_view, window)
            out = out.reshape(B, Sq, H * hd)
            out = constrain(out, ("batch", "seq", "heads"))
            return out @ params["wo"], new_cache
        k_use = k_all.reshape(B, Smax, KV, hd)
        v_use = v_all.reshape(B, Smax, KV, hd)
    else:
        pos = positions[0] if positions.ndim > 1 else positions
        bias = attn_bias(positions, pos, window=window,
                         prefix_len=cfg.num_prefix_tokens if cfg.prefix_lm else 0)
        k_use, v_use, new_cache = k, v, None

    if bias.ndim == 2:
        bias = bias[None]
    out = _gqa_core(q, k_use, v_use, bias, cfg.attn_softcap)
    out = out.reshape(B, Sq, H * hd)
    out = constrain(out, ("batch", "seq", "heads"))
    return out @ params["wo"], new_cache


def _paged_decode_core(cfg, q, k_all, v_all, cache_index, paged_view, window):
    """Decode attention through the tiered page pools (ROADMAP item: decode
    consumes the page pools directly instead of the dense merged buffer).

    The just-updated dense cache is split at each slot's cold boundary into
    the hot/cold pool layout of kernels/paged_decode.py and read back through
    the per-slot page table — on TPU the Pallas kernel streams cold pages
    over PCIe into a double-buffered VMEM window; on CPU the bit-equivalent
    jnp oracle runs (dispatch in kernels/ops.py).  ``boundaries`` and
    ``page_tokens`` must be concrete python ints (pool packing builds the
    page table at trace time), which the serving engine guarantees; the
    engine precomputes the layer-independent ``layout`` (page table, tier,
    pool order) once per decode step so only the per-layer pool gathers run
    here.
    """
    from repro.kernels import ops as kernel_ops
    from repro.kernels.paged_decode import gather_pools, pool_layout

    B, Sq, KV, G, hd = q.shape
    Smax = k_all.shape[1]
    page = paged_view["page_tokens"]
    layout = paged_view.get("layout")
    if layout is None:
        layout = pool_layout(paged_view["boundaries"], Smax // page, page)
    k4 = k_all.reshape(B, Smax, KV, hd)
    v4 = v_all.reshape(B, Smax, KV, hd)
    k_hot, v_hot, k_cold, v_cold = gather_pools(k4, v4, layout, page)
    table, tier = layout[0], layout[1]
    ci = jnp.asarray(cache_index, jnp.int32)
    lengths = (ci if ci.ndim >= 1 else jnp.broadcast_to(ci, (B,))) + 1
    out = kernel_ops.paged_decode_attention(
        q.reshape(B, KV * G, hd), k_hot, v_hot, k_cold, v_cold, table, tier,
        lengths, window=window, softcap_val=cfg.attn_softcap)
    return out


def _pool_prefill_core(cfg, q, k, v, cache, cache_index, paged_view, window,
                       positions):
    """Suffix/chunk prefill straight into the persistent page pools.

    One admitted slot (B == 1), ``Sq`` prompt tokens starting at logical
    position ``cache_index`` (a traced scalar).  ``paged_view`` carries the
    slot's own page-table row ``page_table (1, max_pages)`` / ``page_tier``
    plus ``{"prefill": True}`` — the python-bool dispatch flag ``attention``
    reads (it never becomes a traced value).  The new rows are scattered
    into the slot's physical hot pages, then attention gathers the FULL
    table row back (Skv = max_pages * page_tokens = max_seq), reading each
    page from the hot or cold pool by tier.  Gathering the full row keeps
    every reduction shape identical to the dense prefill path, which is
    what makes the computed rows bit-identical to a full-prompt prefill:
    rows beyond the valid region are finite stale data masked to exactly
    zero probability by ``attn_bias`` (exp(x + NEG_INF) == 0.0 in float32),
    the same way the dense path masks its zero-filled tail.  Shared-prefix
    pages below ``cache_index`` are read, never written — the engine caps
    the start offset so the write region covers only private pages.
    """
    B, Sq, KV, G, hd = q.shape
    assert B == 1, "pool prefill admits one slot at a time"
    page = paged_view["page_tokens"]
    table = paged_view["page_table"]           # (1, max_pages) this slot
    tier = paged_view["page_tier"]
    pos = jnp.asarray(cache_index, jnp.int32) \
        + jnp.arange(Sq, dtype=jnp.int32)
    phys = table[0, pos // page]               # physical hot page per token
    off = pos % page
    k_hot = cache["k_hot"].at[phys, off].set(k.reshape(Sq, KV * hd))
    v_hot = cache["v_hot"].at[phys, off].set(v.reshape(Sq, KV * hd))
    new_cache = {"k_hot": k_hot, "v_hot": v_hot,
                 "k_cold": cache["k_cold"], "v_cold": cache["v_cold"]}
    # full-row gather: (max_pages, page, KV*hd) -> (1, max_seq, KV, hd);
    # out-of-pool indices clamp and are discarded by the tier select
    sel = (tier[0] == 0)[:, None, None]
    k_all = jnp.where(sel, k_hot[table[0]], cache["k_cold"][table[0]])
    v_all = jnp.where(sel, v_hot[table[0]], cache["v_cold"][table[0]])
    Skv = k_all.shape[0] * page
    k_all = k_all.reshape(1, Skv, KV, hd)
    v_all = v_all.reshape(1, Skv, KV, hd)
    bias = attn_bias(positions, jnp.arange(Skv), window=window)
    if bias.ndim == 2:
        bias = bias[None]
    out = _gqa_core(q, k_all, v_all, bias, cfg.attn_softcap)
    return out, new_cache


def _pool_decode_core(cfg, q, k, v, cache, cache_index, paged_view, window,
                      positions=None):
    """Decode attention with the persistent page pools as the cache.

    ``cache`` holds one attention layer's pools ({"k_hot","v_hot","k_cold",
    "v_cold"}, kvcache.PagedKVPools layout); ``paged_view`` carries the
    layer-independent page table / tier arrays (cached by the engine,
    re-uploaded only on layout deltas), the active-slot mask, and the
    garbage-page index.  The new token's KV is scattered into each slot's
    physical write page (inactive slots are redirected to the garbage page so
    lockstep decode can never corrupt a page a live slot references — the
    engine's pre-step CoW guarantees every active write page is exclusive),
    then attention reads the pools through ops.paged_decode_attention.
    Returns (out (B,1,KV,G,hd)-shaped, new_cache) — the cold pools pass
    through untouched: decode never writes below a boundary.
    """
    from repro.kernels import ops as kernel_ops

    B, Sq, KV, G, hd = q.shape
    assert Sq == 1 and paged_view is not None and cache_index is not None, \
        "pool-form caches are decode-only (the engine prefills densely)"
    page = paged_view["page_tokens"]
    table_arr = paged_view["page_table"]
    tier_arr = paged_view["page_tier"]
    ci = jnp.asarray(cache_index, jnp.int32)
    ci = ci if ci.ndim >= 1 else jnp.broadcast_to(ci, (B,))
    rows = jnp.arange(B)
    phys = table_arr[rows, ci // page]
    active = paged_view.get("active")
    if active is not None:
        phys = jnp.where(active, phys, paged_view["garbage_page"])
    off = ci % page
    kf = k.reshape(B, KV * hd)
    vf = v.reshape(B, KV * hd)
    k_hot = cache["k_hot"].at[phys, off].set(kf)
    v_hot = cache["v_hot"].at[phys, off].set(vf)
    new_cache = {"k_hot": k_hot, "v_hot": v_hot,
                 "k_cold": cache["k_cold"], "v_cold": cache["v_cold"]}

    def pool4(a):
        return a.reshape(a.shape[0], page, KV, hd)

    out = kernel_ops.paged_decode_attention(
        q.reshape(B, KV * G, hd), pool4(k_hot), pool4(v_hot),
        pool4(cache["k_cold"]), pool4(cache["v_cold"]), table_arr, tier_arr,
        ci + 1, window=window, softcap_val=cfg.attn_softcap)
    return out, new_cache


# ------------------------------------------------------------------- MLA ----

def init_mla(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, H * (dn + dr), ("embed", "heads"), dtype),
        "w_dkv": dense_init(ks[1], d, dc, ("embed", None), dtype),
        "w_krope": dense_init(ks[2], d, dr, ("embed", None), dtype),
        "w_uk": dense_init(ks[3], dc, H * dn, (None, "heads"), dtype),
        "w_uv": dense_init(ks[4], dc, H * dv, (None, "heads"), dtype),
        "wo": dense_init(ks[5], H * dv, d, ("heads", "embed"), dtype),
    }


def mla_attention(params, cfg, x, positions, *, cache: Optional[dict] = None,
                  cache_index=None, **_) -> Tuple[jax.Array, Optional[dict]]:
    """DeepSeek-V2 MLA. Cache holds the *compressed* latent (B, S, dc) + shared
    rope key (B, S, dr) — the paper's KV-cache compression; K/V are expanded
    from the latent at use time."""
    B, Sq, d = x.shape
    H = cfg.num_heads
    dn, dr, dv, dc = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank

    q = (x @ params["wq"]).reshape(B, Sq, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ params["w_dkv"]                           # (B,Sq,dc)
    krope = apply_rope((x @ params["w_krope"])[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]      # (B,Sq,dr) shared head

    if cache is not None:
        if cache_index is not None and getattr(cache_index, "ndim", 0) >= 1:
            rows = jnp.arange(B)
            ckv_all = cache["ckv"].at[rows, cache_index].set(ckv[:, 0])
            kr_all = cache["krope"].at[rows, cache_index].set(krope[:, 0])
            kv_pos = jnp.arange(ckv_all.shape[1])
        elif cache_index is not None:
            ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, cache_index, 1)
            kr_all = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope, cache_index, 1)
            kv_pos = jnp.arange(ckv_all.shape[1])
        else:
            ckv_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, 0, 1)
            kr_all = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope, 0, 1)
            kv_pos = jnp.arange(ckv_all.shape[1])
        new_cache = {"ckv": constrain(ckv_all, ("batch", "kv_seq", None)),
                     "krope": constrain(kr_all, ("batch", "kv_seq", None))}
    else:
        ckv_all, kr_all, new_cache = ckv, krope, None
        kv_pos = positions[0] if positions.ndim > 1 else positions

    Skv = ckv_all.shape[1]
    k_nope = (ckv_all @ params["w_uk"]).reshape(B, Skv, H, dn)
    v = (ckv_all @ params["w_uv"]).reshape(B, Skv, H, dv)

    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32)) +
              jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                         kr_all.astype(jnp.float32))) * scale
    bias = attn_bias(positions, kv_pos)
    scores = scores + (bias[None] if bias.ndim == 2 else bias[:, None])
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v).reshape(B, Sq, H * dv)
    out = constrain(out, ("batch", "seq", "heads"))
    return out @ params["wo"], new_cache
