"""Core layers: norms, rotary embeddings, MLPs, embeddings, param init.

Params are plain pytrees (nested dicts of jnp arrays). Initializers build a
parallel tree of logical axis names (for sharding) via the ``Param`` wrapper;
``split_params`` separates values from axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import constrain


@dataclasses.dataclass
class Param:
    value: jax.Array
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), tuple(p.axes)),
    lambda axes, ch: Param(ch[0], axes),
)


def split_params(tree):
    """Split a Param tree into (values, logical_axes)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, Param))
    vals = [p.value if isinstance(p, Param) else p for p in leaves]
    axes = [p.axes if isinstance(p, Param) else (None,) * getattr(p, "ndim", 0)
            for p in leaves]
    return jax.tree.unflatten(treedef, vals), jax.tree.unflatten(treedef, axes)


def _init(key, shape, axes, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return Param(jax.random.normal(key, shape, dtype) * scale, axes)


def dense_init(key, d_in, d_out, axes, dtype=jnp.float32):
    return _init(key, (d_in, d_out), axes, dtype=dtype)


def rmsnorm(x, w, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w.astype(jnp.float32)
    y = y * (1.0 + w) if plus_one else y * w
    return y.astype(dt)


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# ---------------------------------------------------------------- rotary ----

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP ----

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, ("embed", "mlp"), dtype),
        "wg": dense_init(k2, d_model, d_ff, ("embed", "mlp"), dtype),
        "wo": dense_init(k3, d_ff, d_model, ("mlp", "embed"), dtype),
    }


def mlp(params, x, act: str):
    h = act_fn(act)(x @ params["wg"]) * (x @ params["wi"])
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ params["wo"]


# ------------------------------------------------------------- embedding ----

def init_embedding(key, cfg, dtype=jnp.float32):
    n = cfg.padded_vocab
    scale = cfg.d_model ** -0.5
    p = {"table": _init(key, (n, cfg.d_model), ("vocab", "embed"),
                        scale=scale, dtype=dtype)}
    if cfg.num_codebooks:  # musicgen: one table per codebook
        keys = jax.random.split(key, cfg.num_codebooks)
        p["table"] = Param(
            jnp.stack([jax.random.normal(k, (n, cfg.d_model), dtype) * scale
                       for k in keys]),
            (None, "vocab", "embed"))
    return p


def embed(params, cfg, tokens):
    """tokens: (B, S) int32, or (B, S, K) for K codebooks."""
    t = params["table"]
    if cfg.num_codebooks:
        # (B, S, K) codes: index each codebook's table, sum the embeddings
        parts = [jnp.take(t[k], tokens[..., k], axis=0) for k in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(t, tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, ("batch", "seq_res", "embed"))


def unembed(params, cfg, x, head=None):
    """x: (B, S, d) -> logits (B, S, V) (or (B, S, K, V) for codebooks)."""
    if head is not None:
        t = head
    else:
        t = params["table"]
    if cfg.num_codebooks:
        logits = jnp.einsum("bsd,kvd->bskv", x, t)
    else:
        logits = x @ t.T
    logits = softcap(logits, cfg.final_softcap)
    return logits
