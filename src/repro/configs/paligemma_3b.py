"""paligemma-3b [vlm] — SigLIP (stub) + gemma decoder. [arXiv:2407.07726; hf]

Backbone only: input_specs() provides 256 precomputed patch embeddings
(SigLIP frontend stub) + text token ids. Prefix-LM masking: bidirectional
attention over the image prefix, causal over text.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    period=(ATTN,),
    prefix_lm=True,
    num_prefix_tokens=256,
    act="gelu",
    scale_embed=True,
    tie_embeddings=True,
))
