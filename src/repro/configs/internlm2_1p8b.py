"""internlm2-1.8b [dense] — GQA llama-family. [arXiv:2403.17297; hf]"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_544,
    head_dim=128,
    period=(ATTN,),
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
))
