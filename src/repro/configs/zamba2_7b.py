"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242; unverified]

81 layers: 1 prologue mamba + 16 periods of (4 mamba + 1 shared attention block).
The shared-attention block re-uses a single weight copy everywhere it appears
(the Zamba signature), so stacked params carry no attention weights.
"""
from repro.configs.base import MAMBA, SHARED_ATTN, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,               # shared block MLP
    vocab_size=32_000,
    head_dim=112,
    period=(MAMBA, MAMBA, MAMBA, MAMBA, SHARED_ATTN),
    prologue=(MAMBA,),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4, chunk=256),
    act="gelu",
    tie_embeddings=True,
))
