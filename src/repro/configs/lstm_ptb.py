"""lstm-ptb — the paper's own LSTM/PTB model (Sentinel Table 3 row 'LSTM').

Medium PTB LSTM (Zaremba et al.): 2 layers, width 650, vocab 10000, BPTT.
Included so the paper's own benchmark suite has a native member alongside the
assigned archs; not part of the 40 dry-run cells.
"""
from repro.configs.base import LSTM, ModelConfig, register

CONFIG = register(ModelConfig(
    name="lstm-ptb",
    family="lstm",
    num_layers=2,
    d_model=650,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=10_000,
    head_dim=650,
    period=(LSTM,),
    act="silu",
    tie_embeddings=False,
    vocab_pad_to=16,
))
