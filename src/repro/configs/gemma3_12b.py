"""gemma3-12b [dense] — 5:1 local:global sliding-window, 128k context.

[hf:google/gemma-3-12b-pt; unverified] — per the assignment sheet.
"""
from repro.configs.base import ATTN, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15_360,
    vocab_size=262_144,
    head_dim=256,
    period=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, ATTN),  # 5 local : 1 global
    sliding_window=1024,
    qk_norm=True,
    rope_theta=10_000.0,          # local layers
    rope_theta_global=1_000_000.0,  # global layers
    act="gelu",
    scale_embed=True,
    tie_embeddings=True,
))
