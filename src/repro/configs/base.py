"""Config system: model configs, input-shape configs, and the arch registry.

Every assigned architecture is a ``ModelConfig`` built from a repeating
*period* of layer kinds (e.g. gemma3 = 5 local + 1 global attention layers),
which is what lets the layer stack lower as a ``lax.scan`` over periods and
what gives Sentinel its migration-interval block structure.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


# Layer kinds that can appear in a period.
ATTN = "attn"            # full causal attention + MLP
LOCAL = "local"          # sliding-window attention + MLP
MLA = "mla"              # multi-head latent attention (deepseek) + MoE/MLP
MAMBA = "mamba"          # mamba2 SSD block
SHARED_ATTN = "shared_attn"  # zamba2 shared transformer block (one weight copy)
MLSTM = "mlstm"          # xLSTM matrix-LSTM block
SLSTM = "slstm"          # xLSTM scalar-LSTM block
LSTM = "lstm"            # classic LSTM (paper's own PTB model)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    experts_per_token: int = 0     # top-k
    num_shared_experts: int = 0
    d_ff: int = 0                  # per-expert hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    group_size: int = 512          # tokens per dispatch group (GShard-style);
                                   # dispatch memory ~ T * group_size * k * factor


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64           # N
    head_dim: int = 64            # P
    num_heads: int = 0            # filled from d_inner // head_dim if 0
    expand: int = 2               # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm | lstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # layer period (repeats num_layers // len(period) times)
    period: Sequence[str] = (ATTN,)
    prologue: Sequence[str] = ()  # unstacked leading layers (deepseek dense layer 0)
    prologue_d_ff: int = 0

    # attention details
    use_paged_decode: bool = False  # decode attention reads the tiered page
                                    # pools via ops.paged_decode_attention
                                    # (serve/engine passes the page view)
    sliding_window: int = 0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3 global layers use a different theta
    prefix_lm: bool = False          # paligemma: bidirectional prefix

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # modality stubs
    num_codebooks: int = 0        # musicgen: 4 EnCodec codebooks
    num_prefix_tokens: int = 0    # paligemma: SigLIP patch embeddings (stub)

    act: str = "silu"             # silu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embed: bool = False     # gemma family scales embeddings by sqrt(d)
    vocab_pad_to: int = 256       # pad embedding table for even TP sharding
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers >= len(self.prologue)
        n = self.num_layers - len(self.prologue)
        assert n % len(self.period) == 0, (
            f"{self.name}: {n} layers not divisible by period {len(self.period)}")

    # ------------------------------------------------------------------
    @property
    def period_len(self) -> int:
        return len(self.period)

    @property
    def num_periods(self) -> int:
        return (self.num_layers - len(self.prologue)) // len(self.period)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def has_attention(self) -> bool:
        kinds = set(self.period) | set(self.prologue)
        return bool(kinds & {ATTN, LOCAL, MLA, SHARED_ATTN})

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid/linear or local-dominant)."""
        kinds = set(self.period)
        if kinds & {MAMBA, MLSTM, SLSTM, LSTM}:
            return True
        # local-attention-dominant archs (gemma2/3) decode in O(window) for
        # local layers; treated as sub-quadratic per DESIGN.md §5.
        return LOCAL in kinds

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=len(self.prologue) + 2 * self.period_len,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            prologue_d_ff=128 if self.prologue else 0,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            # capacity_factor high enough to be dropless at toy scale so
            # prefill/decode parity holds exactly (capacity dropping is
            # batch-shape-dependent by construction)
            moe=dataclasses.replace(self.moe, num_experts=4, experts_per_token=2,
                                    d_ff=64, capacity_factor=4.0)
            if self.moe else None,
            ssm=dataclasses.replace(self.ssm, state_dim=16, head_dim=8, num_heads=0,
                                    chunk=8) if self.ssm else None,
            num_prefix_tokens=4 if self.num_prefix_tokens else 0,
            dtype="float32",
        )
        return dataclasses.replace(self, **{k: v for k, v in kw.items()})


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import the per-arch modules for their registration side effect
    from repro.configs import (  # noqa: F401
        smollm_360m, gemma3_12b, internlm2_1p8b, gemma2_2b,
        granite_moe_3b, deepseek_v2_lite, zamba2_7b, xlstm_1p3b,
        musicgen_medium, paligemma_3b, lstm_ptb,
    )


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skips per DESIGN.md §5."""
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        if arch == "lstm-ptb":
            continue  # paper's own model: not part of the 40 assigned cells
        for sname, shape in SHAPES.items():
            skip = sname == "long_500k" and not cfg.subquadratic
            if skip and not include_skips:
                continue
            out.append((arch, sname, skip))
    return out
