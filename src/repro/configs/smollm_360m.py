"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,      # GQA 3:1
    d_ff=2560,
    vocab_size=49_152,
    head_dim=64,
    period=(ATTN,),
    act="silu",
    tie_embeddings=True,
))
