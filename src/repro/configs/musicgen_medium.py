"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a stub; inputs are 4-codebook token ids
(delay pattern applied upstream). Embeddings of the 4 codebooks are summed and
the head emits 4x2048 logits.
"""
from repro.configs.base import ATTN, ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,           # full MHA
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    period=(ATTN,),
    num_codebooks=4,
    act="gelu",
    tie_embeddings=False,
    vocab_pad_to=128,
))
