"""gemma2-2b [dense] — local/global alternating + logit softcaps. [arXiv:2408.00118; hf]"""
from repro.configs.base import ATTN, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=256,
    period=(LOCAL, ATTN),      # alternating sliding-window / full
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    scale_embed=True,
    tie_embeddings=True,
))
