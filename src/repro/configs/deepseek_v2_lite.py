"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed top-6 + 2 shared.

[arXiv:2405.04434; hf] — layer 0 is dense (d_ff=10944), layers 1..26 MoE.
"""
from repro.configs.base import MLA, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,           # MLA: heads share one latent; kept for bookkeeping
    d_ff=1408,                 # per-expert hidden
    vocab_size=102_400,
    head_dim=192,              # qk_nope + qk_rope
    period=(MLA,),
    prologue=(MLA,),           # dense first layer
    prologue_d_ff=10_944,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=MoEConfig(num_experts=64, experts_per_token=6, num_shared_experts=2,
                  d_ff=1408),
    act="silu",
    tie_embeddings=False,
))
