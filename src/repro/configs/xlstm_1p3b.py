"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, 7:1 ratio. [arXiv:2405.04517; unverified]

d_ff=0 per the sheet: blocks carry their own up/down projections, no separate MLP.
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=512,
    period=(MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, MLSTM, SLSTM),
    act="gelu",
    tie_embeddings=True,
))
