"""granite-moe-3b-a800m [moe] — 40 experts top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base; hf] — per the assignment sheet
(32L d_model=1536 24H GQA kv=8 per-expert d_ff=512 vocab=49155, 40e top-8).
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                  # per-expert hidden size
    vocab_size=49_155,
    head_dim=64,
    period=(ATTN,),
    moe=MoEConfig(num_experts=40, experts_per_token=8, d_ff=512),
    act="silu",
    tie_embeddings=True,
))
