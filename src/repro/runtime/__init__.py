"""Sentinel's unified runtime API: one profile -> plan -> migrate surface.

The repo implements the paper's idea for two workload families — training
(activation/weight offload over migration intervals) and serving (KV-cache
tiering over decode tokens).  This package is the single surface both dispatch
through:

    from repro import runtime

    # any workload: a profiler TraceProfile or an hmsim ServeTrace
    plan   = runtime.plan(workload, cost_model, fast_bytes)  # PlacementPlan
    plan   = runtime.plan(workload, cost_model, fast_bytes,
                          objective="latency")   # select by predicted time
    result = runtime.simulate(workload, cost_model, fast_bytes, "sentinel")

    plan.to_json()                 # bit-stable round trip via from_json
    runtime.list_policies()        # every policy runs on every workload

The machine argument is a ``CostModel`` (``TPU_V5E_COST`` is the default
instance); a legacy ``HWSpec`` passed in its place is upgraded via
``CostModel.from_hw`` and behaves identically.

Layout:
  objects.py   MemoryTier / DataObject / AccessTimeline / Workload protocol
               (+ the TraceProfile / ServeTrace adapters)
  costmodel.py CostModel / StepTraffic / CostReport — the time-domain model
               pricing each policy's recorded per-step traffic
  tiergraph.py TierGraph / TierEdge / GraphHW — the memory system as a
               directed graph of tiers with per-edge bandwidths; every
               policy runs on any graph via the two-tier fold
               (``plan(..., tier_graph=)``, the fast/slow pair is the
               trivial instance)
  policies.py  the one policy registry and the PlacementResult they return
  plan.py      runtime.plan and the serializable PlacementPlan (+ PlanDelta
               incremental re-plans: apply == fresh plan, byte-for-byte)
  online.py    the continuous profile->re-plan loop: OnlineReplanner drift
               detection + hysteresis + elastic slot lending, and
               replay_drift's clairvoyant-regret differential
  synthetic.py deterministic synthetic workloads (golden tests, benchmarks,
               piecewise-stationary drift trio)

The legacy entry points (``core.planner.plan`` / ``plan_serve``,
``core.policies``, ``core.hmsim.simulate_*``) remain as deprecation shims —
thin wrappers over this package; see ``docs/RUNTIME_API.md`` for the
contract and the migration guide.
"""
from repro.runtime.objects import (AccessTimeline, DataObject, MemoryTier,
                                   MultiTenantWorkload, ServingWorkload,
                                   Tenant, TrainingWorkload, Workload,
                                   as_workload, merge_tenant_traces,
                                   normalized_quotas, peak_object_bytes,
                                   tiers_from_hw)
from repro.runtime.costmodel import (TPU_V5E_COST, CostModel, CostReport,
                                     StepTraffic, as_cost_model)
from repro.runtime.tiergraph import GraphHW, TierEdge, TierGraph
from repro.runtime.plan import (Candidate, PlacementPlan, PlanDelta,
                                ServeCandidate, enumerate_candidates,
                                interval_stats, mi_to_periods, pack_slots,
                                plan, plan_delta, plan_serving,
                                plan_training, serve_token_stats,
                                slot_kv_weights, validate_slot_devices)
from repro.runtime.policies import (PAGE_BYTES, POLICIES, PlacementPolicy,
                                    PlacementResult, Unit, build_units,
                                    get_policy, list_policies,
                                    register_policy, simulate)
from repro.runtime.online import (DriftSegment, DriftWorkload, OnlineReplanner,
                                  OnlineReport, ReplanEvent, SegmentReport,
                                  StepStat, WindowStats, drift_score,
                                  plan_churn_bytes, replay_drift)

__all__ = [
    "AccessTimeline", "Candidate", "CostModel", "CostReport", "DataObject",
    "DriftSegment", "DriftWorkload", "MemoryTier", "MultiTenantWorkload",
    "OnlineReplanner", "OnlineReport", "PAGE_BYTES", "POLICIES",
    "PlacementPlan", "PlacementPolicy", "PlacementResult", "PlanDelta",
    "GraphHW", "ReplanEvent", "SegmentReport", "ServeCandidate",
    "ServingWorkload", "StepStat", "StepTraffic", "TPU_V5E_COST", "Tenant",
    "TierEdge", "TierGraph", "TrainingWorkload",
    "Unit", "WindowStats", "Workload", "as_cost_model", "as_workload",
    "build_units", "drift_score", "enumerate_candidates", "get_policy",
    "interval_stats", "list_policies", "merge_tenant_traces", "mi_to_periods",
    "normalized_quotas", "pack_slots", "peak_object_bytes", "plan",
    "plan_churn_bytes", "plan_delta", "plan_serving", "plan_training",
    "register_policy", "replay_drift", "serve_token_stats", "simulate",
    "slot_kv_weights", "tiers_from_hw", "validate_slot_devices",
]
