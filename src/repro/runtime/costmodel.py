"""Time-domain cost model: price per-step traffic, not migration bytes.

Every gate in the repo before this module optimized migration *bytes* — a
proxy that cannot see bandwidth contention between decode reads and
background migration.  Sentinel's actual claim is *performance* parity with
fast-memory-only at ~20% capacity, so the planner needs a clock, not a byte
counter.  This module supplies it:

  StepTraffic   what one timeline step actually moved: fast/slow demand
                reads, migration in/out, compute, tokens.  Every policy's
                ``simulate`` records one per step (``result.step_traffic``).
  CostModel     the machine the traffic is priced on: per-tier read/write
                bandwidths, the host interface-vs-internal split, migration
                contention, and a DMA-overlap factor for the double-buffered
                paged-decode window.  ``step_time`` prices one step as

                    T_step = max(T_compute, T_roofline, T_HBM, T_ext)

                the per-step pipe maximum of fangyunh's
                ``Data_Placement_Optimization`` simulator (SNIPPETS.md 1-2):
                reads and migration share each memory pipe, and the step
                takes as long as its most-contended pipe.
  CostReport    ``price`` folds a traffic series to simulated seconds and
                tokens/sec — the latency objective ``runtime.plan`` selects
                placements by.

The pipe terms, for visible migration v_in/v_out = (1-dma_overlap) * bytes:

  T_compute   flops / peak_flops
  T_roofline  (fast_read + slow_read) / fast_read_bw — every byte the step's
              compute consumed, priced at fast bandwidth.  This floor makes
              the model *placement-consistent*: an all-fast placement
              lower-bounds every other placement of the same reads, and
              slow reads are free exactly while the external pipe hides
              under this floor (the paper's parity-at-20%-capacity regime).
  T_HBM       fast_read / fast_read_bw + v_in / fast_write_bw
              + v_out / fast_read_bw — demand reads and migration copies
              contend for HBM bandwidth.
  T_ext       max((slow_read - demand_read) / min(slow_read_bw,
                                                  host_internal_bw)
                  + max(v_in / mig_read_bw, v_out / mig_write_bw),
                  (slow_read + v_in + v_out) / host_internal_bw)
              — the external pipe seen two ways: the device interface
              (planned slow reads streamed with the slower migration
              direction) and the host memory servicing all of it internally.

plus, serialized on top of the maximum, ``demand_read / ext_read_bw``: the
reactive portion of the slow reads.  A policy that knows the access schedule
(``plans_ahead``: the sentinel family, static placements) streams its slow
reads behind the pipe maximum; a reactive one (LRU paging, caching daemons)
discovers each miss at touch time, so those bytes stall compute — the
paper's proactive-vs-reactive distinction, and the reason demand paging
cannot reach prefetch's latency even at equal traffic.

``CostModel`` duck-types ``HWSpec`` (``fast_bw``/``slow_bw``/``mig_bw``
properties), so it drops into ``runtime.simulate`` and every policy
unchanged; ``CostModel.from_hw`` upgrades a legacy ``HWSpec`` to a model
that simulates *identically* (host interface-bound, no DMA overlap).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, fields
from typing import List, Optional, Sequence

from repro.core.hardware import TPU_V5E, HWSpec


@dataclass
class StepTraffic:
    """What one timeline step moved — the unit ``CostModel`` prices.

    ``fast_read``/``slow_read`` are bytes the step's compute consumed from
    each tier (fast includes the placement-independent fixed traffic:
    KV writes, weight streaming, reserve-pool churn).  ``demand_read`` is
    the *reactive* portion of ``slow_read``: bytes a schedule-blind policy
    only discovered it needed when compute touched them, so they cannot be
    streamed behind the pipe maximum and serialize onto the critical path
    (the event loop sets it from the policy's ``plans_ahead`` flag — the
    paper's proactive-vs-reactive distinction).  ``mig_in``/``mig_out``
    are migration bytes slow->fast / fast->slow attributed to the step;
    ``migs`` the migration events (each costs ``mig_overhead``), ``stall``
    seconds already on the critical path (Case-3 / SLO repair copies).
    ``extra_flops``/``extra_fast`` carry the off-timeline add-on (slot-refill
    prefill), always fast-tier.

    ``prefill_flops``/``prefill_read`` refine the prefill add-on for the
    cache-aware engine: ``prefill_flops`` is the prompt compute actually
    *run* (net of the shared-prefix compute skip — rows whose KV maps onto
    a donor's pages are never recomputed) and ``prefill_read`` the shared
    KV bytes those skipped rows' successors attend back into.  When
    ``extra_flops`` is zero the prefill terms stand alone; series built by
    the serving timeline set both, with ``extra_flops`` preferred so legacy
    pricing is unchanged (``extra_flops == prefill_flops`` there).  With
    ``chunked_prefill=True`` the pricing entry points fold the prefill term
    into the step's pipe maximum (prefill chunks interleave with decode)
    instead of serializing it after the step.
    """
    flops: float = 0.0
    fast_read: float = 0.0
    slow_read: float = 0.0
    demand_read: float = 0.0
    mig_in: float = 0.0
    mig_out: float = 0.0
    tokens: int = 0
    migs: float = 0.0
    extra_flops: float = 0.0
    extra_fast: float = 0.0
    stall: float = 0.0
    prefill_flops: float = 0.0
    prefill_read: float = 0.0


@dataclass
class CostReport:
    """A priced traffic series: the latency objective's measurement."""
    time: float                      # predicted seconds for the series
    compute_time: float              # all-fast prediction of the same reads
    tokens: int
    step_times: List[float] = field(default_factory=list)

    @property
    def slowdown(self) -> float:
        return self.time / max(self.compute_time, 1e-30)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.time, 1e-30)


@dataclass(frozen=True)
class CostModel:
    """The machine a ``StepTraffic`` series is priced on.

    Bandwidths are bytes/second.  ``slow_read_bw`` is the *interface* a
    demand read from the slow tier comes through (PCIe for a TPU host tier);
    ``host_internal_bw`` is the slow tier's internal bandwidth servicing
    demand reads AND migration copies together (``inf`` = interface-bound,
    the legacy two-bandwidth model).  ``mig_read_bw``/``mig_write_bw`` are
    the migration DMA engines per direction; ``dma_overlap`` is the fraction
    of migration traffic the double-buffered paged-decode window hides
    behind compute (0 = fully exposed, the legacy model's assumption).
    """
    name: str = "costmodel"
    peak_flops: float = 1e12
    fast_read_bw: float = 1e11
    fast_write_bw: float = 1e11
    slow_read_bw: float = 1e10
    mig_read_bw: float = 1e10
    mig_write_bw: float = 1e10
    host_internal_bw: float = math.inf
    link_bw: float = 0.0
    dma_overlap: float = 0.0
    mig_overhead: float = 0.0
    fast_bytes: float = 0.0

    # ------------------------------------------------ HWSpec duck-typing --
    # Every policy and simulator reads hw.fast_bw/slow_bw/mig_bw; a
    # CostModel drops in wherever an HWSpec was accepted.
    @property
    def fast_bw(self) -> float:
        return self.fast_read_bw

    @property
    def slow_bw(self) -> float:
        return self.slow_read_bw

    @property
    def mig_bw(self) -> float:
        return self.mig_read_bw

    @classmethod
    def from_hw(cls, hw) -> "CostModel":
        """Upgrade an ``HWSpec`` (or pass a CostModel through).  The mapped
        model simulates and prices the legacy machine exactly: interface-
        bound host (``host_internal_bw = inf``), symmetric migration DMA,
        no DMA overlap."""
        if isinstance(hw, cls):
            return hw
        return cls(name=hw.name, peak_flops=hw.peak_flops,
                   fast_read_bw=hw.fast_bw, fast_write_bw=hw.fast_bw,
                   slow_read_bw=hw.slow_bw, mig_read_bw=hw.mig_bw,
                   mig_write_bw=hw.mig_bw, host_internal_bw=math.inf,
                   link_bw=hw.link_bw, dma_overlap=0.0,
                   mig_overhead=hw.mig_overhead, fast_bytes=hw.fast_bytes)

    # ------------------------------------------------------------ pricing --
    def ext_read_bw(self) -> float:
        """Effective demand-read bandwidth from the slow tier: the slower of
        the device interface and the host's internal memory."""
        return min(self.slow_read_bw, self.host_internal_bw)

    def optimal_alpha(self) -> float:
        """Bandwidth-optimal fast:total read split.  Splitting a read stream
        alpha fast / (1-alpha) slow equalizes the two pipes' times when
        alpha/(1-alpha) = B_fast/B_ext, i.e. alpha = B_fast/(B_fast+B_ext)
        — reads beyond that fraction buy no time, only migration traffic."""
        return self.fast_read_bw / (self.fast_read_bw + self.ext_read_bw())

    def step_time(self, tr: StepTraffic, *,
                  chunked_prefill: bool = False) -> float:
        """Price one step: max over the contended pipes (see module doc),
        plus the serialized demand misses — a reactive policy's slow reads
        are discovered at touch time and stall compute instead of streaming
        behind it (the planned remainder overlaps inside ``T_ext``).

        ``chunked_prefill`` models the engine's interleaved prefill: the
        prefill add-on becomes one more pipe under the step maximum (chunks
        run between decode dispatches and hide behind the slower of the
        two) instead of serializing after the step — the one-shot engine's
        whole-batch stall."""
        vin = tr.mig_in * (1.0 - self.dma_overlap)
        vout = tr.mig_out * (1.0 - self.dma_overlap)
        planned_slow = max(0.0, tr.slow_read - tr.demand_read)
        t_compute = tr.flops / self.peak_flops
        t_roofline = (tr.fast_read + tr.slow_read) / self.fast_read_bw
        t_hbm = tr.fast_read / self.fast_read_bw \
            + vin / self.fast_write_bw + vout / self.fast_read_bw
        t_ext = max(planned_slow / self.ext_read_bw()
                    + max(vin / self.mig_read_bw, vout / self.mig_write_bw),
                    (tr.slow_read + vin + vout) / self.host_internal_bw)
        extra = self._extra_time(tr)
        t = max(t_compute, t_roofline, t_hbm, t_ext)
        if chunked_prefill:
            t = max(t, extra)
            extra = 0.0
        return t + min(tr.demand_read, tr.slow_read) / self.ext_read_bw() \
            + extra + tr.stall \
            + tr.migs * self.mig_overhead

    def step_time_all_fast(self, tr: StepTraffic, *,
                           chunked_prefill: bool = False) -> float:
        """The same step with every demand byte in the fast tier and no
        migration: the roofline floor ``step_time`` can never beat."""
        t = max(tr.flops / self.peak_flops,
                (tr.fast_read + tr.slow_read) / self.fast_read_bw)
        extra = self._extra_time(tr)
        return max(t, extra) if chunked_prefill else t + extra

    def _extra_time(self, tr: StepTraffic) -> float:
        # extra_flops is preferred when both are set (the serving timeline
        # mirrors it into prefill_flops); prefill_read rides the same fast
        # pipe as the prefill's own KV traffic
        eflops = tr.extra_flops or tr.prefill_flops
        ebytes = tr.extra_fast + tr.prefill_read
        if not eflops and not ebytes:
            return 0.0
        return max(eflops / self.peak_flops,
                   ebytes / self.fast_read_bw)

    def price(self, traffic: Sequence[StepTraffic], *,
              chunked_prefill: bool = False) -> CostReport:
        """Fold a traffic series to predicted seconds and tokens/sec."""
        step_times = [self.step_time(tr, chunked_prefill=chunked_prefill)
                      for tr in traffic]
        return CostReport(time=sum(step_times),
                          compute_time=sum(self.step_time_all_fast(tr)
                                           for tr in traffic),
                          tokens=int(sum(tr.tokens for tr in traffic)),
                          step_times=step_times)

    def price_result(self, result, tier_graph=None, *,
                     chunked_prefill: bool = False) -> CostReport:
        """Price a ``PlacementResult`` through its recorded traffic.

        With ``tier_graph`` the series is priced per *edge*: each step's
        time is the pipe maximum over the scalar pipes AND every graph
        edge's attributed bytes over that edge's bandwidth (see
        ``price_on_graph``)."""
        traffic = getattr(result, "step_traffic", None)
        if traffic is None:
            raise ValueError(
                f"result for policy {result.policy!r} carries no "
                "step_traffic (was it built by runtime.simulate?)")
        if tier_graph is None:
            return self.price(traffic, chunked_prefill=chunked_prefill)
        return self.price_on_graph(traffic, tier_graph,
                                   getattr(result, "edge_traffic", None),
                                   chunked_prefill=chunked_prefill)

    def price_on_graph(self, traffic: Sequence[StepTraffic], tier_graph,
                       edge_traffic: Optional[Sequence[dict]] = None,
                       compute: Optional[str] = None, *,
                       chunked_prefill: bool = False,
                       device_traffic: Optional[Sequence[dict]] = None
                       ) -> CostReport:
        """Per-edge pricing: fold each step's channels onto graph edges and
        take the pipe maximum across them.

        The migration channels ride the spill<->compute path (promotions on
        spill->compute, demotions on compute->spill, the DMA-overlapped
        visible fraction only — exactly the terms ``step_time`` already
        prices inside ``T_ext``, so a canonical two-tier graph prices
        byte-identically to ``price``).  ``edge_traffic`` optionally adds
        per-step ``{(src, dst): bytes}`` flows the two-tier fold cannot
        see — cross-device KV streaming on the dev<->dev link — each priced
        at ``path_bw(src, dst)`` as its own pipe (a transfer engine running
        behind compute, surfacing only when it is the bottleneck).

        ``device_traffic`` splits a step across compute nodes: per step a
        ``{node_name: StepTraffic}`` map of each device's *own* share of the
        reads/compute.  When present for a step, the scalar pipe is the max
        over the devices' ``step_time`` values — devices run concurrently,
        so the step lasts as long as its slowest shard — instead of the
        global series' single-machine time (which would price the summed
        reads through one HBM pipe and hide any skew).  The global series
        still supplies tokens and the all-fast floor."""
        # attribute the mig channels to the unbounded (host-like) tier when
        # the graph has one — demotion targets capacity-free memory — and
        # fall back to the view's widest-path spill otherwise.  On the
        # canonical two-tier graph both pick "slow", keeping the pricing
        # byte-identical to ``price``.
        compute_name = compute or tier_graph.nodes[0].name
        spill = next((n.name for n in tier_graph.nodes
                      if n.capacity is None and n.name != compute_name),
                     None)
        view = tier_graph.hw_view(self, compute=compute, spill=spill)

        def pipe(nbytes, src, dst):
            bw = tier_graph.path_bw(src, dst)
            if bw <= 0:
                raise ValueError(f"no path {src} -> {dst} in the tier "
                                 f"graph for {nbytes:.0f} attributed bytes")
            return nbytes / bw

        step_times = []
        for t, tr in enumerate(traffic):
            per_dev = (device_traffic[t] if device_traffic is not None
                       and t < len(device_traffic) else None)
            if per_dev:
                pipes = [self.step_time(dtr,
                                        chunked_prefill=chunked_prefill)
                         for dtr in per_dev.values()]
            else:
                pipes = [self.step_time(tr,
                                        chunked_prefill=chunked_prefill)]
            vin = tr.mig_in * (1.0 - self.dma_overlap)
            vout = tr.mig_out * (1.0 - self.dma_overlap)
            if vin:
                pipes.append(pipe(vin, view.spill, view.compute))
            if vout:
                pipes.append(pipe(vout, view.compute, view.spill))
            flows = (edge_traffic[t] if edge_traffic is not None
                     and t < len(edge_traffic) else None)
            if flows:
                for (src, dst), nbytes in flows.items():
                    if nbytes:
                        pipes.append(pipe(nbytes, src, dst))
            step_times.append(max(pipes))
        return CostReport(time=sum(step_times),
                          compute_time=sum(self.step_time_all_fast(tr)
                                           for tr in traffic),
                          tokens=int(sum(tr.tokens for tr in traffic)),
                          step_times=step_times)

    # --------------------------------------------------------------- json --
    def to_dict(self) -> dict:
        """JSON-safe dict (``inf`` host bandwidth serialized as None)."""
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        if math.isinf(d["host_internal_bw"]):
            d["host_internal_bw"] = None
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        d = dict(d)
        if d.get("host_internal_bw") is None:
            d["host_internal_bw"] = math.inf
        return cls(**d)


def as_cost_model(hw_or_cost) -> CostModel:
    """Coerce an ``HWSpec`` or ``CostModel`` into a ``CostModel``."""
    return CostModel.from_hw(hw_or_cost)


# The default machine: TPU_V5E's constants as a time-domain model.  Shared
# with ``benchmarks/roofline.py`` (``core.hardware.default_cost_model``), so
# the roofline table and the planner price the same machine.  Extends the
# legacy constants (identical where they overlap) with the host split and
# the paged-decode double-buffering overlap the byte-domain model ignored.
TPU_V5E_COST = CostModel(
    name=TPU_V5E.name,
    peak_flops=TPU_V5E.peak_flops,
    fast_read_bw=TPU_V5E.fast_bw,
    fast_write_bw=TPU_V5E.fast_bw,
    slow_read_bw=TPU_V5E.slow_bw,      # PCIe-bound host reads
    mig_read_bw=TPU_V5E.mig_bw,        # PCIe gen4 x16 per direction
    mig_write_bw=TPU_V5E.mig_bw,
    host_internal_bw=204e9,            # 8-channel DDR5 host, far above PCIe
    link_bw=TPU_V5E.link_bw,
    dma_overlap=0.5,                   # double-buffered paged-decode window
    mig_overhead=TPU_V5E.mig_overhead,
    fast_bytes=TPU_V5E.fast_bytes,
)
