"""The unified planner: ``runtime.plan(workload, cost_model, fast_bytes)``
for both training and serving, returning one serializable ``PlacementPlan``.

The machine is a ``CostModel`` (runtime/costmodel.py); a legacy ``HWSpec``
passed positionally is upgraded in place via ``CostModel.from_hw`` (the
upgraded model simulates identically), and the deprecated ``hw=`` keyword
still works behind a warning.  ``objective`` selects what the measured sweep
optimizes: ``"bytes"`` (default) keeps the legacy byte-domain clock and its
golden plans byte-stable; ``"latency"`` selects the candidate whose recorded
per-step traffic the CostModel prices fastest — and, for serving, also
auditions the ``alpha_migration`` policy against the default, since holding
the read split at the bandwidth-optimal alpha can win in the time domain
while losing in the byte domain.

Training (paper §4.4) — given one profiled training step:
  1. compute RS(MI), Data(MI), T(MI) for every candidate interval,
  2. prune by the paper's two constraints,
       space (Eq. 1):  Data(MI) < S - RS(MI)
       time  (Eq. 2):  T(MI)    > (S - RS(MI)) / BW
  3. measure surviving candidates through the registered policy (the runtime
     system would use one real training step per candidate — same procedure,
     measured instead of simulated), resolving Case 3 by test-and-trial,
  4. return the sweet spot.

Serving — the same Eq. 1/2 restated per decode *token*: the reserve pool RS
is the set of open (still-filling) KV blocks, the candidates are prefetch
look-aheads, and the per-slot hot windows are sized from each slot's own
decode schedule.

The resulting ``PlacementPlan`` subsumes the legacy training ``Plan`` and
serving ``ServePlan``: it drives the JAX offload engine
(``core/offload.from_plan`` — ``mi`` is the layer-scan block size), the
serving engine (``serve/engine.ContinuousBatcher`` — ``cold_len`` /
``cold_len_slot`` / ``page_tokens``), and the benchmarks; ``to_json`` /
``from_json`` round-trip it bit-identically for storage beside benchmark
artifacts.  Where each paper equation lands in the code is mapped in
``docs/RUNTIME_API.md``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core import warn_deprecated
from repro.core.hardware import HWSpec
from repro.runtime.costmodel import CostModel, CostReport
from repro.runtime.objects import (MemoryTier, TrainingWorkload, as_workload,
                                   tiers_from_hw)
from repro.runtime.policies import PlacementResult, get_policy, simulate

OBJECTIVES = ("bytes", "latency")


def _resolve_cost_model(cost_model, hw, caller: str) -> CostModel:
    """Collapse the machine arguments to one CostModel.  ``cost_model``
    (positional) accepts a CostModel or a legacy HWSpec (upgraded silently —
    they simulate identically); the old ``hw=`` keyword warns."""
    if hw is not None:
        if cost_model is not None:
            raise TypeError(f"runtime.{caller}() got both cost_model and "
                            "the deprecated hw=")
        warn_deprecated(f"runtime.{caller}(hw=...)",
                        f"runtime.{caller}(workload, cost_model, fast_bytes)",
                        stacklevel=4)
        cost_model = hw
    if cost_model is None:
        raise TypeError(f"runtime.{caller}() needs a machine: pass a "
                        "CostModel (or an HWSpec) as the second argument")
    return CostModel.from_hw(cost_model)


def _check_objective(objective: str, caller: str) -> None:
    if objective not in OBJECTIVES:
        raise ValueError(f"runtime.{caller}(objective={objective!r}): "
                         f"expected one of {OBJECTIVES}")


def _graph_fold(cm, tier_graph, fast_bytes):
    """Resolve the machine the simulations run on.  With a ``tier_graph``
    the policies see its two-tier fold (``TierGraph.hw_view`` — on the
    canonical fast/slow graph the fold IS ``cm``, value for value), and a
    missing ``fast_bytes`` defaults to the compute node's capacity."""
    if tier_graph is None:
        return cm, fast_bytes
    view = tier_graph.hw_view(cm)
    if fast_bytes is None:
        cap = tier_graph.capacity(view.compute)
        if cap is None:
            raise ValueError("plan(tier_graph=...) needs fast_bytes when "
                             "the compute tier is unbounded")
        fast_bytes = float(cap)
    return view, fast_bytes


def _graph_dict(tier_graph, cm, fast_bytes):
    """The plan's serialized topology: None for the canonical two-tier
    graph (already described by ``tiers``/``cost_model``; keeps golden
    JSONs byte-identical), the full node/edge dict otherwise."""
    if tier_graph is None or tier_graph.matches_two_tier(cm, fast_bytes):
        return None
    return tier_graph.to_dict()


# ================================================================ candidates ==

@dataclass
class Candidate:
    """A training migration-interval candidate."""
    mi: int
    rs: float
    data: float          # max prefetch bytes over intervals
    t: float             # min compute seconds over intervals
    space_ok: bool
    time_ok: bool
    sim: Optional[PlacementResult] = None


@dataclass
class ServeCandidate:
    """A serving look-ahead candidate."""
    lookahead: int
    hot_window: int          # tokens of KV kept fast per slot
    prefetch_bytes: float    # per-step slow->fast demand at this look-ahead
    t_token: float           # all-fast decode step time
    space_ok: bool
    time_ok: bool
    sim: Optional[PlacementResult] = None


# ====================================================================== plan ==

@dataclass
class PlacementPlan:
    """The one tiering decision both runtimes consume.

    ``kind`` selects which half is meaningful: training plans carry ``mi``
    (migration interval in timeline steps) and the Case-3 resolution;
    serving plans carry the hot window / look-ahead / per-slot windows.
    ``slot_hot_windows`` refines the single global window per *slot*: each
    slot's window is sized from its own decode schedule (the byte-seconds its
    KV objects occupy in the trace), so a slot serving short requests never
    pins the same hot budget as one serving long ones.  ``page_tokens`` is
    the page granularity those per-slot boundaries are quantized to — the
    unit the paged decode kernel and the PageTable move.
    """
    kind: str = "serving"            # "training" | "serving"
    policy: str = "sentinel"
    fast_bytes: float = 0.0
    rs: float = 0.0
    # ---- training half ----
    mi: int = 0
    stall_on_case3: bool = True
    steps_used: int = 0              # "p, m & t" budget consumed (Table 3)
    # ---- serving half ----
    hot_window: int = 0
    lookahead: int = 0
    slot_hot_windows: Optional[List[int]] = None
    page_tokens: int = 0
    # per-step prompt-token budget the engine's prefill scheduler drains
    # before each decode dispatch (0 = one-shot prefill, the legacy
    # behavior; the key is dropped from the JSON then, keeping every
    # earlier golden plan byte-identical)
    prefill_chunk_tokens: int = 0
    # slot_devices[s] is the decode shard owning batch slot s
    # (``plan_serving(..., decode_devices=N)``).  None on single-device
    # plans, and the key is dropped from the JSON then — the trivial
    # placement folds away and every earlier golden plan stays
    # byte-identical.
    slot_devices: Optional[List[int]] = None
    # ---- multi-tenant accounting (None on single-tenant plans) ----
    # slot_tenants[s] names the tenant owning batch slot s (the engine admits
    # a request only into its own tenant's slots); tenant_quotas are the
    # guaranteed fast-share fractions the windows were sized under;
    # tenant_fast_bytes / tenant_violations echo the winning simulation's
    # per-tenant peaks and quota-violation counts (the SLO report card).
    slot_tenants: Optional[List[str]] = None
    tenant_quotas: Optional[Dict[str, float]] = None
    tenant_fast_bytes: Optional[Dict[str, float]] = None
    tenant_violations: Optional[Dict[str, int]] = None
    # ---- shared ----
    tiers: Optional[List[MemoryTier]] = None
    candidates: List[Any] = field(default_factory=list)
    sim: Optional[PlacementResult] = None
    # ---- time-domain half (populated by objective="latency" only; the
    # bytes default serializes without these keys, keeping golden plan JSON
    # from earlier PRs byte-identical) ----
    objective: str = "bytes"
    cost_model: Optional[CostModel] = None
    predicted_step_times: Optional[List[float]] = None
    # ---- tier-graph half (``runtime.plan(..., tier_graph=)``): the
    # serialized memory topology the plan was made for.  None on two-tier
    # plans — the canonical fast/slow graph is already fully described by
    # ``tiers``/``cost_model``, and dropping the key keeps every golden
    # plan JSON byte-identical. ----
    tier_graph: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------ queries --
    @property
    def throughput(self) -> float:
        return self.sim.throughput if self.sim else 0.0

    @property
    def decode_throughput(self) -> float:
        return self.sim.decode_throughput if self.sim else 0.0

    @property
    def predicted_time(self) -> float:
        """CostModel-predicted seconds for the whole timeline (0.0 on
        bytes-objective plans, which carry no prediction)."""
        return sum(self.predicted_step_times) if self.predicted_step_times \
            else 0.0

    @property
    def predicted_decode_throughput(self) -> float:
        """Predicted tokens/second under the plan's CostModel."""
        t = self.predicted_time
        return self.sim.tokens / t if (self.sim and t) else 0.0

    def cold_len(self, max_seq: int) -> int:
        """Cold-prefix length for a ``max_seq``-token cache buffer (global
        boundary — the concat path)."""
        return max(0, max_seq - self.hot_window)

    def slot_window(self, slot: int) -> int:
        """Hot-window tokens for ``slot`` (falls back to the global window)."""
        if not self.slot_hot_windows:
            return self.hot_window
        return self.slot_hot_windows[slot % len(self.slot_hot_windows)]

    def cold_len_slot(self, slot: int, seq_len: int,
                      page_tokens: Optional[int] = None) -> int:
        """Cold boundary for ``slot`` at its *current* sequence length,
        quantized down to page granularity: tokens older than the slot's own
        hot window, in whole pages.  Monotone in ``seq_len``, so within one
        residency a slot's boundary only ever advances.  ``page_tokens``
        overrides the plan's page size (the engine adjusts it to divide its
        cache buffer)."""
        cold = max(0, seq_len - self.slot_window(slot))
        page = max(1, page_tokens if page_tokens else self.page_tokens)
        return (cold // page) * page

    # --------------------------------------------------------------- json --
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for c, cd in zip(self.candidates, d["candidates"]):
            cd["_type"] = "interval" if isinstance(c, Candidate) else "serve"
        if self.objective == "bytes":
            # legacy serialization: bytes-objective plans predate the time
            # domain, and their golden JSON must stay byte-for-byte stable
            del d["objective"], d["cost_model"], d["predicted_step_times"]
        elif self.cost_model is not None:
            d["cost_model"] = self.cost_model.to_dict()   # inf -> None
        if self.tier_graph is None:
            # two-tier plans predate the graph; dropping the key keeps their
            # golden JSON byte-identical
            del d["tier_graph"]
        if not self.prefill_chunk_tokens:
            # one-shot prefill predates the chunk knob — same golden-JSON
            # stability pattern as tier_graph
            del d["prefill_chunk_tokens"]
        if self.slot_devices is None:
            # single-device plans predate slot placement — same pattern
            del d["slot_devices"]
        return d

    def to_json(self) -> str:
        """Deterministic serialization: same plan -> same bytes (the golden
        round-trip contract ``from_json(p.to_json()).to_json() == p.to_json()``
        guards against silent planner drift)."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)

    @classmethod
    def from_dict(cls, d: dict) -> "PlacementPlan":
        d = dict(d)
        cands = []
        for cd in d.get("candidates") or []:
            cd = dict(cd)
            typ = cd.pop("_type", "serve")
            cd["sim"] = _result_from_dict(cd.get("sim"))
            cands.append((Candidate if typ == "interval"
                          else ServeCandidate)(**cd))
        d["candidates"] = cands
        d["sim"] = _result_from_dict(d.get("sim"))
        if d.get("tiers") is not None:
            d["tiers"] = [MemoryTier(**t) for t in d["tiers"]]
        if d.get("cost_model") is not None:
            d["cost_model"] = CostModel.from_dict(d["cost_model"])
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "PlacementPlan":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------- deltas --
    def digest(self) -> str:
        """Content digest of the serialized plan — the identity a
        ``PlanDelta`` is pinned against, so deltas can only be applied to
        the exact plan they were diffed from (and in emission order)."""
        return hashlib.sha1(self.to_json().encode()).hexdigest()[:16]

    def apply_delta(self, delta: "PlanDelta") -> "PlacementPlan":
        """Apply an incremental re-plan.  The contract (pinned by
        tests/test_online_replan.py): for any two plans,
        ``old.apply_delta(plan_delta(old, new)).to_json() == new.to_json()``
        byte-for-byte — an applied delta IS the fresh plan."""
        if delta.base_digest and delta.base_digest != self.digest():
            raise ValueError(
                f"delta (step {delta.step}) was diffed against plan "
                f"{delta.base_digest}, not {self.digest()} — apply deltas "
                "in emission order")
        d = self.to_dict()
        for k in delta.removed:
            d.pop(k, None)
        # normalize through JSON so an in-memory delta and one reloaded from
        # disk apply identically (tuples -> lists, int dict keys -> str; the
        # from_dict path re-types both forms)
        d.update(json.loads(json.dumps(delta.changes)))
        return PlacementPlan.from_dict(d)


@dataclass
class PlanDelta:
    """An incremental re-plan: only the serialized plan fields that changed.

    ``changes`` maps top-level ``PlacementPlan.to_dict()`` keys to their new
    serialized values; ``removed`` lists keys the new plan no longer
    serializes (an objective downgrade).  ``base_digest`` pins the plan the
    delta was diffed against — ``apply_delta`` refuses a mismatched base, so
    a delta stream replays deterministically or not at all.  ``step`` is the
    decode step the online replanner emitted it at and ``reason`` the drift
    trigger (``docs/RUNTIME_API.md#online-re-planning``)."""
    step: int = 0
    reason: str = ""
    base_digest: str = ""
    changes: Dict[str, Any] = field(default_factory=dict)
    removed: List[str] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {"step": self.step, "reason": self.reason,
                "base_digest": self.base_digest,
                "changes": self.changes, "removed": list(self.removed)}

    def to_json(self) -> str:
        """Deterministic bytes: ``from_json(d.to_json()).to_json()`` is
        byte-identical (the same round-trip contract plans carry)."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)

    @classmethod
    def from_dict(cls, d: dict) -> "PlanDelta":
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "PlanDelta":
        return cls.from_dict(json.loads(s))


def plan_delta(old: PlacementPlan, new: PlacementPlan, *, step: int = 0,
               reason: str = "") -> Optional[PlanDelta]:
    """Diff two plans into an incremental delta (None when nothing changed —
    traffic moved but the planner landed on the same placement)."""
    od, nd = old.to_dict(), new.to_dict()
    changes = {k: v for k, v in nd.items() if k not in od or od[k] != v}
    removed = sorted(k for k in od if k not in nd)
    if not changes and not removed:
        return None
    return PlanDelta(step=step, reason=reason, base_digest=old.digest(),
                     changes=changes, removed=removed)


def _result_from_dict(d: Optional[dict]) -> Optional[PlacementResult]:
    if d is None:
        return None
    d = dict(d)
    d["cases"] = {int(k): v for k, v in d.get("cases", {}).items()}
    return PlacementResult(**d)


# ========================================================== training planner ==

def interval_stats(profile, mi: int, hw: HWSpec):
    """(Data(MI), T(MI)) per interval: prefetch bytes needed by each interval
    and compute time available in the preceding one."""
    steps = profile.num_steps
    acts = [o for o in profile.objects if o.accesses]
    data_per: Dict[int, float] = {}
    t_per: Dict[int, float] = {}
    n_int = (steps + mi - 1) // mi
    for i in range(n_int):
        lo, hi = i * mi, min((i + 1) * mi, steps)
        t_per[i] = sum(max(profile.step_flops(s) / hw.peak_flops,
                           profile.step_bytes(s) / hw.fast_bw)
                       for s in range(lo, hi))
        data_per[i] = 0.0
    # the final boundary step (embedding grad + optimizer) touches every
    # weight/moment, but elementwise: it streams tile-by-tile and never needs
    # them resident together (ZeRO-Offload-style), so it is exempt from the
    # Eq. 1 capacity constraint (it still costs migration *time*).
    opt_step = steps - 1
    for o in acts:
        if o.kind == "weight" or o.lifetime >= 2:
            touched = sorted({a // mi for a in o.accesses if a != opt_step})
            for i in touched:
                # fetched for interval i (unless it was just produced there)
                if o.kind == "weight" or o.birth // mi != i:
                    data_per[i] += o.size
    return data_per, t_per


def enumerate_candidates(profile, hw: HWSpec, fast_bytes: float,
                         max_mi: Optional[int] = None) -> List[Candidate]:
    out = []
    steps = profile.num_steps
    max_mi = max_mi or max(1, steps // 2)
    for mi in range(1, max_mi + 1):
        rs = profile.rs_bytes(mi)
        data_per, t_per = interval_stats(profile, mi, hw)
        data = max(data_per.values()) if data_per else 0.0
        t = min(t_per.values()) if t_per else 0.0
        space_ok = data < fast_bytes - rs
        time_ok = t > data / hw.mig_bw      # tight form of Eq. 2 (see note)
        out.append(Candidate(mi, rs, data, t, space_ok, time_ok))
    return out


def plan_training(workload, cost_model=None, fast_bytes: float = None, *,
                  policy: str = "sentinel_mi", max_mi: Optional[int] = None,
                  sim_all: bool = False, objective: str = "bytes",
                  tier_graph=None, hw=None) -> PlacementPlan:
    """Pick the optimal migration interval.

    Note on Eq. 2: the paper states T(MI) > (S - RS)/BW — the worst case of a
    full fast-memory refill.  We prune with the tighter per-interval form
    T(MI) > Data(MI)/BW (a superset of the paper's surviving candidates) and
    let the measured sweep decide, exactly as the paper's runtime does.

    ``objective="latency"`` keeps the same candidate pool but selects the MI
    whose recorded traffic the CostModel prices fastest (migration copies
    contend with the training step's own reads there, which the byte-domain
    clock cannot see).
    """
    cm = _resolve_cost_model(cost_model, hw, "plan_training")
    _check_objective(objective, "plan_training")
    sim_hw, fast_bytes = _graph_fold(cm, tier_graph, fast_bytes)
    wl = as_workload(workload)
    profile = getattr(wl, "profile", None)
    if profile is None:                      # protocol workloads / timelines
        profile = wl.timeline().source
    if profile is None or not hasattr(profile, "num_periods"):
        raise TypeError("plan_training needs a workload whose timeline "
                        "sources a TraceProfile (candidate enumeration reads "
                        "the profiled objects)")
    pol = get_policy(policy)
    cands = enumerate_candidates(profile, sim_hw, fast_bytes, max_mi)
    survivors = [c for c in cands if c.space_ok and c.time_ok]
    if not survivors:                        # fall back: least-bad candidates
        survivors = [c for c in cands if c.space_ok] or cands
    steps_used = 1                           # the profiling step
    best: Optional[Candidate] = None
    best_pred: Optional[CostReport] = None
    pool = survivors if not sim_all else cands
    for c in pool:
        c.sim = pol.simulate(wl, sim_hw, fast_bytes, mi=c.mi)
        steps_used += 1 + c.sim.detail.get("tt_steps_used", 0)
        if objective == "latency":
            pred = cm.price_result(c.sim, tier_graph=tier_graph)
            if best is None or pred.time < best_pred.time:
                best, best_pred = c, pred
        elif best is None or c.sim.time < best.sim.time:
            best = c
    stall = best.sim.detail.get("tt_choice", "stall") != "slow-access"
    return PlacementPlan(
        kind="training", policy=policy, fast_bytes=fast_bytes,
        rs=best.sim.detail.get("rs", 0.0), mi=best.mi, stall_on_case3=stall,
        steps_used=steps_used,
        tiers=list(tier_graph.tiers) if tier_graph is not None
        else tiers_from_hw(cm, fast_bytes),
        candidates=cands, sim=best.sim, objective=objective,
        cost_model=cm if objective == "latency" else None,
        predicted_step_times=list(best_pred.step_times)
        if best_pred else None,
        tier_graph=_graph_dict(tier_graph, cm, fast_bytes))


def mi_to_periods(profile, mi: int) -> int:
    """Convert a timeline-step MI to layer-scan block size (periods per block)
    for the offload engine.  Timeline steps map 1:1 to periods inside the
    forward/backward regions."""
    return max(1, min(mi, profile.num_periods))


# =========================================================== serving planner ==
# Decode-phase planning: the paper's Eq. 1/2 restated per *token* instead of
# per migration interval.  During decode the timeline unit is one token step,
# the reserve pool RS is the set of open (still-filling) KV blocks, and the
# prefetchable data per step is bounded by one token's compute time times the
# migration bandwidth:
#
#   space (Eq. 1 per-token):  hot_bytes = B * W * kv_tok < S - RS_serve
#   time  (Eq. 2 per-token):  t_token   > prefetch_bytes(L) / BW_mig
#
# where W is the per-slot hot window (tokens kept in fast memory) and L the
# look-ahead (token steps of prefetch lead).  Like the training planner, the
# candidates surviving both constraints are measured on the simulator and the
# sweet spot wins.


def slot_kv_weights(trace) -> List[float]:
    """Per-slot share of KV byte-seconds over the timeline: how much cache
    each slot's decode schedule actually keeps alive.  The per-slot analogue
    of the paper's per-object lifetime profile.

    Sharing-aware: blocks aliasing one physical allocation (equal
    ``shared_key``) contribute their byte-seconds once, split evenly across
    the sharers' slots — a tenant does not get a bigger hot window for
    holding a reference to the same system prompt everyone else holds."""
    w = [0.0] * max(1, trace.num_slots)
    group_size: dict = {}
    for o in trace.objects:
        k = getattr(o, "shared_key", None)
        if k is not None:
            group_size[k] = group_size.get(k, 0) + 1
    for o in trace.objects:
        k = getattr(o, "shared_key", None)
        share = group_size.get(k, 1) if k is not None else 1
        w[o.slot % len(w)] += o.bytes * (o.death - o.birth + 1) / share
    total = sum(w) or 1.0
    return [x / total for x in w]


def serve_token_stats(trace, hw: HWSpec) -> tuple:
    """(t_token, read_bytes): all-fast decode-step time and mean per-step KV
    read volume over the timeline — the serving analogue of interval_stats."""
    steps = max(1, trace.num_steps)
    read_bytes = sum(o.bytes * len(o.accesses) for o in trace.objects) / steps
    act = sum(trace.active.get(t, 0) for t in range(steps)) / steps
    flops = act * trace.flops_per_token
    bw_bytes = read_bytes + trace.weight_bytes + act * trace.num_layers \
        * trace.kv_token_bytes
    return max(flops / hw.peak_flops, bw_bytes / hw.fast_bw), read_bytes


def _tenant_knobs(wl, policy: str) -> dict:
    """Per-tenant simulation knobs for a tenanted workload: quotas turn on
    the violation accounting for any event-driven policy (quota-blind ones
    are *measured* against the same guarantees ``sentinel_slo`` enforces);
    the slack ordering only feeds the SLO policy."""
    from repro.runtime.policies import PlacementPolicy
    quotas = getattr(wl, "tenant_quotas", None)
    cls = get_policy(policy)
    if not quotas or \
            cls.simulate.__func__ is not PlacementPolicy.simulate.__func__:
        return {}
    knobs = {"tenant_quotas": dict(sorted(quotas.items()))}
    slack = getattr(wl, "tenant_slack", None)
    if slack and policy == "sentinel_slo":
        knobs["tenant_slack"] = dict(sorted(slack.items()))
    return knobs


def validate_slot_devices(slot_devices, slots: int,
                          decode_devices: int) -> List[int]:
    """Check a slot->decode-shard mapping's geometry: one entry per batch
    slot, every entry a valid shard index.  Shared by ``plan_serving`` (at
    emission) and ``DisaggregatedEngine`` (at adoption), so a malformed
    placement is rejected identically at both ends."""
    sd = list(slot_devices)
    if len(sd) != slots:
        raise ValueError(f"slot_devices has {len(sd)} entries for "
                         f"{slots} batch slots")
    for s, d in enumerate(sd):
        if not isinstance(d, int) or isinstance(d, bool) \
                or not 0 <= d < decode_devices:
            raise ValueError(f"slot_devices[{s}] = {d!r}: expected a shard "
                             f"index in [0, {decode_devices})")
    return sd


def pack_slots(weights: Sequence[float], decode_devices: int,
               slot_tenants: Optional[Sequence[str]] = None) -> List[int]:
    """Tenant-aware LPT bin-packing of slots onto decode shards.

    Heaviest slot first (weight = planned hot-window bytes), each slot lands
    on the shard minimizing (same-tenant slots already there, load, index):
    load balance with an anti-affinity tie-break that spreads a tenant's
    slots across shards, so one device failure cannot take out a whole
    tenant.  Deterministic — equal keys resolve by slot then shard index."""
    load = [0.0] * decode_devices
    tenant_count = [dict() for _ in range(decode_devices)]
    out = [0] * len(weights)
    order = sorted(range(len(weights)), key=lambda s: (-weights[s], s))
    for s in order:
        tn = slot_tenants[s] if slot_tenants else None
        d = min(range(decode_devices),
                key=lambda i: (tenant_count[i].get(tn, 0) if tn is not None
                               else 0, load[i], i))
        out[s] = d
        load[d] += weights[s]
        if tn is not None:
            tenant_count[d][tn] = tenant_count[d].get(tn, 0) + 1
    return out


def _price_packing(cm: CostModel, graph, traffic, slot_devices, weights,
                   n_devices: int, kv_row: float,
                   flops_per_token: float) -> CostReport:
    """Price a slot->shard packing on the mesh graph: each shard's share of
    every step's reads/compute (proportional to the hot-window bytes it
    hosts) becomes its own HBM pipe, the prefill group's add-on runs as the
    prefill device's concurrent pipe, and the prefill->shard KV streams
    ride the dev<->dev edges — so a skewed packing surfaces as a slower
    slowest shard and the latency objective can reject it."""
    total = sum(weights) or 1.0
    frac = [sum(w for s, w in enumerate(weights)
                if slot_devices[s] == d) / total for d in range(n_devices)]
    prefill = f"dev{n_devices}"
    dev_series, edge_series = [], []
    for tr in traffic:
        per_dev = {}
        flows = {}
        # admitted-prefill tokens behind this step's KV stream: the flops
        # channel attributes them when the trace prices compute; the admit
        # byte channel (extra_fast = computed tokens x KV row) covers
        # flops-less traces
        if flops_per_token:
            ptok = tr.extra_flops / flops_per_token
        elif kv_row:
            ptok = tr.extra_fast / kv_row
        else:
            ptok = 0.0
        for d in range(n_devices):
            f = frac[d]
            per_dev[f"dev{d}"] = dataclasses.replace(
                tr, flops=tr.flops * f, fast_read=tr.fast_read * f,
                slow_read=tr.slow_read * f, demand_read=tr.demand_read * f,
                mig_in=tr.mig_in * f, mig_out=tr.mig_out * f,
                migs=tr.migs * f, extra_flops=0.0, extra_fast=0.0,
                prefill_flops=0.0, prefill_read=0.0)
            flow = ptok * kv_row * f
            if flow:
                flows[(prefill, f"dev{d}")] = flow
        # the prefill group's own pipe: prompt compute runs concurrently
        # with the shards, so the prefill add-on is one more max() arm
        # instead of serializing after the step
        per_dev[prefill] = dataclasses.replace(
            tr, flops=0.0, fast_read=0.0, slow_read=0.0, demand_read=0.0,
            mig_in=0.0, mig_out=0.0, migs=0.0, stall=0.0)
        dev_series.append(per_dev)
        edge_series.append(flows)
    return cm.price_on_graph(traffic, graph, edge_series,
                             device_traffic=dev_series)


def plan_serving(workload, cost_model=None, fast_bytes: float = None, *,
                 policy: Optional[str] = None,
                 lookaheads: Sequence[int] = (2, 4, 8, 16, 32),
                 objective: str = "bytes", tier_graph=None,
                 prefill_chunk_tokens: int = 0,
                 decode_devices: int = 1, disagg: bool = False,
                 hw=None) -> PlacementPlan:
    """Pick the hot window and prefetch look-ahead for serving-time tiering.

    On a multi-tenant workload (one exposing ``tenants`` — see
    ``MultiTenantWorkload``) the default policy is ``sentinel_slo``, the
    per-slot hot windows are sized inside each tenant's guaranteed share,
    and the plan carries the per-tenant accounting
    (``slot_tenants`` / ``tenant_quotas`` / ``tenant_fast_bytes`` /
    ``tenant_violations``).

    ``objective="latency"`` selects by CostModel-predicted decode time and
    (when no explicit policy is forced and the workload is untenanted) also
    auditions ``alpha_migration`` against the default policy — every
    byte-objective candidate stays in the pool, so the latency winner is
    never priced slower than the bytes winner.  Tenanted workloads keep
    ``sentinel_slo`` (the SLO guarantees outrank raw predicted time).

    ``prefill_chunk_tokens > 0`` plans for the engine's *chunked* prefill:
    the prefill add-on is priced under the step's pipe maximum (chunks
    interleave with decode) instead of serializing after it, and the knob
    rides in the plan for ``ContinuousBatcher`` to adopt.

    ``disagg=True`` plans for the disaggregated engine and rejects knob
    combinations it cannot execute up front — chunked prefill interleaves
    prompt chunks with decode on ONE device, the opposite of prefill/decode
    disaggregation, so ``prefill_chunk_tokens > 0`` raises here instead of
    at ``DisaggregatedEngine.__init__``.  ``decode_devices=N`` (N > 1,
    implies ``disagg``) additionally places slots onto decode shards: the
    plan gains ``slot_devices`` (tenant-aware LPT packing by planned
    hot-window bytes — see ``pack_slots``), the serialized ``tier_graph``
    becomes the (N+1)-device mesh (dev0..dev{N-1} decode shards, devN the
    prefill group), and under the latency objective competing packings are
    priced per shard via ``CostModel.price_on_graph`` so a skewed packing
    loses to a balanced one."""
    cm = _resolve_cost_model(cost_model, hw, "plan_serving")
    _check_objective(objective, "plan_serving")
    if decode_devices < 1:
        raise ValueError(f"plan_serving(decode_devices={decode_devices}): "
                         "need at least one decode device")
    disagg = disagg or decode_devices > 1
    if disagg and prefill_chunk_tokens:
        raise ValueError(
            "plan_serving(disagg=True) cannot plan chunked prefill "
            f"(prefill_chunk_tokens={prefill_chunk_tokens}): the "
            "disaggregated engine runs whole prompts on the prefill group "
            "and would reject the plan at DisaggregatedEngine.__init__")
    sim_hw, fast_bytes = _graph_fold(cm, tier_graph, fast_bytes)
    wl = as_workload(workload)
    trace = getattr(wl, "trace", None)
    if trace is None:                        # protocol workloads / timelines
        trace = wl.timeline().source
    if trace is None or not hasattr(trace, "num_slots"):
        raise TypeError("plan_serving needs a workload whose timeline "
                        "sources a ServeTrace (window sizing reads the slot "
                        "geometry)")
    tenants = getattr(wl, "tenants", None)
    forced_policy = policy is not None
    policy = policy or ("sentinel_slo" if tenants else "sentinel")
    knobs = _tenant_knobs(wl, policy)
    rs = trace.rs_bytes()
    budget = max(0.0, fast_bytes - rs)
    kv_tok_all = trace.num_layers * trace.kv_token_bytes
    slots = max(1, trace.num_slots)
    # floor: the open, still-filling block per slot is fast by construction
    # (it IS the reserve pool), so the hot window is never below one block
    hot_window = max(trace.block_tokens,
                     int(budget / (slots * kv_tok_all))) if kv_tok_all else 0
    t_token, _ = serve_token_stats(trace, sim_hw)
    cold_bytes = max(0.0, trace.peak_kv_bytes() - budget)
    # Eq. 1 per-token: the hot windows plus the reserve pool must fit (the
    # floor above can violate this when fast memory is tiny)
    space_ok = rs + slots * hot_window * kv_tok_all <= fast_bytes

    cands: List[ServeCandidate] = []
    for la in sorted(set(lookaheads)):
        # history blocks re-read every history_period steps: within a
        # look-ahead of L steps, L/period of the cold set must be prefetched,
        # against L steps' worth of migration bandwidth (Eq. 2 per-token)
        prefetch = cold_bytes * min(1.0, la / max(1, trace.history_period))
        cands.append(ServeCandidate(la, hot_window, prefetch, t_token,
                                    space_ok=space_ok,
                                    time_ok=t_token * la * sim_hw.mig_bw
                                    >= prefetch))
    # measure survivors on the simulator (fall back to everything when the
    # constraints kill all candidates, mirroring the training planner)
    pool = [c for c in cands if c.space_ok and c.time_ok] or cands
    best: Optional[ServeCandidate] = None
    best_pred: Optional[CostReport] = None
    win_policy, win_sim = policy, None
    for c in pool:
        c.sim = simulate(wl, sim_hw, fast_bytes, policy,
                         lookahead=c.lookahead, **knobs)
        if objective == "latency":
            pred = cm.price_result(c.sim, tier_graph=tier_graph,
                                   chunked_prefill=prefill_chunk_tokens > 0)
            if best is None or pred.time < best_pred.time:
                best, best_pred, win_sim = c, pred, c.sim
        elif best is None or \
                c.sim.decode_throughput > best.sim.decode_throughput:
            best = c
    if objective == "latency" and not forced_policy and not tenants:
        # audition alpha_migration over the same pool: it can only win under
        # the time-domain clock (it deliberately leaves cold-tail reads
        # slow), so the byte-domain sweep would never surface it
        for c in pool:
            alt = simulate(wl, sim_hw, fast_bytes, "alpha_migration",
                           lookahead=c.lookahead, **knobs)
            pred = cm.price_result(alt, tier_graph=tier_graph,
                                   chunked_prefill=prefill_chunk_tokens > 0)
            if pred.time < best_pred.time:
                best, best_pred = c, pred
                win_policy, win_sim = "alpha_migration", alt
    if win_sim is None:
        win_sim = best.sim

    # Eq. 1 refined per slot: distribute the hot-token budget in proportion
    # to each slot's own decode schedule (KV byte-seconds), floor one block
    # (its open block is the reserve pool), quantized to block==page units.
    blk = max(1, trace.block_tokens)
    budget_tokens = budget / kv_tok_all if kv_tok_all else 0.0
    weights = slot_kv_weights(trace)
    slot_tenants = getattr(wl, "slot_tenants", None)
    quotas = getattr(wl, "tenant_quotas", None)
    if tenants and slot_tenants and quotas:
        # quota-partitioned sizing: each tenant's guaranteed token share is
        # split over its own slots by their decode schedules, so one
        # tenant's long-context burst can never widen another's windows away
        tenant_w = {tn: sum(w for s, w in zip(slot_tenants, weights)
                            if s == tn) or 1.0 for tn in set(slot_tenants)}
        slot_windows = []
        for s, (tn, w) in enumerate(zip(slot_tenants, weights)):
            share = budget_tokens * quotas.get(tn, 0.0) * (w / tenant_w[tn])
            slot_windows.append(max(blk, (int(share) // blk) * blk))
    else:
        slot_windows = [max(blk, (int(budget_tokens * w) // blk) * blk)
                        for w in weights]

    # ---- slot -> decode-shard placement (decode_devices > 1) ----
    slot_devices = None
    graph_out = _graph_dict(tier_graph, cm, fast_bytes)
    if decode_devices > 1:
        from repro.runtime.tiergraph import TierGraph
        mesh = TierGraph.mesh(decode_devices + 1, cm,
                              fast_bytes / decode_devices)
        pack_weights = [w * kv_tok_all for w in slot_windows]
        st = list(slot_tenants) if tenants and slot_tenants else None
        slot_devices = pack_slots(pack_weights, decode_devices, st)
        traffic = getattr(win_sim, "step_traffic", None)
        if objective == "latency" and traffic:
            # audition the balanced packing against a contiguous split —
            # the per-shard HBM pipes and prefill->shard streams make a
            # skewed packing visibly slower, which the byte clock cannot see
            contiguous = [min(decode_devices - 1,
                              s * decode_devices // len(pack_weights))
                          for s in range(len(pack_weights))]
            kv_row = trace.num_layers * trace.kv_token_bytes
            fpt = getattr(trace, "flops_per_token", 0.0)
            priced = sorted(
                (_price_packing(cm, mesh, traffic, p, pack_weights,
                                decode_devices, kv_row, fpt).time, i, p)
                for i, p in enumerate([slot_devices, contiguous]))
            slot_devices = priced[0][2]
        slot_devices = validate_slot_devices(slot_devices,
                                             len(slot_windows),
                                             decode_devices)
        if graph_out is None:
            graph_out = mesh.to_dict()

    return PlacementPlan(
        kind="serving", policy=win_policy, fast_bytes=fast_bytes, rs=rs,
        hot_window=best.hot_window, lookahead=best.lookahead,
        slot_hot_windows=slot_windows, page_tokens=blk,
        prefill_chunk_tokens=int(prefill_chunk_tokens),
        slot_devices=slot_devices,
        slot_tenants=list(slot_tenants) if tenants and slot_tenants else None,
        tenant_quotas=dict(sorted(quotas.items()))
        if tenants and quotas else None,
        tenant_fast_bytes=dict(win_sim.tenant_fast_bytes) or None
        if tenants else None,
        tenant_violations=dict(win_sim.tenant_violations)
        if tenants and win_sim.tenant_violations else None,
        tiers=list(tier_graph.tiers) if tier_graph is not None
        else tiers_from_hw(cm, fast_bytes),
        candidates=cands, sim=win_sim,
        objective=objective,
        cost_model=cm if objective == "latency" else None,
        predicted_step_times=list(best_pred.step_times)
        if best_pred else None,
        tier_graph=graph_out)


# ================================================================ entrypoint ==

def plan(workload, cost_model=None, fast_bytes: float = None, *,
         policy: Optional[str] = None, max_mi: Optional[int] = None,
         sim_all: bool = False,
         lookaheads: Sequence[int] = (2, 4, 8, 16, 32),
         objective: str = "bytes", tier_graph=None,
         prefill_chunk_tokens: int = 0,
         decode_devices: int = 1, disagg: bool = False,
         hw=None) -> PlacementPlan:
    """THE entry point: profile -> plan for any workload.

    ``workload`` is a training ``TraceProfile``, a serving ``ServeTrace``, a
    ``MultiTenantWorkload``, or a ``Workload`` adapter.  ``cost_model`` is
    the machine — a ``CostModel``, or a legacy ``HWSpec`` upgraded in place
    (the deprecated ``hw=`` keyword warns).  ``policy`` names a registered
    placement policy (default: ``sentinel_mi`` for training, ``sentinel``
    for serving, ``sentinel_slo`` for multi-tenant serving); ``objective``
    is ``"bytes"`` (legacy clock, default) or ``"latency"`` (select by
    CostModel-predicted time); the remaining knobs apply to the matching
    planner half only.

    ``tier_graph`` plans against an arbitrary memory topology
    (``runtime.TierGraph``): the policies simulate on the graph's two-tier
    fold, pricing runs per edge, ``fast_bytes`` defaults to the compute
    node's capacity, and the plan serializes the graph when it is anything
    other than the canonical fast/slow pair (two-tier plans stay
    byte-identical to their goldens).
    """
    cm = _resolve_cost_model(cost_model, hw, "plan")
    wl = as_workload(workload)
    if wl.kind == "training":
        return plan_training(wl, cm, fast_bytes,
                             policy=policy or "sentinel_mi",
                             max_mi=max_mi, sim_all=sim_all,
                             objective=objective, tier_graph=tier_graph)
    return plan_serving(wl, cm, fast_bytes, policy=policy,
                        lookaheads=lookaheads, objective=objective,
                        tier_graph=tier_graph,
                        prefill_chunk_tokens=prefill_chunk_tokens,
                        decode_devices=decode_devices, disagg=disagg)
