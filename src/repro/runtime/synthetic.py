"""Deterministic synthetic workloads for both planner halves.

No RNG, no jax tracing: the same arguments always resolve to byte-identical
traces, which is what the golden-plan JSON tests and the unified benchmark
smoke (benchmarks/bench_runtime.py) depend on.

``synthetic_profile`` hand-builds a training ``TraceProfile`` with the
paper's object population (a majority of short-lived activations in the
reserve pool, long-lived residuals bridging forward->backward, weights read
in both passes and streamed by the optimizer).  ``synthetic_serve_trace``
resolves a deterministic request stream through the real serve-trace builder.
"""
from __future__ import annotations

from repro.core.profiler import (DataObject, LayerStats, TraceProfile,
                                 timeline_steps)


def synthetic_profile(num_periods: int = 4, unit: int = 1 << 20,
                      res_per_period: int = 3) -> TraceProfile:
    """A training profile with the paper's §3 object structure.

    Per forward period: ``res_per_period`` long-lived residuals (born in the
    forward step, re-read in the matching backward step — the migration
    candidates), a pile of short-lived temporaries (the reserve pool), and a
    weight block read in forward + backward and streamed at the optimizer
    boundary.  ``unit`` scales every byte count.
    """
    P = num_periods
    steps = timeline_steps(P)                 # 2P + 3
    prof = TraceProfile(num_periods=P, num_steps=steps)
    uid = 0

    def add(size, birth, death, kind, accesses):
        nonlocal uid
        o = DataObject(uid, int(size), birth, death, len(accesses), kind,
                       accesses=sorted(accesses))
        prof.objects.append(o)
        uid += 1
        return o

    opt = steps - 1
    for p in range(P):
        fwd, bwd = p + 1, 2 * P + 1 - p
        # weights: read in forward and backward, streamed by the optimizer
        add(4 * unit, 0, opt, "weight", [fwd, bwd, opt])
        # long-lived residuals: forward -> backward reuse (offload targets)
        for r in range(res_per_period):
            add(2 * unit, fwd, bwd, "activation", [fwd, bwd])
        # short-lived temporaries: born and consumed within the step
        for r in range(6):
            add(unit, fwd, fwd, "activation", [fwd])
            add(unit, bwd, bwd, "activation", [bwd])
    # head/loss boundary activation
    add(unit, P + 1, P + 1, "activation", [P + 1])

    for s in range(steps):
        touched = sum(o.size for o in prof.objects if s in o.accesses)
        flops = 40.0 * touched                # mildly compute-bound roofline
        prof.layers[s] = LayerStats(s, flops=flops,
                                    bytes_accessed=float(touched) + unit)
        prof.total_flops += flops
    for o in prof.objects:
        if o.kind != "activation":
            continue
        ls = prof.layers[max(o.birth, 0)]
        if o.lifetime <= 1:
            ls.produced_short += o.size
        else:
            ls.produced_long += o.size
            prof.layers[max(o.death, 0)].reads_long += o.size
    return prof


def synthetic_serve_trace(num_requests: int = 12, num_slots: int = 4,
                          num_layers: int = 8, kv_token_bytes: float = 4096,
                          weight_bytes: float = 50e6,
                          flops_per_token: float = 2e9):
    """The serving fixture trace: a deterministic mixed request stream
    resolved into per-slot per-layer KV-block objects."""
    from repro.core.hmsim import build_serve_trace, synthetic_requests
    reqs = synthetic_requests(num_requests)
    return build_serve_trace(reqs, num_slots=num_slots, num_layers=num_layers,
                             kv_token_bytes=kv_token_bytes,
                             weight_bytes=weight_bytes,
                             flops_per_token=flops_per_token)


def synthetic_shared_prefix_trace(num_tenants: int = 12, num_slots: int = 4,
                                  system_tokens: int = 64,
                                  user_tokens: int = 32,
                                  decode_tokens: int = 40,
                                  num_layers: int = 8,
                                  kv_token_bytes: float = 4096,
                                  weight_bytes: float = 50e6,
                                  flops_per_token: float = 2e9,
                                  shared: bool = True):
    """N tenants x one common system prompt — the multi-tenant serving
    workload for prefix sharing on the unified surface.

    Every request carries the same ``system_tokens``-token system prompt
    followed by a per-tenant user turn (deterministic jitter, no RNG).  With
    ``shared=True`` the system-prompt KV blocks are tagged as one physical
    allocation (``KVObject.shared_key``); ``shared=False`` builds the
    byte-for-byte identical stream *without* sharing — the matched baseline
    the --shared-prefix benchmark gate compares against."""
    from repro.core.hmsim import build_serve_trace
    reqs = []
    for i in range(num_tenants):
        p = system_tokens + user_tokens + (i * 17) % 33
        d = decode_tokens + (i * 11) % 17
        reqs.append((p, d, 0 if shared else i))
    return build_serve_trace(reqs, num_slots=num_slots, num_layers=num_layers,
                             kv_token_bytes=kv_token_bytes,
                             weight_bytes=weight_bytes,
                             flops_per_token=flops_per_token,
                             shared_prefix_tokens=system_tokens
                             if shared else 0)


def synthetic_multi_tenant_trace(chatty_requests: int = 10,
                                 bursty_requests: int = 4,
                                 slots_per_tenant: int = 2,
                                 chatty_quota: float = 0.45,
                                 bursty_quota: float = 0.55,
                                 system_tokens: int = 0,
                                 num_layers: int = 8,
                                 kv_token_bytes: float = 4096,
                                 weight_bytes: float = 50e6,
                                 flops_per_token: float = 2e9):
    """The adversarial multi-tenant serving mix: two tenants with opposite
    shapes competing for one fast tier, as a ``MultiTenantWorkload``.

      chatty   many small-context conversational turns (short prompts, short
               decodes) under a tight decode-latency SLO — its working set
               is far below its guaranteed share, but every block of it is
               latency-critical.
      bursty   few long-context requests (analytics-style prompts, long
               decodes) under a loose SLO — its KV floods any capacity-
               limited fast tier, which is exactly what starves the chatty
               tenant under tenant-blind placement.

    At 20% fast memory a quota-blind lifetime policy packs fast memory with
    the bursty tenant's high-reuse blocks past its share and serves part of
    the chatty tenant's entitled reads from slow memory (quota violations);
    ``sentinel_slo`` keeps the guarantee and degrades the bursty tenant
    instead.  ``system_tokens > 0`` additionally gives every request of both
    tenants one shared system prompt (``prefix_id`` 0 — one physical
    allocation platform-wide).  Deterministic: no RNG anywhere.
    """
    from repro.core.hmsim import build_serve_trace
    from repro.runtime.objects import MultiTenantWorkload, Tenant
    geometry = dict(num_slots=slots_per_tenant, num_layers=num_layers,
                    kv_token_bytes=kv_token_bytes, weight_bytes=weight_bytes,
                    flops_per_token=flops_per_token,
                    shared_prefix_tokens=system_tokens)
    chatty_reqs = [(system_tokens + 16 + (i * 7) % 13,
                    12 + (i * 5) % 9, 0)
                   for i in range(chatty_requests)]
    bursty_reqs = [(system_tokens + 224 + (i * 31) % 49,
                    40 + (i * 13) % 17, 0)
                   for i in range(bursty_requests)]
    tenants = [Tenant("chatty", fast_quota_frac=chatty_quota,
                      slo_slack=1.05, arrival=0),
               Tenant("bursty", fast_quota_frac=bursty_quota,
                      slo_slack=2.0, arrival=4)]
    traces = [build_serve_trace(chatty_reqs, **geometry),
              build_serve_trace(bursty_reqs, **geometry)]
    # prefix_id 0 is the platform-wide system prompt: the one key that is
    # genuinely shared across tenants (everything else stays namespaced)
    return MultiTenantWorkload(tenants, traces, shared_prefix_ids=(0,))


# --------------------------------------------------- drifting traffic --
# Piecewise-stationary workloads for the online re-planner
# (runtime/online.py): each is a sequence of stationary segments over one
# slot/KV geometry, with a distribution shift at every boundary.  Like
# everything else here they are RNG-free, so the golden re-plan trace and
# the clairvoyant-regret gates are byte-stable.

def synthetic_drift_tenant_flip(num_layers: int = 8,
                                kv_token_bytes: float = 4096):
    """Diurnal tenant-mix flip: chatty-dominated -> bursty-dominated ->
    chatty again.  The aggregate slot occupancy barely moves; what drifts is
    *which tenant* the read traffic belongs to — the mix signal the
    re-planner's per-tenant window shares exist to catch."""
    from repro.runtime.online import DriftSegment, DriftWorkload
    mk = lambda c, b: synthetic_multi_tenant_trace(
        chatty_requests=c, bursty_requests=b, num_layers=num_layers,
        kv_token_bytes=kv_token_bytes)
    return DriftWorkload("tenant_flip", (
        DriftSegment("chatty_heavy", mk(12, 2)),
        DriftSegment("bursty_heavy", mk(2, 8)),
        DriftSegment("chatty_back", mk(10, 2))))


def synthetic_drift_prompt_shift(num_slots: int = 4, num_layers: int = 8,
                                 kv_token_bytes: float = 4096,
                                 weight_bytes: float = 50e6,
                                 flops_per_token: float = 2e9):
    """Prompt-length shift: short conversational prompts -> long analytics
    prompts -> short again.  Per-step KV read volume grows ~5x in the middle
    segment, so the hot windows planned on short contexts starve."""
    from repro.core.hmsim import build_serve_trace
    from repro.runtime.online import DriftSegment, DriftWorkload
    geometry = dict(num_slots=num_slots, num_layers=num_layers,
                    kv_token_bytes=kv_token_bytes, weight_bytes=weight_bytes,
                    flops_per_token=flops_per_token)

    def seg(prompt):
        reqs = [(prompt + (i * 7) % 13, 40 + (i * 5) % 9)
                for i in range(2 * num_slots)]
        return build_serve_trace(reqs, **geometry)

    return DriftWorkload("prompt_shift", (
        DriftSegment("short_prompts", seg(64)),
        DriftSegment("long_prompts", seg(320)),
        DriftSegment("short_again", seg(64))))


def synthetic_drift_flash_crowd(slots_per_tenant: int = 2,
                                num_layers: int = 8,
                                kv_token_bytes: float = 4096,
                                weight_bytes: float = 50e6,
                                flops_per_token: float = 2e9):
    """Flash crowd: a tenant that is near-silent in the calm segments floods
    the system in the middle one.  While it sleeps its batch slots sit idle
    — the elastic-lending case: the replanner lends them to the busy tenant
    and reclaims them when the crowd arrives."""
    from repro.core.hmsim import build_serve_trace
    from repro.runtime.objects import MultiTenantWorkload, Tenant
    from repro.runtime.online import DriftSegment, DriftWorkload
    geometry = dict(num_slots=slots_per_tenant, num_layers=num_layers,
                    kv_token_bytes=kv_token_bytes, weight_bytes=weight_bytes,
                    flops_per_token=flops_per_token)
    tenants = lambda: [Tenant("steady", fast_quota_frac=0.5,
                              slo_slack=1.1, arrival=0),
                       Tenant("crowd", fast_quota_frac=0.5,
                              slo_slack=2.0, arrival=0)]

    def calm(n_steady):
        steady = [(96 + (i * 7) % 13, 16 + (i * 5) % 9, 0)
                  for i in range(n_steady)]
        crowd = [(32, 6, 0)]                 # one straggler, then silence
        return MultiTenantWorkload(tenants(), [
            build_serve_trace(steady, **geometry),
            build_serve_trace(crowd, **geometry)])

    def surge():
        steady = [(96 + (i * 7) % 13, 16 + (i * 5) % 9, 0)
                  for i in range(4)]
        crowd = [(160 + (i * 31) % 29, 24 + (i * 13) % 11, 0)
                 for i in range(12)]
        return MultiTenantWorkload(tenants(), [
            build_serve_trace(steady, **geometry),
            build_serve_trace(crowd, **geometry)])

    return DriftWorkload("flash_crowd", (
        DriftSegment("calm", calm(8)),
        DriftSegment("surge", surge()),
        DriftSegment("calm_again", calm(8))))


def synthetic_disagg_trace(num_slots: int = 4, num_layers: int = 8,
                           kv_token_bytes: float = 4096,
                           weight_bytes: float = 50e6,
                           flops_per_token: float = 2e9):
    """Prefill/decode phase drift: decode-steady traffic interrupted by a
    prefill-heavy burst, then steady again.

    The burst segment is the regime prefill/decode disaggregation exists
    for (``serve/disagg.py``): long analytics prompts with short answers,
    so admission compute (the per-step ``extra_*`` channels) dominates and
    a colocated engine serializes a prompt's worth of prefill into every
    decode step.  The steady segments are the opposite shape — short
    conversational prompts, long decodes — where the planned hot windows
    are all that matters.  Replayed by ``bench_runtime --drift`` and the
    ``OnlineReplanner`` differential suite like any other drift workload:
    the re-planner must catch the phase flip in both directions."""
    from repro.core.hmsim import build_serve_trace
    from repro.runtime.online import DriftSegment, DriftWorkload
    geometry = dict(num_slots=num_slots, num_layers=num_layers,
                    kv_token_bytes=kv_token_bytes, weight_bytes=weight_bytes,
                    flops_per_token=flops_per_token)

    def seg(prompt, decode, n):
        reqs = [(prompt + (i * 7) % 13, decode + (i * 5) % 9)
                for i in range(n)]
        return build_serve_trace(reqs, **geometry)

    return DriftWorkload("disagg_phases", (
        DriftSegment("decode_steady", seg(48, 64, 2 * num_slots)),
        DriftSegment("prefill_burst", seg(384, 12, 3 * num_slots)),
        DriftSegment("decode_again", seg(48, 64, 2 * num_slots))))


def synthetic_prefill_burst(num_slots: int = 4, num_layers: int = 8,
                            kv_token_bytes: float = 4096,
                            weight_bytes: float = 50e6,
                            flops_per_token: float = 2e9):
    """Chunked-prefill drift: decode-steady traffic hit by a flash crowd of
    long *shared-prefix* prompts, then steady again.

    The burst is the regime the cache-aware prefill scheduler exists for:
    every crowd request carries the same prefix_id over a long common
    prefix, so the engine skips the shared rows' compute (the trace's
    ``prefill_skip_tokens`` / the timeline's net ``extra_flops`` +
    ``prefill_read_bytes``) while the chunker keeps decode stepping through
    the admissions.  Distinct from ``disagg_phases``: there the burst is
    unshared and whole-prompt, here the re-planner must track a burst whose
    *priced* prefill cost is far below its token count — mis-modeling the
    skip shows up directly as clairvoyant regret in ``bench_runtime
    --drift``."""
    from repro.core.hmsim import build_serve_trace
    from repro.runtime.online import DriftSegment, DriftWorkload
    geometry = dict(num_slots=num_slots, num_layers=num_layers,
                    kv_token_bytes=kv_token_bytes, weight_bytes=weight_bytes,
                    flops_per_token=flops_per_token,
                    shared_prefix_tokens=256)

    def steady(n):
        reqs = [(48 + (i * 7) % 13, 56 + (i * 5) % 9) for i in range(n)]
        return build_serve_trace(reqs, **geometry)

    def burst(n):
        # one shared 256-token system prefix + a private tail per request
        reqs = [(512 + (i * 11) % 23, 24 + (i * 3) % 7, 0)
                for i in range(n)]
        return build_serve_trace(reqs, **geometry)

    return DriftWorkload("prefill_burst", (
        DriftSegment("decode_steady", steady(2 * num_slots)),
        DriftSegment("shared_burst", burst(4 * num_slots)),
        DriftSegment("decode_again", steady(2 * num_slots))))


def drift_workloads() -> dict:
    """The canonical piecewise-stationary set the differential suite and
    ``bench_runtime --drift`` replay."""
    return {w.name: w for w in (synthetic_drift_tenant_flip(),
                                synthetic_drift_prompt_shift(),
                                synthetic_drift_flash_crowd(),
                                synthetic_disagg_trace(),
                                synthetic_prefill_burst())}
