"""Unified tier/trace model: the one object-granular view of a workload.

Sentinel's core claim is a single idea — repeatable workloads let the runtime
place *data objects* (not pages) across memory tiers using known lifetimes.
This module is the shared vocabulary both workload families speak:

  MemoryTier      a named tier (bandwidth + capacity) derived from an HWSpec.
  DataObject      one placeable allocation: bytes, birth/death, and a
                  step-indexed access schedule.  Training long-lived
                  activations/weights and serving KV blocks are both
                  DataObjects (serving reuses ``hmsim.KVObject`` directly —
                  anything with uid/bytes/birth/death/accesses qualifies).
  AccessTimeline  the fully resolved replayable timeline: per-step compute
                  and traffic, object birth/free/read events, and the
                  reserve-pool accounting of paper §4.3.
  Workload        the protocol both stacks adapt into: ``TrainingWorkload``
                  wraps a profiler ``TraceProfile`` (timeline steps = layer
                  steps of one training iteration), ``ServingWorkload`` wraps
                  an ``hmsim.ServeTrace`` (timeline steps = decode tokens).
                  Phase/step semantics of each source are preserved — the
                  adapters translate, they do not approximate.

Every placement policy in ``runtime/policies.py`` and the unified planner in
``runtime/plan.py`` consume only this model, which is what makes every policy
benchmarkable on every workload.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

from repro.core.hardware import HWSpec


@dataclass(frozen=True)
class MemoryTier:
    """One memory tier. ``capacity`` None means unbounded (the slow tier).

    ``bandwidth`` is the *read* bandwidth compute sees against this tier
    (the roofline denominator) — NOT the rate of moving data in or out of
    it.  The two-tier model historically conflated the two through
    ``hw.mig_bw``; transfer rates are a property of the link, carried by
    ``tiergraph.TierEdge`` and sourced from the ``CostModel`` migration
    fields (``mig_read_bw``/``mig_write_bw``/``link_bw``).
    """
    name: str
    bandwidth: float                 # read bandwidth, B/s (see docstring)
    capacity: Optional[float] = None


def tiers_from_hw(hw: HWSpec, fast_bytes: float) -> List[MemoryTier]:
    """The two-tier model every policy assumes: fast (HBM / near DRAM,
    capacity-limited) over slow (host / far DRAM, unbounded).

    Since the tier-graph generalization this is the trivial 2-node
    ``TierGraph`` instance — the node list is byte-identical to what this
    helper always returned."""
    from repro.runtime.tiergraph import TierGraph   # avoid import cycle
    return TierGraph.two_tier(hw, fast_bytes).tiers


@dataclass
class DataObject:
    """A placeable data object on the unified timeline.

    Serving KV blocks (``hmsim.KVObject``) are consumed duck-typed — the
    policies only touch ``uid``/``bytes``/``birth``/``death``/``accesses``
    (and optionally ``shared_key``) — so this class is instantiated for
    training-derived timelines and any synthetic workloads.

    ``shared_key``: objects carrying the same non-None key are aliases of
    ONE physical allocation (a shared prompt prefix mapped to the same
    refcounted pages).  Sharing-aware policies and the capacity accounting
    charge the group's bytes once; reads still charge per access (each
    reader streams the bytes through its own attention)."""
    uid: int
    bytes: int
    birth: int
    death: int
    accesses: List[int] = field(default_factory=list)   # sorted step indices
    kind: str = "object"            # "weight" | "activation" | "kv" | ...
    meta: dict = field(default_factory=dict)
    shared_key: Optional[tuple] = None
    tenant: Optional[str] = None    # owning tenant id (multi-tenant runs)

    @property
    def lifetime(self) -> int:
        return max(0, self.death - self.birth)


def peak_object_bytes(objects) -> float:
    """Peak concurrently-live bytes over a set of objects, counting every
    shared group (equal non-None ``shared_key``) once: the group's bytes are
    live exactly over the union of its members' [birth, death] intervals —
    physical pages exist while any reference does, like a
    ``kvcache.PageTable`` refcount."""
    deltas: Dict[int, float] = {}

    def add(t, b):
        deltas[t] = deltas.get(t, 0.0) + b

    groups: Dict[tuple, List[Any]] = {}
    for o in objects:
        k = getattr(o, "shared_key", None)
        if k is None:
            add(o.birth, o.bytes)
            add(o.death + 1, -o.bytes)
        else:
            groups.setdefault(k, []).append(o)
    for objs in groups.values():
        b = objs[0].bytes
        ivs = sorted((o.birth, o.death) for o in objs)
        lo, hi = ivs[0]
        for lo2, hi2 in ivs[1:]:
            if lo2 <= hi + 1:                     # refcount never hit zero
                hi = max(hi, hi2)
            else:
                add(lo, b)
                add(hi + 1, -b)
                lo, hi = lo2, hi2
        add(lo, b)
        add(hi + 1, -b)
    peak = cur = 0.0
    for t in sorted(deltas):
        cur += deltas[t]
        peak = max(peak, cur)
    return peak


@dataclass
class AccessTimeline:
    """The resolved replayable timeline of one workload.

    Per-step scalars (length ``num_steps``):
      flops            compute issued at the step
      total_bytes      all memory traffic of the step (roofline numerator)
      fixed_fast_bytes traffic always charged to the fast tier no matter the
                       placement (KV writes + weight streaming in serving;
                       reserve-pool/fused traffic in training) — the policies
                       only ever move ``total - fixed`` between tiers
      tokens           units of work completed (decode tokens; 0 in training)
      extra_flops/extra_fast_bytes
                       off-timeline work folded into the step (slot-refill
                       prefill in serving; zero in training)

    ``admits``/``births``/``frees``/``reads`` are the per-step event lists the
    event-driven policies replay, in the exact order the source trace resolved
    them.  ``reserved_bytes`` is fast memory pre-committed outside the object
    set (training short-lived pool, §4.3); serving reserves through the open
    KV blocks which *are* timeline objects, so it is 0 there.
    """
    kind: str                       # "training" | "serving"
    num_steps: int
    objects: List[Any]
    flops: List[float]
    total_bytes: List[float]
    fixed_fast_bytes: List[float]
    tokens: List[int]
    extra_flops: List[float]
    extra_fast_bytes: List[float]
    admits: Dict[int, List[Any]]
    births: Dict[int, List[Any]]
    frees: Dict[int, List[Any]]
    reads: Dict[int, List[Any]]
    reserved_bytes: float = 0.0
    source: Any = None              # the TraceProfile / ServeTrace adapted
    # shared KV bytes the cache-aware prefill reads back instead of
    # recomputing (serving only; None = no skip information in the source).
    # extra_flops/extra_fast_bytes are then *net of the compute skip*.
    prefill_read_bytes: Optional[List[float]] = None

    def timeline(self) -> "AccessTimeline":
        """A timeline is its own Workload (lets policies re-dispatch)."""
        return self

    def reserve_bytes(self, mi: int = 1) -> float:
        """RS(MI) of paper §4.3 on this timeline's native reserve model."""
        if self.kind == "training" and self.source is not None:
            return self.source.rs_bytes(mi)
        if self.kind == "serving" and self.source is not None:
            return self.source.rs_bytes()
        return self.reserved_bytes

    def peak_bytes(self) -> float:
        """Peak concurrently-live object bytes over the timeline (shared
        groups counted once — see ``peak_object_bytes``)."""
        if self.kind == "serving" and hasattr(self.source, "peak_kv_bytes"):
            return self.source.peak_kv_bytes()   # same object set, one impl
        return peak_object_bytes(self.objects)

    def step_time_all_fast(self, s: int, hw: HWSpec) -> float:
        """Roofline step time with every byte in the fast tier."""
        return max(self.flops[s] / hw.peak_flops,
                   self.total_bytes[s] / hw.fast_bw)

    def extra_time(self, s: int, hw: HWSpec) -> float:
        """Off-timeline add-on (prefill) at step s; always fast-tier."""
        pread = self.prefill_read_bytes[s] if self.prefill_read_bytes else 0.0
        if not self.extra_flops[s] and not self.extra_fast_bytes[s] \
                and not pread:
            return 0.0
        return max(self.extra_flops[s] / hw.peak_flops,
                   (self.extra_fast_bytes[s] + pread) / hw.fast_bw)


@runtime_checkable
class Workload(Protocol):
    """Anything the unified runtime can plan for."""
    kind: str

    def timeline(self) -> AccessTimeline: ...


class TrainingWorkload:
    """Adapter: profiler ``TraceProfile`` -> unified timeline.

    Timeline steps are the profiler's layer steps of one training iteration
    (forward periods, head/loss, backward periods, optimizer boundary).  The
    placeable objects are the long-lived activations and accessed weights —
    exactly the paper's migration candidates; short-lived objects stay in the
    reserved pool (``reserved_bytes``) and their traffic rides in
    ``fixed_fast_bytes``.
    """

    kind = "training"

    def __init__(self, profile):
        self.profile = profile
        self._tl: Optional[AccessTimeline] = None

    def timeline(self) -> AccessTimeline:
        if self._tl is not None:
            return self._tl
        prof = self.profile
        steps = prof.num_steps
        objects: List[DataObject] = []
        for o in prof.objects:
            if not o.accesses or getattr(o, "fused", False):
                continue
            if o.kind == "activation" and o.lifetime < 2:
                continue                      # reserve pool, never placed
            objects.append(DataObject(o.uid, o.size, max(0, o.birth),
                                      max(0, o.death),
                                      sorted(set(o.accesses)), o.kind))
        admits: Dict[int, List[Any]] = {}
        births: Dict[int, List[Any]] = {}
        frees: Dict[int, List[Any]] = {}
        reads: Dict[int, List[Any]] = {}
        obj_read_bytes = [0.0] * steps
        for o in objects:
            (admits if o.kind == "weight" else births).setdefault(
                o.birth if o.kind != "weight" else 0, []).append(o)
            frees.setdefault(o.death + 1, []).append(o)
            for s in o.accesses:
                if 0 <= s < steps:
                    reads.setdefault(s, []).append(o)
                    obj_read_bytes[s] += o.bytes
        flops = [prof.step_flops(s) for s in range(steps)]
        total = [prof.step_bytes(s) for s in range(steps)]
        fixed = [max(0.0, total[s] - obj_read_bytes[s]) for s in range(steps)]
        self._tl = AccessTimeline(
            kind=self.kind, num_steps=steps, objects=objects, flops=flops,
            total_bytes=total, fixed_fast_bytes=fixed, tokens=[0] * steps,
            extra_flops=[0.0] * steps, extra_fast_bytes=[0.0] * steps,
            admits=admits, births=births, frees=frees, reads=reads,
            reserved_bytes=prof.rs_bytes(1), source=prof)
        return self._tl


class ServingWorkload:
    """Adapter: ``hmsim.ServeTrace`` -> unified timeline.

    Timeline steps are decode-token steps; the objects are the trace's KV
    blocks (used directly — identity-preserving, so event order and therefore
    simulated numbers are bit-identical to the pre-unification serve
    simulator).  Prefill work at slot refills rides in the ``extra_*``
    channels, KV writes + weight streaming in ``fixed_fast_bytes``.
    """

    kind = "serving"

    def __init__(self, trace):
        self.trace = trace
        self._tl: Optional[AccessTimeline] = None

    def timeline(self) -> AccessTimeline:
        if self._tl is not None:
            return self._tl
        tr = self.trace
        steps = tr.num_steps
        flops, fixed, total = [], [], []
        tokens, eflops, ebytes, pread = [], [], [], []
        skip_tok = getattr(tr, "prefill_skip_tokens", None) or {}
        for t in range(steps):
            act = tr.active.get(t, 0)
            flops.append(act * tr.flops_per_token)
            fx = tr.write_bytes(t) + tr.weight_bytes
            fixed.append(fx)
            total.append(fx + sum(o.bytes for o in tr.reads.get(t, ())))
            tokens.append(act)
            # cache-aware prefill: shared-prefix rows a donor already
            # materialized are skipped (net flops/writes), their KV read
            # back through the fast tier instead
            p_tok = tr.prefill_tokens.get(t, 0)
            skip = min(skip_tok.get(t, 0), p_tok)
            eflops.append((p_tok - skip) * tr.flops_per_token)
            ebytes.append((p_tok - skip) * tr.num_layers * tr.kv_token_bytes)
            pread.append(skip * tr.num_layers * tr.kv_token_bytes)
        self._tl = AccessTimeline(
            kind=self.kind, num_steps=steps, objects=tr.objects, flops=flops,
            total_bytes=total, fixed_fast_bytes=fixed, tokens=tokens,
            extra_flops=eflops, extra_fast_bytes=ebytes, admits=tr.admits,
            births=tr.births, frees=tr.frees, reads=tr.reads,
            reserved_bytes=0.0, source=tr,
            prefill_read_bytes=pread if any(pread) else None)
        return self._tl


@dataclass(frozen=True)
class Tenant:
    """One tenant of a multi-tenant serving deployment.

    ``id``               stable string identity (JSON-safe: it keys the plan's
                         per-tenant accounting dicts).
    ``fast_quota_frac``  the tenant's *guaranteed* share of the fast-memory
                         placement budget, as a fraction.  None means
                         "unspecified": ``normalized_quotas`` grants such
                         tenants an equal split of whatever fraction the
                         explicit quotas leave unreserved.
    ``slo_slack``        allowed decode slowdown versus all-fast (the
                         decode-latency SLO, expressed as a ratio >= 1).  It
                         orders *graceful degradation*: when guaranteed
                         capacity must be reclaimed from borrowers, tenants
                         with the loosest SLO give pages back first.
    ``arrival``          decode step the tenant's request stream starts at —
                         its arrival trace offset on the merged timeline.
    """
    id: str
    fast_quota_frac: Optional[float] = None
    slo_slack: float = 1.0
    arrival: int = 0


def normalized_quotas(tenants: Sequence[Tenant]) -> Dict[str, float]:
    """Per-tenant guaranteed fast-memory fractions, summing to <= 1.

    Explicit ``fast_quota_frac`` values are kept (rescaled only if they
    oversubscribe); tenants with an unspecified quota (None) split the
    leftover fraction evenly — every tenant ends up with a guarantee.
    """
    fixed = {t.id: float(t.fast_quota_frac) for t in tenants
             if t.fast_quota_frac is not None}
    total_fixed = sum(fixed.values())
    if total_fixed > 1.0:
        fixed = {k: v / total_fixed for k, v in fixed.items()}
        total_fixed = 1.0
    rest = [t.id for t in tenants if t.id not in fixed]
    out = dict(fixed)
    if rest:
        share = max(0.0, 1.0 - total_fixed) / len(rest)
        for tid in rest:
            out[tid] = share
    return out


def merge_tenant_traces(tenants: Sequence[Tenant], traces: Sequence[Any],
                        shared_prefix_ids: Sequence[Any] = ()):
    """Interleave N tenants' ``hmsim.ServeTrace``s into ONE trace.

    Each tenant's trace is shifted by its ``arrival`` offset and mapped onto
    a disjoint slot range (the tenant's private continuous-batching slots —
    one model instance serves everyone, so weight streaming is charged
    once); every KV object is re-uid'ed and tagged with its tenant id.
    ``shared_key``s are *namespaced per tenant* by default — two tenants'
    traces built independently with the conventional ``prefix_id`` 0 hold
    physically distinct prompts, and coalescing them would undercount
    capacity and migration.  Prefix ids listed in ``shared_prefix_ids`` are
    declared platform-wide (one system prompt serving every tenant) and
    keep their keys verbatim, so they stay ONE physical allocation across
    tenants.  Returns ``(merged_trace, slot_tenants)`` where
    ``slot_tenants[s]`` names the tenant owning merged slot ``s``.
    """
    import copy

    from repro.core.hmsim import ServeTrace
    if len(tenants) != len(traces) or not tenants:
        raise ValueError("merge_tenant_traces needs one trace per tenant")
    t0 = traces[0]
    for tr in traces[1:]:
        same = all(getattr(tr, f) == getattr(t0, f) for f in
                   ("num_layers", "block_tokens", "recent_window",
                    "history_period", "kv_token_bytes", "weight_bytes",
                    "flops_per_token"))
        if not same:
            raise ValueError("tenant traces must share one model geometry "
                             "(layers/block/window/period/kv/weight/flops)")
    merged = ServeTrace(
        num_slots=sum(tr.num_slots for tr in traces),
        num_layers=t0.num_layers, block_tokens=t0.block_tokens,
        recent_window=t0.recent_window, history_period=t0.history_period,
        kv_token_bytes=t0.kv_token_bytes, weight_bytes=t0.weight_bytes,
        flops_per_token=t0.flops_per_token)
    shared_ids = set(shared_prefix_ids)
    slot_tenants: List[str] = []
    uid = slot_off = 0
    for tn, tr in zip(tenants, traces):
        dt = max(0, int(tn.arrival))
        slot_tenants += [tn.id] * tr.num_slots
        remap: Dict[int, Any] = {}
        for o in tr.objects:
            c = copy.copy(o)
            c.uid, uid = uid, uid + 1
            c.slot = o.slot + slot_off
            c.birth, c.death = o.birth + dt, o.death + dt
            c.accesses = [a + dt for a in o.accesses]
            c.tenant = tn.id
            if c.shared_key is not None and \
                    c.shared_key[0] not in shared_ids:
                c.shared_key = (tn.id,) + tuple(c.shared_key)
            remap[o.uid] = c
            merged.objects.append(c)
        for src, dst in ((tr.admits, merged.admits),
                         (tr.births, merged.births),
                         (tr.frees, merged.frees), (tr.reads, merged.reads)):
            for t, objs in src.items():
                dst.setdefault(t + dt, []).extend(remap[o.uid] for o in objs)
        for t, n in tr.active.items():
            merged.active[t + dt] = merged.active.get(t + dt, 0) + n
        for t, n in tr.prefill_tokens.items():
            merged.prefill_tokens[t + dt] = \
                merged.prefill_tokens.get(t + dt, 0) + n
        for t, n in tr.prefill_skip_tokens.items():
            merged.prefill_skip_tokens[t + dt] = \
                merged.prefill_skip_tokens.get(t + dt, 0) + n
        merged.num_steps = max(merged.num_steps, tr.num_steps + dt)
        slot_off += tr.num_slots
    return merged, slot_tenants


class MultiTenantWorkload:
    """Adapter: N tenants x N ``ServeTrace``s -> one unified timeline.

    The third scenario on the unified surface: capacity pressure comes from
    *competing* request streams instead of one model's phases.  The merged
    trace is a plain ``ServeTrace`` whose objects carry tenant tags, so every
    registered policy runs on it unchanged; the SLO-aware planner half reads
    ``tenants`` / ``tenant_quotas`` / ``slot_tenants`` off this adapter to
    enforce per-tenant shares.
    """

    kind = "serving"

    def __init__(self, tenants: Sequence[Tenant], traces: Sequence[Any],
                 shared_prefix_ids: Sequence[Any] = ()):
        self.tenants = list(tenants)
        if len({t.id for t in self.tenants}) != len(self.tenants):
            raise ValueError("tenant ids must be unique")
        self.trace, self.slot_tenants = merge_tenant_traces(
            tenants, traces, shared_prefix_ids)
        self.tenant_quotas = normalized_quotas(self.tenants)
        self.tenant_slack = {t.id: float(t.slo_slack) for t in self.tenants}
        self._tl: Optional[AccessTimeline] = None

    def timeline(self) -> AccessTimeline:
        if self._tl is None:
            self._tl = ServingWorkload(self.trace).timeline()
        return self._tl


def as_workload(w: Any):
    """Coerce a TraceProfile / ServeTrace / Workload into a Workload.

    Dispatch is structural (no imports of the source modules): a training
    profile exposes ``num_periods``, a serving trace ``num_slots``.
    """
    if isinstance(w, (TrainingWorkload, ServingWorkload)):
        return w
    if hasattr(w, "timeline") and hasattr(w, "kind"):
        return w
    if hasattr(w, "num_periods") and hasattr(w, "objects"):
        return TrainingWorkload(w)
    if hasattr(w, "num_slots") and hasattr(w, "kv_token_bytes"):
        return ServingWorkload(w)
    raise TypeError(f"cannot adapt {type(w).__name__} into a runtime "
                    "Workload (expected TraceProfile, ServeTrace, or an "
                    "object implementing the Workload protocol)")
