"""Tier graphs: the memory system as a topology, not a pair.

Everything before this module assumed exactly two tiers — fast HBM over an
unbounded slow host — because that is the machine the source paper measures.
Production serving runs on a *mesh*: device-A HBM ↔ device-B HBM ↔ host,
with distinct bandwidth on every link (ICI between devices, PCIe to the
host, DDR inside it).  Following RIMMS and Unimem (PAPERS.md), this module
models that memory system as a directed graph of ``MemoryTier`` nodes with
per-edge bandwidths, while keeping every registered policy unchanged:

  TierGraph     frozen graph of ``MemoryTier`` nodes + ``TierEdge`` links.
                ``two_tier(hw, fast_bytes)`` is the trivial 2-node instance
                — ``objects.tiers_from_hw`` now routes through it, so the
                whole existing planner/policy surface is the special case.
  path_bw       max-bottleneck (widest-path) bandwidth between two tiers:
                what a transfer can actually sustain end to end.
  GraphHW       a duck-typed ``HWSpec`` view of the graph as seen from one
                compute node.  Policies only consume ``peak_flops`` /
                ``fast_bw`` / ``slow_bw`` / ``mig_bw`` / ``mig_overhead``,
                so any graph folds to the two tiers the compute node sees:
                its own HBM, and the spill tier with the widest path in.
                On a ``two_tier`` graph the fold reproduces the underlying
                machine's numbers exactly — bit-identical simulation.

Node bandwidth vs edge bandwidth: ``MemoryTier.bandwidth`` is the *read*
bandwidth compute sees against that tier (the roofline denominator).  The
bandwidth of *moving* data between tiers is a property of the link, not the
node — that is what ``TierEdge.bandwidth`` carries, sourced from the
``CostModel`` migration fields (``mig_read_bw``/``mig_write_bw``/
``link_bw``).  The old two-tier model conflated the two through
``hw.mig_bw``; the graph keeps them distinct.

Serialization: ``PlacementPlan`` carries ``graph.to_dict()`` when the graph
is non-trivial; canonical two-tier plans keep the field ``None`` so
``objective="bytes"`` plan JSONs stay byte-identical to the goldens.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.objects import MemoryTier

__all__ = ["TierEdge", "TierGraph", "GraphHW"]


@dataclass(frozen=True)
class TierEdge:
    """One directed transfer link ``src -> dst`` at ``bandwidth`` B/s.

    Edge bandwidth is the DMA/interconnect rate of the link itself —
    distinct from the endpoints' read bandwidths (see module doc)."""
    src: str
    dst: str
    bandwidth: float


@dataclass(frozen=True)
class TierGraph:
    """A directed graph of memory tiers with per-edge bandwidths.

    ``nodes[0]`` is the compute tier by convention — the tier whose
    bandwidth is the roofline denominator (override per-view via
    ``hw_view(compute=...)``).  Capacity ``None`` marks an unbounded node
    (the host).  The graph is frozen and hashable so plans and caches can
    key on it.
    """
    nodes: Tuple[MemoryTier, ...]
    edges: Tuple[TierEdge, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "edges", tuple(self.edges))
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        if not names:
            raise ValueError("a TierGraph needs at least one node")
        known = set(names)
        for e in self.edges:
            if e.src not in known or e.dst not in known:
                raise ValueError(f"edge {e.src}->{e.dst} references an "
                                 f"unknown tier (nodes: {sorted(known)})")
            if e.src == e.dst:
                raise ValueError(f"self-edge on {e.src}")
            if e.bandwidth <= 0:
                raise ValueError(f"edge {e.src}->{e.dst}: non-positive "
                                 f"bandwidth {e.bandwidth}")

    # ------------------------------------------------------------ queries --
    @property
    def names(self) -> List[str]:
        return [n.name for n in self.nodes]

    @property
    def tiers(self) -> List[MemoryTier]:
        """The node list in ``PlacementPlan.tiers`` order (compute first)."""
        return list(self.nodes)

    def node(self, name: str) -> MemoryTier:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(f"unknown tier {name!r}; nodes: {self.names}")

    def capacity(self, name: str) -> Optional[float]:
        """Capacity of one tier (None = unbounded)."""
        return self.node(name).capacity

    def edge_bw(self, src: str, dst: str) -> float:
        """Direct-link bandwidth ``src -> dst``; 0.0 when no edge exists."""
        for e in self.edges:
            if e.src == src and e.dst == dst:
                return e.bandwidth
        return 0.0

    def path_bw(self, src: str, dst: str) -> float:
        """Max-bottleneck bandwidth from ``src`` to ``dst``: the widest
        path's narrowest link — what one transfer can sustain end to end.
        ``inf`` for src == dst, 0.0 when unreachable."""
        self.node(src), self.node(dst)
        if src == dst:
            return math.inf
        # widest-path Dijkstra: expand the frontier by best bottleneck
        best = {src: math.inf}
        heap = [(-math.inf, src)]
        while heap:
            neg_w, u = heapq.heappop(heap)
            w = -neg_w
            if u == dst:
                return w
            if w < best.get(u, 0.0):
                continue
            for e in self.edges:
                if e.src != u:
                    continue
                cand = min(w, e.bandwidth)
                if cand > best.get(e.dst, 0.0):
                    best[e.dst] = cand
                    heapq.heappush(heap, (-cand, e.dst))
        return best.get(dst, 0.0)

    @property
    def is_two_tier(self) -> bool:
        """The trivial instance: exactly the fast/slow pair."""
        return self.names == ["fast", "slow"]

    def matches_two_tier(self, hw, fast_bytes: float) -> bool:
        """True when this graph *is* the canonical two-tier fold of ``hw``
        — the case where a plan's serialized graph carries no information
        beyond the plan's existing ``tiers``/``cost_model`` fields."""
        try:
            return self == TierGraph.two_tier(hw, fast_bytes)
        except Exception:
            return False

    # ------------------------------------------------------- constructors --
    @classmethod
    def two_tier(cls, hw, fast_bytes: float) -> "TierGraph":
        """The legacy fast/slow pair as a 2-node graph.  Node bandwidths
        and capacities are byte-identical to what ``tiers_from_hw`` always
        produced; edge bandwidths come from the machine's migration DMA
        fields (``CostModel.mig_read_bw``/``mig_write_bw``; a plain
        ``HWSpec`` collapses both to ``mig_bw``)."""
        promote = float(getattr(hw, "mig_read_bw", hw.mig_bw))
        demote = float(getattr(hw, "mig_write_bw", hw.mig_bw))
        return cls(
            nodes=(MemoryTier("fast", hw.fast_bw, float(fast_bytes)),
                   MemoryTier("slow", hw.slow_bw, None)),
            edges=(TierEdge("slow", "fast", promote),
                   TierEdge("fast", "slow", demote)))

    @classmethod
    def mesh(cls, num_devices: int, hw, fast_bytes_per_device: float,
             link_bw: Optional[float] = None) -> "TierGraph":
        """A device mesh: ``dev0..devN-1`` HBM nodes over one shared host.

        Device HBMs are fully connected at ``link_bw`` (default: the
        machine's ``link_bw`` — ICI on a TPU pod slice); every device
        reaches the host at the migration DMA bandwidths.  ``dev0`` is the
        compute/decode tier by the nodes[0] convention."""
        if num_devices < 1:
            raise ValueError("mesh needs >= 1 device")
        link = float(link_bw if link_bw is not None
                     else getattr(hw, "link_bw", 0.0))
        promote = float(getattr(hw, "mig_read_bw", hw.mig_bw))
        demote = float(getattr(hw, "mig_write_bw", hw.mig_bw))
        nodes = [MemoryTier(f"dev{d}", hw.fast_bw,
                            float(fast_bytes_per_device))
                 for d in range(num_devices)]
        nodes.append(MemoryTier("host", hw.slow_bw, None))
        edges: List[TierEdge] = []
        for d in range(num_devices):
            edges.append(TierEdge("host", f"dev{d}", promote))
            edges.append(TierEdge(f"dev{d}", "host", demote))
            if link > 0:
                for o in range(num_devices):
                    if o != d:
                        edges.append(TierEdge(f"dev{d}", f"dev{o}", link))
        return cls(nodes=tuple(nodes), edges=tuple(edges))

    # -------------------------------------------------------------- views --
    def hw_view(self, machine, compute: Optional[str] = None,
                spill: Optional[str] = None) -> "GraphHW":
        """Fold the graph to the duck-typed ``HWSpec`` one compute node
        sees; every registered policy runs on it unchanged."""
        return GraphHW(self, machine, compute=compute, spill=spill)

    # --------------------------------------------------------------- json --
    def to_dict(self) -> dict:
        return {
            "nodes": [{"name": n.name, "bandwidth": n.bandwidth,
                       "capacity": n.capacity} for n in self.nodes],
            "edges": [{"src": e.src, "dst": e.dst,
                       "bandwidth": e.bandwidth} for e in self.edges],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TierGraph":
        return cls(nodes=tuple(MemoryTier(**n) for n in d["nodes"]),
                   edges=tuple(TierEdge(**e) for e in d.get("edges", ())))


class GraphHW:
    """A tier graph folded to the two-tier machine one compute node sees.

    Policies and simulators consume only ``hw.peak_flops`` / ``fast_bw`` /
    ``slow_bw`` / ``mig_bw`` / ``mig_overhead`` / ``fast_bytes`` (plus the
    ``CostModel`` extras via delegation), so the fold is:

      fast_bw   the compute node's own read bandwidth
      slow_bw   the spill node's read bandwidth
      mig_bw    ``path_bw(spill -> compute)`` — the widest path a promotion
                can stream through, which on a mesh may route *via a
                neighbor device* when ICI beats the host DMA
      fast_bytes  the compute node's capacity (machine's when unbounded)

    ``spill`` defaults to the non-compute node with the widest path into
    compute, preferring unbounded (host) nodes on ties — on ``two_tier``
    graphs this reproduces the wrapped machine's numbers exactly, so the
    graph path is bit-identical to the legacy two-tier path.  Everything
    else (``peak_flops``, ``mig_overhead``, ``step_time``, pricing) is
    delegated to the wrapped machine.
    """

    def __init__(self, graph: TierGraph, machine,
                 compute: Optional[str] = None,
                 spill: Optional[str] = None):
        self.graph = graph
        self.machine = machine
        self.compute = compute or graph.nodes[0].name
        graph.node(self.compute)
        if spill is None:
            others = [n for n in graph.nodes if n.name != self.compute]
            if not others:
                raise ValueError("hw_view needs a non-compute tier to "
                                 "spill to")
            # widest path in wins; unbounded (host-like) nodes break ties
            spill = max(others, key=lambda n: (
                graph.path_bw(n.name, self.compute),
                n.capacity is None)).name
        else:
            graph.node(spill)
        self.spill = spill

    # ------------------------------------------------- the two-tier fold --
    @property
    def fast_bw(self) -> float:
        return self.graph.node(self.compute).bandwidth

    @property
    def slow_bw(self) -> float:
        return self.graph.node(self.spill).bandwidth

    @property
    def mig_bw(self) -> float:
        return self.graph.path_bw(self.spill, self.compute)

    @property
    def fast_bytes(self) -> float:
        cap = self.graph.capacity(self.compute)
        return float(cap) if cap is not None else self.machine.fast_bytes

    def __getattr__(self, name):
        # peak_flops, mig_overhead, slow/mig DMA fields, step_time, price...
        return getattr(self.machine, name)

    def __repr__(self):
        return (f"GraphHW({self.compute!r} over {self.spill!r}, "
                f"nodes={self.graph.names})")
