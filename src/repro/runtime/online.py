"""Online re-planning under traffic drift: the continuous profile→re-plan loop.

``runtime.plan()`` is offline — profile once, place forever — which leans on
the paper's repeatability assumption.  Production serving traffic drifts
(diurnal tenant mix, prompt-length shifts, flash crowds), so this module
closes the loop the way "Online Application Guidance for Heterogeneous
Memory Systems" (PAPERS.md) does: keep profiling the live stream, detect
distribution shift, and re-plan *incrementally*.

    OnlineReplanner   sliding-window drift detector + incremental planner.
                      Consumes per-step stats shaped like the live engine's
                      counters (``ContinuousBatcher.step_migration_bytes``,
                      decode tokens, per-tenant read activity — the same
                      series ``predict_pool_counters`` replays), prices each
                      window with the ``CostModel``, and triggers when the
                      windowed traffic moves more than ``threshold`` against
                      the reference window captured at the last plan.  A
                      trigger re-plans on the freshly observed workload and
                      emits a ``PlanDelta`` (plan.py) — only the fields that
                      changed — whose application is byte-identical to the
                      fresh ``runtime.plan()``.  Hysteresis bounds churn:
                      ``min_dwell`` steps must pass between re-plans, and
                      the cumulative re-layout bytes (shrinking hot windows
                      demote pages) must stay under ``churn_budget_bytes``
                      or the delta is suppressed.  Idle tenants' batch slots
                      are lent to the busiest active tenant (and reclaimed
                      when they wake), the slot-level analogue of
                      ``sentinel_slo`` lending idle quota.
    DriftWorkload     a piecewise-stationary workload: a sequence of
                      stationary segments sharing one slot/KV geometry
                      (runtime/synthetic.py builds the canonical three).
    replay_drift      the simulator-level online loop: walk a DriftWorkload
                      step by step, price the current plan's traffic, feed
                      the replanner, apply its deltas, and report online vs
                      per-segment clairvoyant vs static-stale predicted
                      time — the clairvoyant-regret differential the test
                      suite and ``bench_runtime --drift`` gate.

Regret is defined in the time domain: ``online_s / clairvoyant_s - 1``,
where clairvoyant re-plans each segment with full knowledge at its first
step and pays no detection lag or churn.  Deltas apply to a live engine
through ``ContinuousBatcher.apply_plan`` — demotions toward the new plan's
boundaries go through the ``PageTable`` version machinery, and
``predict_pool_counters(..., plan_schedule=...)`` replays them
integer-exactly.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import json

from repro.runtime.costmodel import CostModel, as_cost_model
from repro.runtime.objects import as_workload
from repro.runtime.plan import (PlacementPlan, PlanDelta, _tenant_knobs,
                                plan as _plan, plan_delta)
from repro.runtime.policies import get_policy, simulate

DEFAULT_LOOKAHEADS = (2, 4, 8, 16, 32)


# ============================================================ drift workloads ==

def _trace_of(workload):
    tr = getattr(workload, "trace", None)
    if tr is None:
        tr = as_workload(workload).timeline().source
    if tr is None or not hasattr(tr, "num_slots"):
        raise TypeError("drift segments need serving workloads (a ServeTrace "
                        "or MultiTenantWorkload)")
    return tr


@dataclass(frozen=True)
class DriftSegment:
    """One stationary phase of a piecewise-stationary workload."""
    name: str
    workload: Any

    @property
    def trace(self):
        return _trace_of(self.workload)

    @property
    def num_steps(self) -> int:
        return self.trace.num_steps


@dataclass(frozen=True)
class DriftWorkload:
    """A sequence of stationary segments over one serving geometry.  The
    online planner sees the segments only through their step-by-step traffic;
    the clairvoyant oracle plans each segment with full knowledge."""
    name: str
    segments: Tuple[DriftSegment, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("a DriftWorkload needs at least one segment")
        t0 = self.segments[0].trace
        for seg in self.segments[1:]:
            tr = seg.trace
            if (tr.num_slots, tr.num_layers, tr.kv_token_bytes,
                    tr.block_tokens) != (t0.num_slots, t0.num_layers,
                                         t0.kv_token_bytes, t0.block_tokens):
                raise ValueError(
                    f"segment {seg.name!r} changes the slot/KV geometry — "
                    "plans would not be compatible across segments")

    @property
    def num_steps(self) -> int:
        return sum(s.num_steps for s in self.segments)

    def peak_kv_bytes(self) -> float:
        return max(s.trace.peak_kv_bytes() for s in self.segments)

    def row_bytes(self) -> float:
        """KV bytes per token across all layers — the unit hot-window
        changes are converted to churn bytes with."""
        t = self.segments[0].trace
        return t.num_layers * t.kv_token_bytes


# ============================================================== window stats ==

@dataclass
class StepStat:
    """One decode step's observed counters — the engine-shaped unit the
    replanner consumes (``step_migration_bytes[t]``, tokens decoded, priced
    step seconds, per-tenant read bytes)."""
    time_s: float = 0.0
    tokens: float = 0.0
    mig_bytes: float = 0.0
    tenant_reads: Dict[str, float] = field(default_factory=dict)


@dataclass
class WindowStats:
    """A sliding window of StepStats folded to means."""
    start: int
    end: int
    step_time: float
    tokens: float
    migration: float
    tenant_share: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def fold(cls, start: int, stats: Sequence[StepStat]) -> "WindowStats":
        n = max(1, len(stats))
        per: Dict[str, float] = {}
        for st in stats:
            for tn, b in st.tenant_reads.items():
                per[tn] = per.get(tn, 0.0) + b
        total = sum(per.values())
        share = {tn: b / total for tn, b in sorted(per.items())} \
            if total > 0 else {}
        return cls(start=start, end=start + len(stats),
                   step_time=sum(s.time_s for s in stats) / n,
                   tokens=sum(s.tokens for s in stats) / n,
                   migration=sum(s.mig_bytes for s in stats) / n,
                   tenant_share=share)


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-30)


def drift_score(ws: WindowStats, ref: WindowStats) -> Tuple[float, str]:
    """How far the window moved from the reference, and which signal moved
    most: relative priced step time / token rate / migration rate, plus the
    absolute per-tenant read-share shift (a mix flip can hide inside a flat
    aggregate)."""
    cands = [(_rel(ws.step_time, ref.step_time), "step_time"),
             (_rel(ws.tokens, ref.tokens), "tokens")]
    if ws.migration > 0 or ref.migration > 0:
        cands.append((_rel(ws.migration, ref.migration), "migration"))
    tenants = set(ws.tenant_share) | set(ref.tenant_share)
    if tenants:
        mix = max(abs(ws.tenant_share.get(tn, 0.0)
                      - ref.tenant_share.get(tn, 0.0)) for tn in tenants)
        cands.append((mix, "tenant_mix"))
    score, label = max(cands)
    return min(score, 99.0), label


# ==================================================================== events ==

@dataclass
class ReplanEvent:
    """One replanner decision: a drift re-plan, a slot lend/reclaim, or a
    churn-budget suppression (``applied=False``)."""
    step: int
    segment: int
    reason: str
    churn_bytes: float
    applied: bool
    delta: PlanDelta
    plan: Optional[PlacementPlan] = None      # applied plan; not serialized

    def to_dict(self) -> dict:
        return {"step": self.step, "segment": self.segment,
                "reason": self.reason, "churn_bytes": self.churn_bytes,
                "applied": self.applied, "delta": self.delta.to_dict()}


def plan_churn_bytes(old: PlacementPlan, new: PlacementPlan,
                     row_bytes: float) -> float:
    """Bytes a steady-state engine demotes to adopt ``new``: every token a
    slot's hot window shrinks by is a page-table demotion at the boundary
    (grown windows cost nothing — cold pages are never promoted back)."""
    slots = max(len(old.slot_hot_windows or ()),
                len(new.slot_hot_windows or ()), 1)
    return float(sum(
        max(0, old.slot_window(s) - new.slot_window(s)) * row_bytes
        for s in range(slots)))


# ================================================================= replanner ==

class OnlineReplanner:
    """The continuous profile→re-plan loop's decision core.

    Drive it with ``record(step, StepStat)`` per decode step; it keeps a
    ``window``-step sliding window and a reference window captured at the
    last (re-)plan.  ``drift_reason`` answers whether the windowed traffic
    moved beyond ``threshold`` (and the hysteresis dwell passed);
    ``replan`` diffs a fresh plan on the re-profiled workload into a
    ``PlanDelta`` and applies it unless the cumulative churn budget would be
    exceeded; ``maybe_lend`` emits slot-reassignment deltas for idle
    tenants.  All decisions are recorded in ``events``."""

    def __init__(self, cost_model, fast_bytes: float, *, window: int = 8,
                 threshold: float = 0.2, min_dwell: int = 16,
                 churn_budget_bytes: Optional[float] = None,
                 row_bytes: float = 0.0, policy: Optional[str] = None,
                 lookaheads: Sequence[int] = DEFAULT_LOOKAHEADS,
                 lend_idle: bool = True):
        self.cm = as_cost_model(cost_model)
        self.fast_bytes = float(fast_bytes)
        self.window = max(1, int(window))
        self.threshold = float(threshold)
        self.min_dwell = max(0, int(min_dwell))
        self.churn_budget_bytes = (4.0 * self.fast_bytes
                                   if churn_budget_bytes is None
                                   else float(churn_budget_bytes))
        self.row_bytes = float(row_bytes)
        self.policy = policy
        self.lookaheads = tuple(lookaheads)
        self.lend_idle = bool(lend_idle)
        self.plan: Optional[PlacementPlan] = None
        self.events: List[ReplanEvent] = []
        self.churn_spent = 0.0
        self._recent: deque = deque(maxlen=self.window)
        self._recent_start = 0
        self._ref: Optional[WindowStats] = None
        self._owner: Optional[List[str]] = None   # true slot ownership
        self._last_replan = 0
        self._last_lend = -(1 << 30)

    # ------------------------------------------------------------ feeding --
    def adopt(self, plan: PlacementPlan, step: int = 0) -> None:
        """Install a plan (the initial offline plan, or an external one).
        Refuses policies that cannot be re-parameterized by a delta."""
        if not get_policy(plan.policy).supports_replan:
            raise ValueError(
                f"policy {plan.policy!r} does not support incremental "
                "re-planning (PlacementPolicy.supports_replan is False; "
                "see docs/POLICIES.md)")
        self.plan = plan
        if plan.slot_tenants:
            self._owner = list(plan.slot_tenants)
        self._last_replan = step
        self._ref = None                   # re-captured on the next full window

    def record(self, step: int, stat: StepStat) -> None:
        if not self._recent:
            self._recent_start = step
        elif len(self._recent) == self.window:
            self._recent_start += 1
        self._recent.append(stat)
        if self._ref is None and len(self._recent) == self.window:
            self._ref = self.window_stats()

    def window_stats(self) -> Optional[WindowStats]:
        if not self._recent:
            return None
        return WindowStats.fold(self._recent_start, list(self._recent))

    # ----------------------------------------------------------- deciding --
    def drift_reason(self, step: int) -> Optional[str]:
        """Non-None when the windowed traffic drifted beyond ``threshold``
        against the reference window and the min-dwell hysteresis passed."""
        if self._ref is None or len(self._recent) < self.window:
            return None
        if step - self._last_replan < self.min_dwell:
            return None
        score, label = drift_score(self.window_stats(), self._ref)
        if score <= self.threshold:
            return None
        return f"{label}:{score:.2f}"

    def replan(self, workload, step: int, reason: str,
               segment: int = -1) -> Optional[ReplanEvent]:
        """Re-plan on the freshly observed workload, emit the delta, apply
        it within the churn budget.  Returns None when the fresh plan equals
        the current one (the traffic moved; the placement didn't)."""
        fresh = _plan(workload, self.cm, self.fast_bytes, policy=self.policy,
                      lookaheads=self.lookaheads, objective="latency")
        self._last_replan = step
        self._ref = self.window_stats()    # rebaseline on today's traffic
        # a lend in effect is the replanner's own state, not drift: when the
        # fresh plan differs only in slot tenancy, the placement did not
        # actually move — rebaseline silently instead of thrashing the lend
        probe = fresh
        if list(fresh.slot_tenants or ()) != list(self.plan.slot_tenants
                                                  or ()):
            probe = replace(fresh, slot_tenants=self.plan.slot_tenants)
        if plan_delta(self.plan, probe) is None:
            return None
        delta = plan_delta(self.plan, fresh, step=step, reason=reason)
        churn = plan_churn_bytes(self.plan, fresh, self.row_bytes)
        applied = self.churn_spent + churn <= self.churn_budget_bytes
        ev = ReplanEvent(step=step, segment=segment, reason=reason,
                         churn_bytes=churn, applied=applied, delta=delta,
                         plan=fresh if applied else None)
        if applied:
            self.plan = self.plan.apply_delta(delta)
            assert self.plan.to_json() == fresh.to_json()   # the contract
            if fresh.slot_tenants:
                self._owner = list(fresh.slot_tenants)
            self.churn_spent += churn
        self.events.append(ev)
        return ev

    def maybe_lend(self, step: int, segment: int = -1) -> \
            Optional[ReplanEvent]:
        """Elastic slot reassignment: an owner tenant with zero read
        activity across the whole window lends its slots to the busiest
        active tenant; a woken owner reclaims them.  Pure ``slot_tenants``
        deltas — no pages move, so churn is zero and the budget/dwell
        hysteresis does not apply (only a one-window spacing)."""
        if not self.lend_idle or self._owner is None or \
                len(self._recent) < self.window:
            return None
        if step - self._last_lend < self.window:
            return None
        ws = self.window_stats()
        activity = {tn: ws.tenant_share.get(tn, 0.0)
                    for tn in sorted(set(self._owner))}
        busy = [tn for tn, a in activity.items() if a > 0.0]
        if not busy:
            return None
        top = max(busy, key=lambda tn: (activity[tn], tn))
        desired = [tn if activity[tn] > 0.0 else top for tn in self._owner]
        if desired == list(self.plan.slot_tenants or ()):
            return None
        idle = sorted(tn for tn, a in activity.items() if a <= 0.0)
        reason = (f"lend:{','.join(idle)}->{top}" if idle
                  else "reclaim:owners")
        delta = PlanDelta(step=step, reason=reason,
                          base_digest=self.plan.digest(),
                          changes={"slot_tenants": desired})
        self.plan = self.plan.apply_delta(delta)
        self._last_lend = step
        ev = ReplanEvent(step=step, segment=segment, reason=reason,
                         churn_bytes=0.0, applied=True, delta=delta,
                         plan=self.plan)
        self.events.append(ev)
        return ev


# ==================================================================== report ==

@dataclass
class SegmentReport:
    name: str
    steps: int
    tokens: int
    online_s: float
    clairvoyant_s: float
    static_s: float
    online_mig_bytes: float
    clairvoyant_mig_bytes: float
    static_mig_bytes: float

    def to_dict(self) -> dict:
        return {k: getattr(self, k) for k in (
            "name", "steps", "tokens", "online_s", "clairvoyant_s",
            "static_s", "online_mig_bytes", "clairvoyant_mig_bytes",
            "static_mig_bytes")}


@dataclass
class OnlineReport:
    """The clairvoyant-regret differential: online vs per-segment oracle vs
    static-stale, plus the full re-plan event sequence.  ``to_json`` is the
    golden-fixture serialization (deterministic bytes)."""
    workload: str
    policy: str
    knobs: Dict[str, float]
    segments: List[SegmentReport]
    events: List[ReplanEvent]
    churn_bytes: float
    churn_budget_bytes: float
    tenant_violations: Dict[str, int]
    plan0: Optional[PlacementPlan] = None     # not serialized

    @property
    def online_s(self) -> float:
        return sum(s.online_s for s in self.segments)

    @property
    def clairvoyant_s(self) -> float:
        return sum(s.clairvoyant_s for s in self.segments)

    @property
    def static_s(self) -> float:
        return sum(s.static_s for s in self.segments)

    @property
    def tokens(self) -> int:
        return sum(s.tokens for s in self.segments)

    @property
    def regret(self) -> float:
        """Predicted-time regret vs the clairvoyant plan sequence (equals
        the tokens/sec regret — every plan serves the same tokens)."""
        return self.online_s / max(self.clairvoyant_s, 1e-30) - 1.0

    @property
    def online_mig_bytes(self) -> float:
        return sum(s.online_mig_bytes for s in self.segments) \
            + self.churn_bytes

    @property
    def clairvoyant_mig_bytes(self) -> float:
        return sum(s.clairvoyant_mig_bytes for s in self.segments)

    @property
    def online_tokens_per_s(self) -> float:
        return self.tokens / max(self.online_s, 1e-30)

    @property
    def static_tokens_per_s(self) -> float:
        return self.tokens / max(self.static_s, 1e-30)

    def to_dict(self) -> dict:
        return {"workload": self.workload, "policy": self.policy,
                "knobs": self.knobs,
                "segments": [s.to_dict() for s in self.segments],
                "events": [e.to_dict() for e in self.events],
                "churn_bytes": self.churn_bytes,
                "churn_budget_bytes": self.churn_budget_bytes,
                "tenant_violations": self.tenant_violations,
                "online_s": self.online_s,
                "clairvoyant_s": self.clairvoyant_s,
                "static_s": self.static_s,
                "regret": self.regret,
                "online_mig_bytes": self.online_mig_bytes,
                "clairvoyant_mig_bytes": self.clairvoyant_mig_bytes}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)


# ==================================================================== replay ==

def _price_plan(wl, cm: CostModel, fast_bytes: float, plan: PlacementPlan):
    """Simulate ``wl`` under ``plan``'s policy/knobs and price the traffic:
    (per-step seconds, per-step migration bytes, PlacementResult)."""
    knobs = dict(get_policy(plan.policy).replan_knobs(plan))
    knobs.update(_tenant_knobs(wl, plan.policy))
    res = simulate(wl, cm, fast_bytes, plan.policy, **knobs)
    rep = cm.price(res.step_traffic)
    mig = [tr.mig_in + tr.mig_out for tr in res.step_traffic]
    return rep.step_times, mig, res


def _tenant_read_series(wl) -> List[Dict[str, float]]:
    """Per-step per-tenant read bytes from the timeline — the replay's stand-
    in for the engine's per-tenant counters (a tenant reads only while it
    decodes, so zero reads across a window means an idle tenant)."""
    tl = as_workload(wl).timeline()
    out: List[Dict[str, float]] = []
    for t in range(tl.num_steps):
        per: Dict[str, float] = {}
        for o in tl.reads.get(t, ()):
            tn = getattr(o, "tenant", None)
            if tn is not None:
                per[str(tn)] = per.get(str(tn), 0.0) + o.bytes
        out.append(per)
    return out


def replay_drift(drift: DriftWorkload, cost_model, fast_bytes: float, *,
                 policy: Optional[str] = None, window: int = 8,
                 threshold: float = 0.2, min_dwell: int = 16,
                 churn_budget_bytes: Optional[float] = None,
                 lookaheads: Sequence[int] = DEFAULT_LOOKAHEADS,
                 lend_idle: bool = True) -> OnlineReport:
    """Walk a piecewise-stationary workload through the online loop.

    Per segment, the replay prices each step under the plan in effect (the
    same per-step traffic the engine's counters report), feeds the replanner,
    and applies its deltas; a mid-segment re-plan re-prices the remaining
    steps under the fresh plan and pays the re-layout churn as a stall
    (``churn_bytes / mig_bw``) on the trigger step.  The report compares
    against the per-segment clairvoyant oracle (fresh ``runtime.plan`` at
    each segment's first step, no lag, no churn) and the static-stale
    baseline (segment-0's plan forever)."""
    cm = as_cost_model(cost_model)
    segs = drift.segments
    plan0 = _plan(segs[0].workload, cm, fast_bytes, policy=policy,
                  lookaheads=lookaheads, objective="latency")
    rpl = OnlineReplanner(cm, fast_bytes, window=window, threshold=threshold,
                          min_dwell=min_dwell,
                          churn_budget_bytes=churn_budget_bytes,
                          row_bytes=drift.row_bytes(), policy=policy,
                          lookaheads=lookaheads, lend_idle=lend_idle)
    rpl.adopt(plan0, step=0)
    seg_reports: List[SegmentReport] = []
    violations: Dict[str, int] = {}
    gstep = 0

    def note_violations(res) -> None:
        for tn, n in (res.tenant_violations or {}).items():
            violations[tn] = violations.get(tn, 0) + n

    for si, seg in enumerate(segs):
        wl = seg.workload
        steps = seg.num_steps
        tenant_reads = _tenant_read_series(wl)
        # the clairvoyant oracle: full knowledge at the segment's first step
        clair = plan0 if si == 0 else _plan(wl, cm, fast_bytes, policy=policy,
                                            lookaheads=lookaheads,
                                            objective="latency")
        clair_times = list(clair.predicted_step_times)
        clair_mig = [tr.mig_in + tr.mig_out for tr in clair.sim.step_traffic]
        # the static-stale baseline: segment-0's plan forever
        if si == 0:
            static_times, static_mig = clair_times, clair_mig
        else:
            static_times, static_mig, _ = _price_plan(wl, cm, fast_bytes,
                                                      plan0)
        # the online walk: price under the plan in effect, feed the
        # replanner, switch series when a delta lands
        cache: Dict[str, tuple] = {clair.digest(): (clair_times, clair_mig)}
        cur = None
        online_s = online_mig = 0.0
        local = 0
        while local < steps:
            if cur is None:
                key = rpl.plan.digest()
                if key not in cache:
                    t, m, res = _price_plan(wl, cm, fast_bytes, rpl.plan)
                    note_violations(res)
                    cache[key] = (t, m)
                cur = cache[key]
            online_s += cur[0][local]
            online_mig += cur[1][local]
            rpl.record(gstep, StepStat(
                time_s=cur[0][local], tokens=clair.sim.step_traffic[local]
                .tokens, mig_bytes=cur[1][local],
                tenant_reads=tenant_reads[local]))
            rpl.maybe_lend(gstep, segment=si)      # pricing is unchanged
            reason = rpl.drift_reason(gstep)
            if reason is not None:
                ev = rpl.replan(wl, gstep, reason, segment=si)
                if ev is not None and ev.applied:
                    # the re-layout copies stall the trigger step; the rest
                    # of the segment prices under the fresh plan
                    online_s += ev.churn_bytes / cm.mig_bw
                    if ev.plan.predicted_step_times:
                        cache.setdefault(ev.plan.digest(), (
                            list(ev.plan.predicted_step_times),
                            [tr.mig_in + tr.mig_out
                             for tr in ev.plan.sim.step_traffic]))
                    cur = None
            local += 1
            gstep += 1
        note_violations(clair.sim)
        seg_reports.append(SegmentReport(
            name=seg.name, steps=steps,
            tokens=int(sum(tr.tokens for tr in clair.sim.step_traffic)),
            online_s=online_s, clairvoyant_s=sum(clair_times),
            static_s=sum(static_times), online_mig_bytes=online_mig,
            clairvoyant_mig_bytes=sum(clair_mig),
            static_mig_bytes=sum(static_mig)))
    return OnlineReport(
        workload=drift.name, policy=plan0.policy,
        knobs={"window": rpl.window, "threshold": rpl.threshold,
               "min_dwell": rpl.min_dwell, "fast_bytes": fast_bytes},
        segments=seg_reports, events=rpl.events,
        churn_bytes=rpl.churn_spent,
        churn_budget_bytes=rpl.churn_budget_bytes,
        tenant_violations=dict(sorted(violations.items())), plan0=plan0)
