"""The unified placement-policy registry: every policy, every workload.

A **policy** decides which memory tier each data object lives in and what
migrates between timeline steps.  Policies register by name and are simulated
through one entry point::

    result = runtime.simulate(workload, hw, fast_bytes, "sentinel", lookahead=8)

``workload`` may be a training ``TraceProfile``, a serving ``ServeTrace``, or
anything implementing the ``Workload`` protocol (runtime/objects.py) — every
registered policy runs on every workload, which is what makes the baselines
comparable across scenarios.

Two families share the registry:

  event-driven   subclass the ``PlacementPolicy`` hook protocol
                 (on_free/on_admit/on_birth/on_reads/migrate); the shared
                 event loop replays the timeline step by step.  These are the
                 serving-native policies: ``prefer_fast``, ``lru_page``,
                 ``sentinel``.
  interval/static  override ``simulate`` directly.  These are the
                 training-native simulators re-expressed as policies:
                 ``sentinel_mi`` (the paper's MI-interval prefetch/evict
                 engine with §4.4 test-and-trial), ``ial``/``lru`` (the
                 page-grain reactive daemons), ``all_fast``/``all_slow``
                 (static placement bounds).

All of them return a ``PlacementResult``.  Per-policy semantics and the
incumbent tie-breaking rule live in ``docs/POLICIES.md``.
"""
from __future__ import annotations

import bisect
import collections
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Type

from repro.core.hardware import HWSpec
from repro.runtime.costmodel import StepTraffic
from repro.runtime.objects import AccessTimeline, as_workload


def _pread(tl: AccessTimeline, s: int) -> float:
    """Shared-KV read-back bytes of the cache-aware prefill at step ``s``
    (StepTraffic.prefill_read); 0 for timelines without skip information."""
    return tl.prefill_read_bytes[s] if tl.prefill_read_bytes else 0.0

PAGE_BYTES = 2 << 20          # huge-page granularity for page-grain baselines


# ==================================================================== result ==

@dataclass
class PlacementResult:
    """One simulated run of a policy over a workload timeline.

    ``time`` is seconds for the whole timeline (one training step, or the
    full decode schedule); ``compute_time`` the all-fast lower bound;
    ``tokens`` the decode tokens produced (0 for training).  The legacy
    ``SimResult``/``ServeSimResult`` names alias this class.
    """
    policy: str
    time: float
    compute_time: float
    tokens: int = 0
    migrations: int = 0
    bytes_s2f: float = 0.0
    bytes_f2s: float = 0.0
    stall_time: float = 0.0
    slow_bytes_accessed: float = 0.0
    cases: Dict[int, int] = field(default_factory=lambda: {1: 0, 2: 0, 3: 0})
    mi: int = 0
    detail: dict = field(default_factory=dict)
    # multi-tenant accounting (empty on untenanted runs): peak fast bytes a
    # tenant's objects occupied, and quota-violation events per tenant (a
    # within-guarantee read served from slow memory while another tenant
    # squatted beyond its own share) — see docs/POLICIES.md#sentinel_slo
    tenant_fast_bytes: Dict[str, float] = field(default_factory=dict)
    tenant_violations: Dict[str, int] = field(default_factory=dict)

    @property
    def step_time(self) -> float:          # legacy training alias
        return self.time

    @step_time.setter
    def step_time(self, v: float) -> None:
        self.time = v

    @property
    def slowdown(self) -> float:
        return self.time / max(self.compute_time, 1e-30)

    @property
    def throughput(self) -> float:         # timelines / second (training)
        return 1.0 / max(self.time, 1e-30)

    @property
    def decode_throughput(self) -> float:  # tokens / second (serving)
        return self.tokens / max(self.time, 1e-30)


# ================================================================== registry ==

POLICIES: Dict[str, Type["PlacementPolicy"]] = {}


def register_policy(name: str):
    """Class decorator: add a PlacementPolicy subclass to the registry."""
    def deco(cls):
        cls.name = name
        POLICIES[name] = cls
        return cls
    return deco


def get_policy(name: str) -> Type["PlacementPolicy"]:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown placement policy {name!r}; "
                       f"registered: {sorted(POLICIES)}") from None


def list_policies() -> List[str]:
    return sorted(POLICIES)


def simulate(workload, hw: HWSpec, fast_bytes: float,
             policy: str = "sentinel", *, tier_graph=None,
             **knobs) -> PlacementResult:
    """Replay ``workload`` under a registered policy — the one simulation
    entry point for training and serving alike.

    ``tier_graph`` runs the policy on an arbitrary memory topology
    (``runtime.tiergraph.TierGraph``): the graph folds to the duck-typed
    two-tier machine its compute node sees (``TierGraph.hw_view``), so
    every registered policy runs unchanged — on the canonical two-tier
    graph the fold reproduces ``hw`` exactly and the result is
    bit-identical to the legacy path."""
    if tier_graph is not None:
        hw = tier_graph.hw_view(hw)
    tl = as_workload(workload).timeline()
    return get_policy(policy).simulate(tl, hw, fast_bytes, **knobs)


# ======================================================= event-driven family ==

class PlacementPolicy:
    """Base: tracks placement (uid -> in fast?) and fast occupancy; charges
    migrations.  Subclasses override the hooks they care about.

    Hook order per timeline step t (driven by the shared event loop):
      on_free(t, objs)      objects at end of life disappear
      on_admit(t, objs)     pre-existing objects enter the timeline
                            (weights; prefill blocks of a refilled slot)
      on_birth(t, objs)     objects produced this step are born
      on_reads(t, objs)     -> (bytes_fast, bytes_slow) for this step's reads
      migrate(t, budget)    -> #migrations, off-critical-path volume capped
                               by budget (= step_time * mig_bw)
    """

    name = "base"
    granularity = "object"
    # Does the policy know the access schedule ahead of time?  Planned slow
    # reads stream behind compute (priced inside the pipe maximum); reactive
    # policies discover misses at touch time, so the cost model serializes
    # their slow reads (StepTraffic.demand_read).
    plans_ahead = False
    # Online re-planning (runtime/online.py): a policy supports incremental
    # ``PlacementPlan`` deltas when its entire decision state is re-derivable
    # from the plan's knobs — the replanner can then swap plans mid-stream
    # and the policy behaves as if it had been planned that way.  Reactive
    # policies (LRU paging, the caching daemons) and the MI-interval engine
    # carry history a delta cannot re-parameterize, so they opt out; the
    # online loop refuses them up front.  See docs/POLICIES.md.
    supports_replan = False

    @classmethod
    def replan_knobs(cls, plan) -> dict:
        """Simulation knobs that re-parameterize this policy from a plan —
        what the online replayer passes to ``simulate`` when pricing a
        traffic window under ``plan``.  Meaningful only when
        ``supports_replan`` is set."""
        return {}

    def __init__(self, timeline, hw, fast_bytes: float, **knobs):
        self.timeline = timeline
        # legacy attribute: policies written against the serve-only registry
        # stored the raw trace here
        self.trace = getattr(timeline, "source", timeline)
        self.hw, self.fast_bytes = hw, float(fast_bytes)
        self.knobs = knobs
        self.in_fast: Dict[int, bool] = {}
        self.live: Dict[int, object] = {}
        self.fast_used = 0.0
        self.peak_fast_used = 0.0
        self.migrations = 0
        self.bytes_s2f = 0.0
        self.bytes_f2s = 0.0
        self.slow_bytes_accessed = 0.0
        self.stall_time = 0.0
        # shared-object groups (equal non-None ``shared_key``): one physical
        # allocation, one tier, one capacity/migration charge for the group
        self._shared: Dict[tuple, dict] = {}
        # per-tenant accounting.  ``tenant_quotas`` (knob: tenant -> fraction
        # of the placement budget) turns on the violation metric for ANY
        # policy — quota-blind policies are measured against the same
        # guarantees the SLO-aware policy enforces.
        self.tenant_fast: Dict[str, float] = {}
        self.tenant_fast_peak: Dict[str, float] = {}
        self.tenant_violations: Dict[str, int] = {}
        q = knobs.get("tenant_quotas") or {}
        self.tenant_quotas: Dict[str, float] = \
            {str(k): float(v) * self.fast_bytes for k, v in q.items()}

    # ------------------------------------------------------------- helpers --
    @staticmethod
    def _group_key(o):
        return getattr(o, "shared_key", None)

    @staticmethod
    def _tenant_of(o) -> Optional[str]:
        tn = getattr(o, "tenant", None)
        return None if tn is None else str(tn)

    def _tenant_add(self, tn: Optional[str], b: float) -> None:
        if tn is None:
            return
        v = self.tenant_fast.get(tn, 0.0) + b
        self.tenant_fast[tn] = v
        if v > self.tenant_fast_peak.get(tn, 0.0):
            self.tenant_fast_peak[tn] = v

    def _group(self, o):
        """Live shared group of ``o``, or None (unshared / first member)."""
        k = self._group_key(o)
        if k is None:
            return None
        g = self._shared.get(k)
        return g if g and g["uids"] else None

    def _charge_bytes(self, o) -> float:
        """Capacity charge of placing ``o``: zero when its shared group is
        already resident (the physical pages exist exactly once)."""
        return 0.0 if self._group(o) is not None else o.bytes

    def _place(self, o, fast: bool):
        self.live[o.uid] = o
        k = self._group_key(o)
        if k is not None:
            g = self._group(o)
            if g is not None:              # adopt the group's placement, free
                g["uids"].add(o.uid)
                self.in_fast[o.uid] = g["fast"]
                return
            # the group's one capacity charge goes to the tenant that first
            # materialized the physical pages
            self._shared[k] = {"fast": fast, "uids": {o.uid},
                               "tn": self._tenant_of(o)}
        self.in_fast[o.uid] = fast
        if fast:
            self.fast_used += o.bytes
            self._tenant_add(self._tenant_of(o), o.bytes)

    def _demote(self, o):
        g = self._group(o)
        if g is not None:
            if g["fast"]:                  # whole group moves, bytes once
                g["fast"] = False
                for uid in g["uids"]:
                    self.in_fast[uid] = False
                self.fast_used -= o.bytes
                self._tenant_add(g.get("tn"), -o.bytes)
                self.migrations += 1
                self.bytes_f2s += o.bytes
            return
        if self.in_fast.get(o.uid):
            self.in_fast[o.uid] = False
            self.fast_used -= o.bytes
            self._tenant_add(self._tenant_of(o), -o.bytes)
            self.migrations += 1
            self.bytes_f2s += o.bytes

    def _promote(self, o):
        g = self._group(o)
        if g is not None:
            if not g["fast"]:
                g["fast"] = True
                for uid in g["uids"]:
                    self.in_fast[uid] = True
                self.fast_used += o.bytes
                self._tenant_add(g.get("tn"), o.bytes)
                self.migrations += 1
                self.bytes_s2f += o.bytes
            return
        if not self.in_fast.get(o.uid):
            self.in_fast[o.uid] = True
            self.fast_used += o.bytes
            self._tenant_add(self._tenant_of(o), o.bytes)
            self.migrations += 1
            self.bytes_s2f += o.bytes

    # --------------------------------------------------------------- hooks --
    def on_free(self, t: int, objs: Iterable) -> None:
        for o in objs:
            k = self._group_key(o)
            fast = self.in_fast.pop(o.uid, False)
            self.live.pop(o.uid, None)
            if k is not None:
                g = self._shared.get(k)
                if g is not None:
                    g["uids"].discard(o.uid)
                    if g["uids"]:
                        continue           # pages survive via other refs
                    self._shared.pop(k, None)
                    if g["fast"]:
                        self.fast_used -= o.bytes
                        self._tenant_add(g.get("tn"), -o.bytes)
                continue
            if fast:
                self.fast_used -= o.bytes
                self._tenant_add(self._tenant_of(o), -o.bytes)

    def on_admit(self, t: int, objs: Iterable) -> None:
        for o in objs:
            self._place(o, self.fast_used + self._charge_bytes(o)
                        <= self.fast_bytes)

    def on_birth(self, t: int, objs: Iterable) -> None:
        # objects just written by compute (fast-resident at production);
        # they stay fast if room remains, else they spill at birth
        self.on_admit(t, objs)

    def on_reads(self, t: int, objs: Iterable):
        bf = bs = 0.0
        for o in objs:
            if self.in_fast.get(o.uid, False):
                bf += o.bytes
            else:
                bs += o.bytes
                self._note_slow_read(o)
        self.slow_bytes_accessed += bs
        return bf, bs

    def _note_slow_read(self, o) -> None:
        """SLO accounting: a slow read is a quota *violation* when the
        reading tenant was still inside its guaranteed fast share (it was
        entitled to the capacity) while some other tenant occupied fast
        memory beyond its own share.  Quotas summing to <= 1 make the two
        conditions jointly imply a squatter denied the entitled tenant."""
        if not self.tenant_quotas:
            return
        tn = self._tenant_of(o)
        q = self.tenant_quotas.get(tn)
        if q is None or self.tenant_fast.get(tn, 0.0) + o.bytes > q:
            return                         # no guarantee, or demand beyond it
        if any(self.tenant_fast.get(j, 0.0) > qj + 1e-6
               for j, qj in self.tenant_quotas.items() if j != tn):
            self.tenant_violations[tn] = self.tenant_violations.get(tn, 0) + 1

    def migrate(self, t: int, budget_bytes: float) -> int:
        return 0

    # ------------------------------------------------------------ simulate --
    @classmethod
    def simulate(cls, workload, hw: HWSpec, fast_bytes: float,
                 **knobs) -> PlacementResult:
        """Replay the timeline through this policy's hooks (the shared
        event loop; interval/static policies override this instead)."""
        tl = as_workload(workload).timeline()
        # fast memory pre-committed to the reserve pool (training short-lived
        # objects) is off-limits to the policy; its traffic is in fixed_fast
        pol = cls(tl, hw, max(0.0, fast_bytes - tl.reserved_bytes), **knobs)
        total = compute_lb = 0.0
        tokens = 0
        traffic: List[StepTraffic] = []
        for t in range(tl.num_steps):
            s2f0, f2s0 = pol.bytes_s2f, pol.bytes_f2s
            stall0 = pol.stall_time
            pol.on_free(t, tl.frees.get(t, ()))
            pol.on_admit(t, tl.admits.get(t, ()))
            pol.on_birth(t, tl.births.get(t, ()))
            pol.peak_fast_used = max(pol.peak_fast_used, pol.fast_used)
            bf, bs = pol.on_reads(t, tl.reads.get(t, ()))
            fixed = tl.fixed_fast_bytes[t]
            t_step = max(tl.flops[t] / hw.peak_flops,
                         (bf + fixed) / hw.fast_bw + bs / hw.slow_bw)
            t_step += tl.extra_time(t, hw)
            migs = pol.migrate(t, t_step * hw.mig_bw)
            pol.peak_fast_used = max(pol.peak_fast_used, pol.fast_used)
            total += t_step + migs * hw.mig_overhead
            compute_lb += max(tl.flops[t] / hw.peak_flops,
                              (bf + bs + fixed) / hw.fast_bw)
            compute_lb += tl.extra_time(t, hw)
            tokens += tl.tokens[t]
            traffic.append(StepTraffic(
                flops=tl.flops[t], fast_read=bf + fixed, slow_read=bs,
                demand_read=0.0 if cls.plans_ahead else bs,
                mig_in=pol.bytes_s2f - s2f0, mig_out=pol.bytes_f2s - f2s0,
                tokens=tl.tokens[t], migs=migs,
                extra_flops=tl.extra_flops[t],
                extra_fast=tl.extra_fast_bytes[t],
                stall=pol.stall_time - stall0,
                prefill_flops=tl.extra_flops[t],
                prefill_read=_pread(tl, t)))
        total += pol.stall_time          # SLO repairs stall the decode stream
        res = PlacementResult(
            policy=cls.name, time=total, compute_time=compute_lb,
            tokens=tokens, migrations=pol.migrations, bytes_s2f=pol.bytes_s2f,
            bytes_f2s=pol.bytes_f2s, stall_time=pol.stall_time,
            slow_bytes_accessed=pol.slow_bytes_accessed,
            tenant_fast_bytes=dict(sorted(pol.tenant_fast_peak.items())),
            tenant_violations=dict(sorted(pol.tenant_violations.items())),
            detail={"fast_bytes": fast_bytes, "peak_kv": tl.peak_bytes(),
                    "peak_fast_used": pol.peak_fast_used, **knobs})
        # dynamic attribute (not a dataclass field): the per-step traffic a
        # CostModel prices; kept off asdict() so plan JSON stays byte-stable
        res.step_traffic = traffic
        return res


@register_policy("prefer_fast")
class PreferFast(PlacementPolicy):
    """Static PreferHBM: fast while room remains, no migration ever."""
    plans_ahead = True       # placement is fixed -> slow reads are streamable
    supports_replan = True   # stateless: any plan re-parameterizes it


@register_policy("lru_page")
class LRUPage(PlacementPolicy):
    """Page-grain reactive LRU with bump allocation (false sharing).

    Objects are packed into ``page_bytes`` pages in birth order, interleaving
    producers exactly like a bump allocator does.  Placement and migration
    are per *page*: a promoted page carries every byte it packs, dead or
    alive; a page's fast space is only reclaimed when all members died or
    when the page is demoted.  Promotion is reactive: a slow page touched
    since the last step becomes a candidate; the least-recently-touched fast
    pages are demoted to make room.
    """

    granularity = "page"

    class _Page:
        __slots__ = ("pid", "members", "live_bytes", "in_fast", "last_touch")

        def __init__(self, pid):
            self.pid = pid
            self.members: list = []
            self.live_bytes = 0.0
            self.in_fast = False
            self.last_touch = -1

    def __init__(self, timeline, hw, fast_bytes, *,
                 page_bytes: int = PAGE_BYTES, **knobs):
        super().__init__(timeline, hw, fast_bytes, **knobs)
        self.page_bytes = float(page_bytes)
        self.pages: List[LRUPage._Page] = []
        self.page_of: Dict[int, LRUPage._Page] = {}
        self._open: Optional[LRUPage._Page] = None
        self._open_fill = 0.0
        self._touched_slow: "collections.OrderedDict" = collections.OrderedDict()

    def _alloc(self, o):
        if self._open is None or self._open_fill + o.bytes > self.page_bytes:
            pg = LRUPage._Page(len(self.pages))
            pg.in_fast = self.fast_used + self.page_bytes <= self.fast_bytes
            if pg.in_fast:
                self.fast_used += self.page_bytes
            self.pages.append(pg)
            self._open, self._open_fill = pg, 0.0
        pg = self._open
        pg.members.append(o)
        pg.live_bytes += o.bytes
        self._open_fill += o.bytes
        self.page_of[o.uid] = pg
        self.live[o.uid] = o
        self.in_fast[o.uid] = pg.in_fast

    def on_admit(self, t, objs):
        for o in objs:
            self._alloc(o)

    on_birth = on_admit

    def on_free(self, t, objs):
        for o in objs:
            pg = self.page_of.pop(o.uid, None)
            self.live.pop(o.uid, None)
            self.in_fast.pop(o.uid, None)
            if pg is None:
                continue
            pg.live_bytes -= o.bytes
            if pg.live_bytes <= 0 and pg is not self._open:
                # fully dead page: space reclaimed (only now — false sharing
                # kept the dead bytes resident until the last member died)
                if pg.in_fast:
                    self.fast_used -= self.page_bytes
                pg.in_fast = False

    def on_reads(self, t, objs):
        bf = bs = 0.0
        for o in objs:
            pg = self.page_of[o.uid]
            pg.last_touch = t
            if pg.in_fast:
                bf += o.bytes
            else:
                bs += o.bytes
                self._touched_slow[pg.pid] = pg
        self.slow_bytes_accessed += bs
        return bf, bs

    def migrate(self, t, budget_bytes):
        moved = 0
        # most recently touched slow pages first (reactive promotion)
        for pid in reversed(list(self._touched_slow)):
            pg = self._touched_slow.pop(pid)
            if pg.live_bytes <= 0 or budget_bytes < self.page_bytes:
                continue
            # demote LRU fast pages until the candidate fits
            while self.fast_used + self.page_bytes > self.fast_bytes and \
                    budget_bytes >= self.page_bytes:
                victims = [p for p in self.pages
                           if p.in_fast and p.live_bytes > 0]
                if not victims:
                    break
                v = min(victims, key=lambda p: p.last_touch)
                if v.last_touch >= pg.last_touch:
                    break                      # nothing colder than candidate
                v.in_fast = False
                self.fast_used -= self.page_bytes
                for m in v.members:
                    if m.uid in self.in_fast:
                        self.in_fast[m.uid] = False
                budget_bytes -= self.page_bytes
                self.migrations += 1
                self.bytes_f2s += self.page_bytes
                moved += 1
            if self.fast_used + self.page_bytes <= self.fast_bytes and \
                    budget_bytes >= self.page_bytes:
                pg.in_fast = True
                self.fast_used += self.page_bytes
                for m in pg.members:
                    if m.uid in self.in_fast:
                        self.in_fast[m.uid] = True
                budget_bytes -= self.page_bytes
                self.migrations += 1
                self.bytes_s2f += self.page_bytes
                moved += 1
        self._touched_slow.clear()
        return moved


@register_policy("sentinel")
class SentinelLifetime(PlacementPolicy):
    """Lifetime-aware object policy with look-ahead prefetch.

    The access schedule is known (decode repeats per token, training repeats
    per step — the paper's repeatability), so each object's exact next access
    is available.  Every step the policy (a) prefetches objects whose next
    access falls within ``lookahead`` steps, (b) evicts the objects whose
    next access is farthest away (or never) to make room — Belady at object
    granularity, bandwidth-capped like the paper's migration threads.
    """

    plans_ahead = True
    # the whole decision state is (lookahead, windows) — all plan knobs, so
    # an online delta fully re-parameterizes the policy mid-stream
    supports_replan = True

    def __init__(self, timeline, hw, fast_bytes, *, lookahead: int = 8,
                 **knobs):
        super().__init__(timeline, hw, fast_bytes, **knobs)
        self.lookahead = max(1, int(lookahead))

    @classmethod
    def replan_knobs(cls, plan) -> dict:
        return {"lookahead": int(plan.lookahead)} if plan.lookahead else {}

    @staticmethod
    def _next_access(o, t: int) -> Optional[int]:
        i = bisect.bisect_right(o.accesses, t)
        return o.accesses[i] if i < len(o.accesses) else None

    def _score(self, o, t: int) -> int:
        """Known accesses within the look-ahead horizon (per-token Eq. 2:
        this is the reuse the migration bandwidth can still buy back)."""
        lo = bisect.bisect_right(o.accesses, t)
        hi = bisect.bisect_right(o.accesses, t + self.lookahead)
        return hi - lo

    def _group_members(self, o):
        """Live members of ``o``'s shared group (just ``o`` when unshared) —
        a shared page's placement serves every sharer, so eviction decisions
        must consider the whole group."""
        g = self._group(o)
        if g is None:
            return [o]
        return [self.live[uid] for uid in g["uids"] if uid in self.live]

    def _group_next_access(self, o, t: int) -> Optional[int]:
        """Soonest next access across the group (Belady on shared pages)."""
        nas = [self._next_access(m, t) for m in self._group_members(o)]
        nas = [x for x in nas if x is not None]
        return min(nas) if nas else None

    def _evict_for(self, need: float, t: int) -> None:
        """Make room by evicting farthest-next-access fast objects (Belady
        on the known schedule; shared groups judged by their most-urgent
        member, since demoting one member moves the whole group)."""
        if self.fast_used + need <= self.fast_bytes:
            return
        victims = [o for o in self.live.values() if self.in_fast.get(o.uid)]
        victims.sort(key=lambda o: -(self._group_next_access(o, t) or 10 ** 12))
        for v in victims:
            if self.fast_used + need <= self.fast_bytes:
                break
            self._demote(v)

    def on_admit(self, t, objs):
        # placement at birth is free (data is written to its tier directly):
        # hot-window objects displace colder incumbents, cold history is born
        # slow — the serving analogue of "born in fast" vs residual offload
        for o in objs:
            if self._group(o) is not None:
                self._place(o, True)        # pages already resident: free ride
                continue
            if self._score(o, t - 1) == 0:
                self._place(o, False)
                continue
            self._evict_for(o.bytes, t)
            self._place(o, self.fast_used + o.bytes <= self.fast_bytes)

    on_birth = on_admit

    def _desired_fast_set(self, t, scored) -> set:
        """Greedy-by-score fast set (Belady with known schedules); shared
        groups charge capacity once.  ``sentinel_slo`` overrides this with a
        quota-partitioned construction — the promote/demote machinery in
        ``migrate`` is shared."""
        target = set()
        used = 0.0
        seen_groups = set()
        for sc, o in scored:
            if sc <= 0:
                break
            k = self._group_key(o)
            eff = o.bytes if k is None or k not in seen_groups else 0.0
            if used + eff <= self.fast_bytes:
                target.add(o.uid)
                used += eff
                if k is not None:
                    seen_groups.add(k)
        return target

    def migrate(self, t, budget_bytes):
        migs0 = self.migrations
        live = list(self.live.values())
        scored = [(self._score(o, t), o) for o in live]
        # desired fast set: greedy by score; incumbents win ties so
        # equal-rate history objects never ping-pong between tiers
        scored.sort(key=lambda p: (-p[0], not self.in_fast.get(p[1].uid),
                                   p[1].uid))
        target = self._desired_fast_set(t, scored)
        promotes = [o for sc, o in scored
                    if o.uid in target and not self.in_fast.get(o.uid)]
        promotes.sort(key=lambda o: self._next_access(o, t) or 10 ** 12)
        for o in promotes:
            if self.in_fast.get(o.uid):
                continue                    # shared group already moved
            if o.bytes > budget_bytes:
                break
            while self.fast_used + o.bytes > self.fast_bytes:
                # demoting any member moves its whole shared group, so a
                # group with a member in the target set is never a victim
                # (else demote/promote would ping-pong the group's bytes)
                victims = [v for v in live if self.in_fast.get(v.uid)
                           and not any(m.uid in target
                                       for m in self._group_members(v))]
                if not victims:
                    break
                v = min(victims, key=lambda v: max(
                    self._score(m, t) for m in self._group_members(v)))
                if v.bytes > budget_bytes:
                    budget_bytes = -1.0
                    break
                self._demote(v)
                budget_bytes -= v.bytes
            if budget_bytes < 0 or self.fast_used + o.bytes > self.fast_bytes:
                break
            self._promote(o)
            budget_bytes -= o.bytes
        return self.migrations - migs0


@register_policy("sentinel_slo")
class SentinelSLO(SentinelLifetime):
    """SLO-aware multi-tenant variant of ``sentinel``.

    Same lifetime knowledge (Belady on the known access schedule), but the
    fast tier is partitioned by per-tenant *guarantees*:

      quotas         ``tenant_quotas`` (tenant -> fraction of the placement
                     budget, summing to <= 1) are each tenant's guaranteed
                     share.  Default: equal shares over the tenants tagged in
                     the timeline.
      work-conserving borrowing
                     capacity a tenant leaves idle is lent out — the desired
                     fast set is built in two passes, first each tenant's
                     best objects within its own quota, then global Belady
                     over whatever room remains.
      graceful degradation
                     borrowed capacity is revocable: when a within-guarantee
                     placement needs room, borrowers are demoted first,
                     ordered by SLO slack (``tenant_slack``; loosest SLO
                     degrades first), never a tenant inside its own share.
      repair-on-read as a backstop, an entitled read about to hit slow
                     memory is promoted first (the migration stalls the
                     stream — charged to ``stall_time``), so a tenant inside
                     its guarantee never reads from slow memory while a
                     squatter holds its share: ``tenant_violations`` is zero
                     by construction whenever the quotas sum to <= 1.
    """

    def __init__(self, timeline, hw, fast_bytes, *, tenant_quotas=None,
                 tenant_slack=None, lookahead: int = 8, **knobs):
        if tenant_quotas is None:
            tenants = sorted({str(o.tenant) for o in timeline.objects
                              if getattr(o, "tenant", None) is not None})
            tenant_quotas = {tn: 1.0 / len(tenants) for tn in tenants} \
                if tenants else {}
        super().__init__(timeline, hw, fast_bytes, lookahead=lookahead,
                         tenant_quotas=tenant_quotas, **knobs)
        self.tenant_slack: Dict[str, float] = \
            {str(k): float(v) for k, v in (tenant_slack or {}).items()}

    # --------------------------------------------------------- quota state --
    def _quota_of(self, tn: Optional[str]) -> Optional[float]:
        return None if tn is None else self.tenant_quotas.get(tn)

    def _within_quota(self, o) -> bool:
        """Would placing ``o`` fast keep its tenant inside its guarantee?"""
        tn = self._tenant_of(o)
        q = self._quota_of(tn)
        return q is not None and \
            self.tenant_fast.get(tn, 0.0) + self._charge_bytes(o) <= q

    def _is_borrower(self, o) -> bool:
        """Fast-resident beyond (or outside) any guarantee: revocable."""
        g = self._group(o)
        tn = g.get("tn") if g is not None else self._tenant_of(o)
        q = self._quota_of(tn)
        return q is None or self.tenant_fast.get(tn, 0.0) > q + 1e-6

    def _slack_of(self, o) -> float:
        g = self._group(o)
        tn = g.get("tn") if g is not None else self._tenant_of(o)
        if tn is None:
            return float("inf")            # untenanted: degrades first
        return self.tenant_slack.get(tn, 1.0)

    def _reclaim_for(self, need: float, t: int, protect: Optional[str]):
        """Make room for a within-guarantee placement: demote borrowers
        first (loosest SLO first, then farthest next access), falling back
        to plain Belady only if no borrower remains.  When every tenant is
        inside its quota and the quotas sum to <= 1, the borrower pass alone
        always finds the room."""
        if self.fast_used + need <= self.fast_bytes:
            return
        victims = [o for o in self.live.values() if self.in_fast.get(o.uid)
                   and self._tenant_of(o) != protect]
        victims.sort(key=lambda o: (
            -self._slack_of(o),
            -(self._group_next_access(o, t) or 10 ** 12), o.uid))
        for v in victims:
            if self.fast_used + need <= self.fast_bytes:
                return
            if self._is_borrower(v):
                self._demote(v)
        self._evict_for(need, t)

    # ----------------------------------------------------------- placement --
    def on_admit(self, t, objs):
        for o in objs:
            if self._group(o) is not None:
                self._place(o, True)       # pages already resident: free ride
                continue
            within = self._within_quota(o)
            if not within and self._score(o, t - 1) == 0:
                self._place(o, False)      # cold and beyond guarantee
                continue
            if within:
                self._reclaim_for(o.bytes, t, self._tenant_of(o))
            else:
                self._evict_for(o.bytes, t)
            self._place(o, self.fast_used + o.bytes <= self.fast_bytes)

    on_birth = on_admit

    def on_reads(self, t, objs):
        # repair-on-read: an entitled access about to hit slow memory pulls
        # the object in first, reclaiming lent capacity; the copy is on the
        # critical path (the paper's Case-3 stall, per object)
        for o in objs:
            if self.in_fast.get(o.uid, False) or not self._within_quota(o):
                continue
            self._reclaim_for(o.bytes, t, self._tenant_of(o))
            if self.fast_used + self._charge_bytes(o) <= self.fast_bytes:
                self._promote(o)
                self.stall_time += o.bytes / self.hw.mig_bw
        return super().on_reads(t, objs)

    # ----------------------------------------------------------- migration --
    def _desired_fast_set(self, t, scored) -> set:
        """Two-pass target: guaranteed shares first (each tenant's best
        objects within its own quota), then work-conserving borrowing of
        whatever capacity is left, by global score order."""
        target = set()
        used = 0.0
        tenant_used: Dict[str, float] = {}
        seen_groups = set()
        for sc, o in scored:               # pass 1: inside the guarantees
            if sc <= 0:
                break
            tn = self._tenant_of(o)
            q = self._quota_of(tn)
            if q is None:
                continue
            k = self._group_key(o)
            eff = o.bytes if k is None or k not in seen_groups else 0.0
            if tenant_used.get(tn, 0.0) + eff <= q and \
                    used + eff <= self.fast_bytes:
                target.add(o.uid)
                used += eff
                tenant_used[tn] = tenant_used.get(tn, 0.0) + eff
                if k is not None:
                    seen_groups.add(k)
        for sc, o in scored:               # pass 2: borrow the idle rest
            if sc <= 0:
                break
            if o.uid in target:
                continue
            k = self._group_key(o)
            eff = o.bytes if k is None or k not in seen_groups else 0.0
            if used + eff <= self.fast_bytes:
                target.add(o.uid)
                used += eff
                if k is not None:
                    seen_groups.add(k)
        return target


# ===================================================== interval/static units ==

@dataclass
class Unit:
    """The migration unit of the interval/page simulators: one object, or one
    page packing many objects."""
    uid: int
    bytes: int
    accesses: Sequence[int]     # sorted step indices
    long_lived: bool
    short_lived_resident: bool  # lives in the reserved pool (Sentinel)


def build_units(profile, granularity: str = "object",
                page_mode: str = "sentinel") -> List[Unit]:
    """Units from a training TraceProfile.  granularity 'object': Sentinel's
    view.  'page': pack objects into pages (page_mode 'original' reproduces
    false sharing)."""
    from repro.core.allocator import pack_pages
    acts = [o for o in profile.objects
            if o.kind == "activation" and o.accesses and not o.fused]
    weights = [o for o in profile.objects if o.kind == "weight" and o.accesses]
    units: List[Unit] = []
    if granularity == "object":
        for o in acts:
            units.append(Unit(o.uid, o.size, sorted(set(o.accesses)),
                              o.lifetime >= 2, o.lifetime <= 1))
        for o in weights:
            units.append(Unit(o.uid, o.size, sorted(set(o.accesses)), True, False))
    else:
        pages, _ = pack_pages(acts + weights, page_mode)
        for p in pages:
            accesses = p.accesses
            if not accesses:
                continue
            long_lived = p.death - p.birth >= 2 or \
                any(o.kind == "weight" for o in p.objects)
            units.append(Unit(100_000_000 + p.pid, p.bytes, accesses,
                              long_lived, not long_lived))
    return units


def _timeline_units(tl: AccessTimeline, granularity: str,
                    page_mode: str) -> List[Unit]:
    """Units for the interval/page simulators on any workload timeline."""
    if tl.kind == "training" and tl.source is not None:
        return build_units(tl.source, granularity, page_mode)
    objs = [o for o in tl.objects if o.accesses]
    if granularity == "object":
        return [Unit(o.uid, o.bytes, sorted(set(o.accesses)),
                     o.death - o.birth >= 2, o.death - o.birth < 2)
                for o in objs]
    # page granularity on a non-training workload: generic bump packing in
    # birth order (the same false-sharing regime as allocator 'original')
    units: List[Unit] = []
    cur_access: set = set()
    cur_fill = 0.0
    cur_long = False
    pid = 0

    def flush():
        nonlocal pid, cur_access, cur_fill, cur_long
        if cur_access:
            units.append(Unit(100_000_000 + pid, int(PAGE_BYTES),
                              sorted(cur_access), cur_long, not cur_long))
            pid += 1
        cur_access, cur_fill, cur_long = set(), 0.0, False

    for o in sorted(objs, key=lambda o: (o.birth, o.uid)):
        if cur_fill + o.bytes > PAGE_BYTES and cur_fill > 0:
            flush()
        cur_access.update(o.accesses)
        cur_fill += o.bytes
        cur_long = cur_long or (o.death - o.birth >= 2)
    flush()
    return units


def _all_fast_times(tl: AccessTimeline, hw: HWSpec) -> List[float]:
    """All-fast compute time per timeline step (roofline max of the two)."""
    return [tl.step_time_all_fast(s, hw) for s in range(tl.num_steps)]


@register_policy("alpha_migration")
class AlphaMigration(SentinelLifetime):
    """Sentinel with a bandwidth-optimal stopping rule for promotion.

    Splitting a read stream alpha fast / (1-alpha) slow equalizes the two
    memory pipes' service times at ``alpha* = B_fast / (B_fast + B_ext)``
    (fangyunh's AlphaMigration; derivation in docs/POLICIES.md): reads
    promoted beyond that split cannot shorten the step — the fast pipe is
    already the slower of the two — they only add migration traffic.  So
    this policy builds the same greedy-by-score fast set as ``sentinel`` but
    stops admitting objects once the covered look-ahead read bytes reach
    alpha* of the horizon's total, deliberately leaving the cold tail slow.

    Under the byte-domain clock it can only tie or lose to ``sentinel``
    (slow reads always cost there); under a ``CostModel`` with a real host
    tier the saved migration traffic wins — which is exactly the
    ``objective="latency"`` planner's reason to consider it.

    Knobs: ``lookahead`` (inherited), ``alpha`` (override the derived
    split; default ``B_fast / (B_fast + min(slow_read_bw, host_internal))``
    from the hw/CostModel it runs on).
    """

    def __init__(self, timeline, hw, fast_bytes, *,
                 alpha: Optional[float] = None, **knobs):
        super().__init__(timeline, hw, fast_bytes, **knobs)
        if alpha is None:
            ext = min(getattr(hw, "slow_read_bw", hw.slow_bw),
                      getattr(hw, "host_internal_bw", float("inf")))
            alpha = hw.fast_bw / (hw.fast_bw + ext)
        self.alpha = min(1.0, max(0.0, float(alpha)))

    def _desired_fast_set(self, t, scored) -> set:
        # goal: cover alpha* of the horizon's placeable read bytes
        # (score * bytes = known reads of the object within the look-ahead)
        goal = self.alpha * sum(sc * o.bytes for sc, o in scored if sc > 0)
        target = set()
        used = covered = 0.0
        seen_groups = set()
        for sc, o in scored:
            if sc <= 0 or covered >= goal:
                break
            k = self._group_key(o)
            eff = o.bytes if k is None or k not in seen_groups else 0.0
            if used + eff <= self.fast_bytes:
                target.add(o.uid)
                used += eff
                covered += sc * o.bytes
                if k is not None:
                    seen_groups.add(k)
        return target


# ====================================================== interval (sentinel) ==

@register_policy("sentinel_mi")
class SentinelMI(PlacementPolicy):
    """The paper's training runtime (§4.4) as a registered policy:
    MI-interval prefetch slow->fast overlapped with compute, mid-interval
    eviction of units not needed soon, Case 1/2/3 accounting, and optional
    test-and-trial over the Case-3 resolution.

    Knobs: ``mi`` (migration interval in timeline steps; default num_steps/8),
    ``test_and_trial``, ``stall_on_case3``, ``reserve_pool``,
    ``granularity``/``page_mode`` (object vs page units).
    """

    plans_ahead = True

    @classmethod
    def simulate(cls, workload, hw: HWSpec, fast_bytes: float, *,
                 mi: Optional[int] = None, test_and_trial: bool = True,
                 stall_on_case3: bool = True, reserve_pool: bool = True,
                 granularity: str = "object",
                 page_mode: str = "sentinel") -> PlacementResult:
        tl = as_workload(workload).timeline()
        if mi is None:
            mi = max(1, tl.num_steps // 8)
        kw = dict(reserve_pool=reserve_pool, granularity=granularity,
                  page_mode=page_mode)
        if not test_and_trial:
            return cls._run(tl, hw, fast_bytes, mi,
                            stall_on_case3=stall_on_case3, **kw)
        # test-and-trial (§4.4): try both Case-3 resolutions, keep the winner
        a = cls._run(tl, hw, fast_bytes, mi, stall_on_case3=True, **kw)
        if a.cases[3] == 0:
            a.detail["tt_choice"] = "n/a"
            return a
        b = cls._run(tl, hw, fast_bytes, mi, stall_on_case3=False, **kw)
        best = a if a.time <= b.time else b
        best.detail["tt_choice"] = "stall" if best is a else "slow-access"
        best.detail["tt_steps_used"] = 2
        return best

    @classmethod
    def _run(cls, tl: AccessTimeline, hw: HWSpec, fast_bytes: float, mi: int,
             *, stall_on_case3: bool, reserve_pool: bool, granularity: str,
             page_mode: str) -> PlacementResult:
        """One MI run: at the start of interval A the data needed by interval
        B is prefetched slow->fast overlapped with A's compute; long-lived
        units not needed soon are evicted fast->slow mid-interval (this is
        what frees space for the residual-offload pattern).  Newly produced
        long-lived units are always born in fast."""
        units = _timeline_units(tl, granularity, page_mode)
        steps = tl.num_steps
        t_step = _all_fast_times(tl, hw)
        res = PlacementResult(cls.name, 0.0, sum(t_step),
                              tokens=sum(tl.tokens), mi=mi)
        # per-step traffic for CostModel pricing: demand reads are exact;
        # interval-level migration/stall is spread evenly over the
        # interval's steps (the DMA runs concurrently with all of them)
        records: List[StepTraffic] = []
        snap = [0.0, 0.0, 0, 0.0]      # bytes_s2f, bytes_f2s, migs, stall

        access_map: Dict[int, List[Unit]] = collections.defaultdict(list)
        for u in units:
            for s in u.accesses:
                access_map[s].append(u)

        rs = tl.reserve_bytes(mi) if reserve_pool else 0.0
        budget = max(0.0, fast_bytes - rs)

        movable = [u for u in units if u.long_lived]
        in_fast: Dict[int, bool] = {u.uid: False for u in movable}
        fast_used = 0.0

        def next_access_after(u: Unit, s: int) -> Optional[int]:
            for a in u.accesses:
                if a > s:
                    return a
            return None

        slow_resident = {u.uid for u in movable if u.bytes > budget}
        # (paper §4.5: fast memory must at least fit RS + the largest
        # long-lived object; units violating that are pinned slow)

        def force_evict(need: float, now: int, horizon: int) -> float:
            """Make room for `need` bytes by evicting farthest-next-access
            units.  Returns bytes evicted (charged to the eviction channel)."""
            nonlocal fast_used
            victims = [u for u in movable if in_fast.get(u.uid, False)]
            victims.sort(key=lambda u: -(next_access_after(u, now) or 10 ** 9))
            freed = 0.0
            for u in victims:
                if fast_used + need <= budget:
                    break
                in_fast[u.uid] = False
                fast_used -= u.bytes
                freed += u.bytes
                res.migrations += 1
                res.bytes_f2s += u.bytes
            return freed

        # initial prefetch: units needed by interval 0, by first-use order
        first = [u for u in movable if any(a < mi for a in u.accesses)
                 and u.uid not in slow_resident]
        peak_fast = 0.0

        def bump(b: float) -> None:
            nonlocal fast_used, peak_fast
            fast_used += b
            peak_fast = max(peak_fast, fast_used)

        first.sort(key=lambda u: u.accesses[0])
        for u in first:
            if fast_used + u.bytes <= budget:
                in_fast[u.uid] = True
                bump(u.bytes)
                res.migrations += 1
                res.bytes_s2f += u.bytes

        intervals = [(i, min(i + mi, steps)) for i in range(0, steps, mi)]
        total = 0.0

        for (lo, hi) in intervals:
            nxt_lo, nxt_hi = hi, min(hi + mi, steps)
            migs_before = res.migrations

            # -- execute interval: compute + penalties + births + evictions --
            interval_compute = 0.0
            forced_evict_bytes = 0.0
            for s in range(lo, hi):
                bytes_slow = 0.0
                for u in access_map.get(s, ()):
                    if not u.long_lived:
                        continue
                    if u.uid in slow_resident:
                        bytes_slow += u.bytes
                        res.slow_bytes_accessed += u.bytes
                        continue
                    if u.accesses[0] == s and not in_fast.get(u.uid, False):
                        # birth: produced into fast, forcing eviction if full
                        if fast_used + u.bytes > budget:
                            forced_evict_bytes += force_evict(u.bytes, s,
                                                              nxt_hi)
                        if fast_used + u.bytes <= budget:
                            in_fast[u.uid] = True
                            bump(u.bytes)
                        else:                    # truly no room: spills slow
                            slow_resident.add(u.uid)
                            bytes_slow += u.bytes
                            res.slow_bytes_accessed += u.bytes
                    elif not in_fast.get(u.uid, False):
                        bytes_slow += u.bytes    # read from slow
                        res.slow_bytes_accessed += u.bytes
                if not reserve_pool:
                    # "no space reservation" ablation: short-lived units
                    # demand fast space; the shortfall is slow-accessed
                    short_here = sum(u.bytes for u in access_map.get(s, ())
                                     if u.short_lived_resident)
                    free = fast_bytes - fast_used
                    overflow = max(0.0, short_here - max(0.0, free))
                    bytes_slow += overflow
                    res.slow_bytes_accessed += overflow
                t_fast = max(0.0, tl.total_bytes[s] - bytes_slow)
                t = max(tl.flops[s] / hw.peak_flops,
                        t_fast / hw.fast_bw + bytes_slow / hw.slow_bw)
                t += tl.extra_time(s, hw)
                interval_compute += t
                records.append(StepTraffic(
                    flops=tl.flops[s], fast_read=t_fast,
                    slow_read=bytes_slow, tokens=tl.tokens[s],
                    extra_flops=tl.extra_flops[s],
                    extra_fast=tl.extra_fast_bytes[s],
                    prefill_flops=tl.extra_flops[s],
                    prefill_read=_pread(tl, s)))

            # -- eviction channel accounting (fast->slow, full duplex) --
            evict_capacity = interval_compute * hw.mig_bw - forced_evict_bytes
            if evict_capacity < 0:                # write-back pressure stalls
                stall = -evict_capacity / hw.mig_bw
                res.stall_time += stall
                total += stall
                evict_capacity = 0.0
            # scheduled mid-interval eviction: units not needed before nxt_hi
            candidates = [u for u in movable if in_fast.get(u.uid, False)]
            candidates.sort(
                key=lambda u: -(next_access_after(u, hi - 1) or 10 ** 9))
            for u in candidates:
                na = next_access_after(u, hi - 1)
                if na is not None and na < nxt_hi:
                    continue                      # needed soon: keep
                if u.bytes > evict_capacity:
                    break
                evict_capacity -= u.bytes
                in_fast[u.uid] = False
                fast_used -= u.bytes
                res.migrations += 1
                res.bytes_f2s += u.bytes

            # -- prefetch for the next interval (slow->fast channel) --
            pending = [u for u in movable
                       if not in_fast[u.uid] and u.uid not in slow_resident
                       and any(nxt_lo <= a < nxt_hi for a in u.accesses)]
            pending.sort(
                key=lambda u: next_access_after(u, nxt_lo - 1) or nxt_lo)
            capacity = interval_compute * hw.mig_bw
            space_blocked = False
            while pending:
                u = pending[0]
                if fast_used + u.bytes > budget:
                    space_blocked = True
                    break
                if u.bytes > capacity:
                    break
                capacity -= u.bytes
                bump(u.bytes)
                in_fast[u.uid] = True
                res.migrations += 1
                res.bytes_s2f += u.bytes
                pending.pop(0)

            # per-migration fixed overhead (move_pages/TLB shootdown on CPU
            # HM, DMA dispatch on TPU) — exposed on the critical path
            interval_migs = res.migrations - migs_before
            total += interval_migs * hw.mig_overhead

            total += interval_compute
            if nxt_lo >= steps:
                pass                              # no next interval: no case
            elif not pending:
                res.cases[1] += 1
            elif space_blocked:
                res.cases[2] += 1                 # leave in slow
            else:
                res.cases[3] += 1
                if stall_on_case3:
                    stall = 0.0
                    for u in list(pending):
                        if fast_used + u.bytes <= budget:
                            stall += u.bytes / hw.mig_bw
                            bump(u.bytes)
                            in_fast[u.uid] = True
                            res.migrations += 1
                            res.bytes_s2f += u.bytes
                            pending.remove(u)
                    res.stall_time += stall
                    total += stall
                # else: leave in slow, pay access penalty next interval

            n = hi - lo
            for r in records[-n:]:
                r.mig_in += (res.bytes_s2f - snap[0]) / n
                r.mig_out += (res.bytes_f2s - snap[1]) / n
                r.migs += (res.migrations - snap[2]) / n
                r.stall += (res.stall_time - snap[3]) / n
            snap = [res.bytes_s2f, res.bytes_f2s,
                    res.migrations, res.stall_time]

        res.time = total
        res.detail = {"fast_budget": budget, "rs": rs,
                      "peak_fast_used": peak_fast}
        res.step_traffic = records
        return res


# ================================================= page-grain reactive (HM) ==

class _CachingDaemon(PlacementPolicy):
    """Page-grain reactive baselines (IAL from Yan et al. ASPLOS'19, LRU).

    Two FIFO lists (active/inactive).  Pages are *not* demand-migrated — a
    periodic optimization pass (the every-5-seconds daemon; here
    ``opts_per_step`` passes per timeline replay) promotes recently
    re-accessed slow pages into fast memory and demotes inactive-list pages
    when fast memory is full.  Between passes, slow pages are accessed in
    slow memory — the detection *lag* is exactly the paper's criticism, and
    page-grain false sharing (page_mode='original') makes the promoted bytes
    partly useless.

    The timeline repeats identically (training steps; decode schedules), so
    we replay ``repeats`` times and report the last (steady state: recurring
    pages have been classified).
    """

    granularity = "page"
    recency = False               # IAL: FIFO; LRU subclass: recency ordering

    @classmethod
    def simulate(cls, workload, hw: HWSpec, fast_bytes: float, *,
                 page_mode: str = "original", repeats: int = 3,
                 opts_per_step: int = 4) -> PlacementResult:
        tl = as_workload(workload).timeline()
        units = _timeline_units(tl, "page", page_mode)
        steps = tl.num_steps
        res = PlacementResult(cls.name, 0.0, sum(_all_fast_times(tl, hw)),
                              tokens=sum(tl.tokens))

        access_map: Dict[int, List[Unit]] = collections.defaultdict(list)
        for u in units:
            for s in u.accesses:
                access_map[s].append(u)

        in_fast: Dict[int, bool] = {u.uid: False for u in units}
        fast_used = 0.0
        by_uid = {u.uid: u for u in units}
        # list state: uid -> last-touch tick; FIFO order by insertion
        active: collections.OrderedDict = collections.OrderedDict()
        inactive: collections.OrderedDict = collections.OrderedDict()
        touched_since_opt: collections.OrderedDict = collections.OrderedDict()
        seen_before: set = set()

        opt_every = max(1, steps // max(1, opts_per_step))

        def optimization_pass(bw_budget: float):
            """Promote recently re-touched slow pages; demote FIFO-head
            pages.  Migration volume per pass is bounded by the elapsed-time
            bandwidth product (parallel copy threads, Yan et al.)."""
            nonlocal fast_used
            moved = 0
            for uid in list(touched_since_opt):
                if bw_budget <= 0:
                    break
                u = by_uid[uid]
                if in_fast[uid]:
                    # fast page touched again: inactive -> active promotion
                    if uid in inactive:
                        inactive.pop(uid)
                        active[uid] = True
                    elif cls.recency and uid in active:
                        active.move_to_end(uid)
                    continue
                if uid not in seen_before:
                    continue  # second-touch rule: first sighting classifies
                # slow page was re-touched: candidate for promotion
                while fast_used + u.bytes > fast_bytes and bw_budget > 0:
                    src = inactive if inactive else active
                    if not src:
                        break
                    vid, _ = src.popitem(last=False)      # FIFO/LRU head
                    v = by_uid[vid]
                    if in_fast[vid]:
                        in_fast[vid] = False
                        fast_used -= v.bytes
                        res.migrations += 1
                        res.bytes_f2s += v.bytes
                        bw_budget -= v.bytes
                        moved += 1
                if fast_used + u.bytes <= fast_bytes and bw_budget > 0:
                    in_fast[uid] = True
                    fast_used += u.bytes
                    res.detail["peak_fast_used"] = max(
                        res.detail.get("peak_fast_used", 0.0), fast_used)
                    inactive[uid] = True
                    res.migrations += 1
                    res.bytes_s2f += u.bytes
                    bw_budget -= u.bytes
                    moved += 1
            seen_before.update(touched_since_opt)
            touched_since_opt.clear()
            return moved

        last_rep_time = 0.0
        traffic: List[StepTraffic] = []
        for rep in range(repeats):
            rep_time = 0.0
            since_opt = 0.0
            last_rep = rep == repeats - 1
            for s in range(steps):
                s2f0, f2s0, migs0 = res.bytes_s2f, res.bytes_f2s, \
                    res.migrations
                bytes_slow = 0.0
                for u in access_map.get(s, ()):
                    touched_since_opt[u.uid] = True
                    if not in_fast[u.uid]:
                        bytes_slow += u.bytes
                        res.slow_bytes_accessed += u.bytes
                t_fast = max(0.0, tl.total_bytes[s] - bytes_slow)
                t = max(tl.flops[s] / hw.peak_flops,
                        t_fast / hw.fast_bw + bytes_slow / hw.slow_bw)
                t += tl.extra_time(s, hw)
                rep_time += t
                since_opt += t
                if (s + 1) % opt_every == 0:
                    # daemon runs on dedicated helper threads (Yan et al. use
                    # 4 copy + 8 migration threads): off the critical path
                    optimization_pass(since_opt * hw.mig_bw)
                    since_opt = 0.0
                if last_rep:
                    # steady-state traffic only (matches the reported time)
                    traffic.append(StepTraffic(
                        flops=tl.flops[s], fast_read=t_fast,
                        slow_read=bytes_slow, demand_read=bytes_slow,
                        mig_in=res.bytes_s2f - s2f0,
                        mig_out=res.bytes_f2s - f2s0,
                        tokens=tl.tokens[s],
                        migs=res.migrations - migs0,
                        extra_flops=tl.extra_flops[s],
                        extra_fast=tl.extra_fast_bytes[s],
                        prefill_flops=tl.extra_flops[s],
                        prefill_read=_pread(tl, s)))
            last_rep_time = rep_time
        res.time = last_rep_time
        res.step_traffic = traffic
        return res


@register_policy("ial")
class IAL(_CachingDaemon):
    """Yan et al. ASPLOS'19 two-FIFO-list daemon."""


@register_policy("lru")
class LRUDaemon(_CachingDaemon):
    """Same daemon skeleton with recency ordering."""
    recency = True


# ==================================================================== static ==

class _Static(PlacementPolicy):
    where = "fast"
    plans_ahead = True       # fixed placement: every read is streamable
    supports_replan = True   # stateless: a delta just re-prices it

    @classmethod
    def simulate(cls, workload, hw: HWSpec, fast_bytes: float,
                 **_ignored) -> PlacementResult:
        tl = as_workload(workload).timeline()
        fast = cls.where == "fast"
        bw = hw.fast_bw if fast else hw.slow_bw
        t = sum(max(tl.flops[s] / hw.peak_flops, tl.total_bytes[s] / bw)
                + tl.extra_time(s, hw)
                for s in range(tl.num_steps))
        res = PlacementResult(cls.name, t, sum(_all_fast_times(tl, hw)),
                              tokens=sum(tl.tokens))
        res.step_traffic = [StepTraffic(
            flops=tl.flops[s],
            fast_read=tl.total_bytes[s] if fast else 0.0,
            slow_read=0.0 if fast else tl.total_bytes[s],
            tokens=tl.tokens[s], extra_flops=tl.extra_flops[s],
            extra_fast=tl.extra_fast_bytes[s],
            prefill_flops=tl.extra_flops[s], prefill_read=_pread(tl, s))
            for s in range(tl.num_steps)]
        return res


@register_policy("all_fast")
class AllFast(_Static):
    """Everything in the fast tier: the speed ceiling."""
    where = "fast"


@register_policy("all_slow")
class AllSlow(_Static):
    """Everything in the slow tier: the floor every policy must beat."""
    where = "slow"
