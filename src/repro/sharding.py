"""Logical-axis sharding: model code names axes, the launcher maps them to mesh axes.

Model code calls ``constrain(x, ("batch", "seq", "embed"))``; under an active
``AxisRules`` context this becomes ``lax.with_sharding_constraint`` with the
mapped ``PartitionSpec``; with no context it is a no-op (CPU unit tests).

Param shardings are derived from the same rules via ``param_spec`` using the
logical axes each initializer attaches (see models/layers.py ``LOGICAL_AXES``).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def is_axes_leaf(x) -> bool:
    """A logical-axes annotation: tuple of axis names / None."""
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


class AxisRules:
    """Maps logical axis names -> mesh axis name(s) or None (replicated)."""

    def __init__(self, mesh: Mesh, rules: Mapping[str, object]):
        self.mesh = mesh
        self.rules = dict(rules)

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical axes. With a concrete shape, entries
        whose mesh-axis product doesn't divide the dim are dropped *before*
        marking the mesh axis used — so e.g. a 16-way model axis skipped on a
        40-expert dim remains available for the per-expert hidden dim."""
        out = []
        used = set()
        for i, ax in enumerate(logical):
            m = self.rules.get(ax) if ax is not None else None
            if m is None:
                out.append(None)
                continue
            # a list rule holds fallback candidates (tried in order); a tuple
            # is a single joint-axes mapping
            candidates = m if isinstance(m, list) else [m]
            chosen = None
            for cand in candidates:
                key = tuple(cand) if isinstance(cand, tuple) else (cand,)
                if any(k in used for k in key):
                    continue
                if shape is not None:
                    size = 1
                    for a in key:
                        size *= self.mesh.shape[a]
                    if shape[i] % size != 0:
                        continue
                chosen = cand
                used.update(key)
                break
            out.append(chosen)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


def _divisible(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def sharding_for(shape: Tuple[int, ...], logical: Sequence[Optional[str]],
                 rules: AxisRules) -> NamedSharding:
    """NamedSharding for a concrete shape: logical axes mapped through the
    rules, dropping any entry whose mesh-axis product doesn't divide the dim
    (e.g. 40 experts on a 16-way model axis, kv_heads=5)."""
    return NamedSharding(rules.mesh, rules.spec(logical, shape))


def constrain(x: jax.Array, logical: Sequence[Optional[str]]):
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def param_spec(path: Tuple[str, ...], leaf_logical: Sequence[Optional[str]],
               shape: Tuple[int, ...], rules: AxisRules) -> NamedSharding:
    spec = _divisible(shape, rules.spec(leaf_logical), rules.mesh)
    return NamedSharding(rules.mesh, spec)


# Default logical->mesh rule sets ------------------------------------------------

def tp_dp_rules(mesh: Mesh, fsdp: bool = False, seq_parallel: bool = False,
                dp_only: bool = False) -> AxisRules:
    """Megatron TP over 'model', DP over ('pod','data') (pod axis optional).

    fsdp=True additionally shards the big param dim over the data axes
    (ZeRO-3 style; XLA inserts the all-gathers).
    seq_parallel=True shards the residual-stream sequence dim over 'model'
    (Megatron-SP): per-layer activation all-gathers become reduce-scatter/
    all-gather pairs on 1/16 the payload.
    dp_only=True folds the model axis into data parallelism (small models:
    no TP collectives at all, grads all-reduce only).
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    if dp_only:
        full = data_axes + (("model",) if "model" in mesh.shape else ())
        # fallback chain: widest DP product that divides the batch
        cands = [full[i:] for i in range(len(full))] + \
                [full[:j] for j in range(len(full) - 1, 0, -1)]
        r = {k: None for k in ("seq", "seq_res", "embed", "heads", "kv_heads",
                               "head_dim", "mlp", "vocab", "experts",
                               "expert_mlp", "kv_latent", "fsdp", "kv_seq",
                               "ssm_heads", "ssm_state", "layers", "capacity")}
        r["batch"] = cands
        return AxisRules(mesh, r)
    rules = {
        "batch": data,
        "seq": None,
        # residual-stream sequence dim (block boundaries + embeddings):
        # sharding it over 'model' is Megatron-SP — per-layer TP all-gathers
        # become reduce-scatter/all-gather pairs on 1/TP the payload
        "seq_res": "model" if seq_parallel else None,
        # FSDP: the d_model dim of *weights* shards over the data axes
        # (ZeRO-3); "embed" appears in activation constraints too, where the
        # dedup-vs-batch logic drops it (batch already uses the data axes)
        "embed": data if fsdp else None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": "model",   # used when the expert dim can't shard (EP
                                 # falls back to TP-within-expert)
        "kv_latent": "model",    # MLA compressed cache
        "fsdp": data if fsdp else None,
        # decode-time sequence parallelism (KV cache length); enabled by
        # serve rules below, replicated under training rules
        "kv_seq": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "layers": None,
        "capacity": None,
    }
    return AxisRules(mesh, rules)


def serve_rules(mesh: Mesh, seq_shard: bool = False) -> AxisRules:
    """Inference rules: optionally shard the KV cache over data axes (long ctx)."""
    r = tp_dp_rules(mesh)
    if seq_shard:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        r.rules["kv_seq"] = data_axes if len(data_axes) > 1 else data_axes[0]
        r.rules["batch"] = None
    return r
