"""Training loop: step factory + fault-tolerant runner.

``make_train_step`` builds the jitted (state, batch) -> (state, metrics) step
with Sentinel offload and sharding applied; ``run`` drives it with periodic
checkpoints, retry-on-failure (replaying the deterministic pipeline), and
straggler detection via step-time EWMA.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core.offload import SentinelConfig, loss_kwargs
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model
from repro.models.layers import split_params
from repro.optim import adamw


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_retries: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0   # step slower than factor*EWMA -> warn


def make_train_step(cfg, scfg: SentinelConfig, opt_cfg: adamw.OptConfig,
                    donate: bool = True) -> Callable:
    kw = loss_kwargs(scfg)

    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, **kw))(state["params"])
        with jax.named_scope("boundary_opt"):
            new_params, new_opt, om = adamw.update(
                grads, state["opt"], state["params"], opt_cfg)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def init_state(key, cfg, opt_cfg: adamw.OptConfig):
    params, axes = split_params(model.init_params(key, cfg))
    return {"params": params, "opt": adamw.init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}, axes


def run(cfg, tcfg: TrainConfig, scfg: SentinelConfig,
        opt_cfg: adamw.OptConfig, dcfg: DataConfig,
        state=None, step_fn=None, log: Callable = print) -> Dict[str, Any]:
    """Fault-tolerant loop. Any step that raises is retried after restoring
    the latest checkpoint (the deterministic pipeline replays identical
    batches, so recovery is bit-exact)."""
    if state is None:
        state, _ = init_state(jax.random.PRNGKey(dcfg.seed), cfg, opt_cfg)
    step_fn = step_fn or make_train_step(cfg, scfg, opt_cfg)

    start = ckpt.latest_step(tcfg.ckpt_dir)
    if start is not None:
        state = ckpt.restore(state, tcfg.ckpt_dir, start)
        log(f"[train] resumed from step {start}")

    ewma = None
    retries = 0
    history = []
    step = int(state["step"])
    while step < tcfg.steps:
        batch = make_batch(dcfg, step)
        t0 = time.perf_counter()
        try:
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
        except Exception as e:  # node failure / OOM: restore + retry
            retries += 1
            if retries > tcfg.max_retries:
                raise
            log(f"[train] step {step} failed ({type(e).__name__}); "
                f"retry {retries}/{tcfg.max_retries}")
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None:
                state = ckpt.restore(state, tcfg.ckpt_dir, last)
                step = int(state["step"])
            continue
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > tcfg.straggler_factor * ewma and step > 3:
            log(f"[train] straggler: step {step} took {dt:.3f}s "
                f"(ewma {ewma:.3f}s)")
        step = int(state["step"])
        history.append(float(metrics["loss"]))
        if step % tcfg.log_every == 0:
            log(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                f"({dt*1e3:.1f} ms)")
        if tcfg.ckpt_every and step % tcfg.ckpt_every == 0:
            ckpt.save(state, tcfg.ckpt_dir, step)
    return {"state": state, "losses": history}
