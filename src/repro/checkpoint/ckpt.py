"""Sharded checkpoints with elastic restore.

Format: one ``.npz`` per checkpoint step holding every leaf by its tree path
(full arrays — process-0 gathers; adequate for the single-process dry-run
container, and the API is mesh-shape-agnostic: ``restore`` reshards onto
whatever mesh/sharding the caller passes, so a job restarted on a different
topology (elastic scaling / failed-node replacement) resumes bit-exact).

Writes are atomic (tmp + rename); ``latest_step`` scans the directory, so a
crashed write never corrupts recovery.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "//"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _fmt(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(tree: Any, directory: str, step: int, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)              # atomic publish
    _gc(directory, keep)
    return path


def _gc(directory: str, keep: int):
    steps = all_steps(directory)
    for s in steps[:-keep] if keep else []:
        try:
            os.remove(os.path.join(directory, f"ckpt_{s:08d}.npz"))
        except OSError:
            pass


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(template: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `template`. If `shardings` (a matching
    tree of NamedSharding) is given, leaves are device_put with it — this is
    the elastic-resharding path: the stored full arrays redistribute onto the
    current mesh regardless of the topology they were saved from."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "memory_kind"))
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, tmpl), sh in zip(paths, shard_leaves):
        key = _SEP.join(_fmt(p) for p in path)
        arr = data[key]
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype)
                          if hasattr(tmpl, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
