"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

These are the semantics of record: each kernel in this package must match its
oracle to float tolerance across shape/dtype sweeps (tests/test_kernels_*).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 20


# ------------------------------------------------------- flash attention ----

def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap_val: float = 0.0, scale: float | None = None):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D) with H % KVH == 0.

    Plain softmax attention; the oracle for flash_attention.
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, KVH, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    qpos = jnp.arange(Sq)[:, None] + (k.shape[1] - Sq)  # right-aligned
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, window: int = 0,
                         softcap_val: float = 0.0):
    """Single-token decode. q: (B, H, D); caches: (B, S, KVH, D);
    lengths: (B,) int32 — #valid cache entries (query is at lengths-1)."""
    B, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (D ** -0.5)
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    kpos = jnp.arange(S)[None, :]
    ok = kpos < lengths[:, None]
    if window:
        ok &= kpos >= (lengths[:, None] - window)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, D)


def paged_decode_attention_ref(q, k_hot, v_hot, k_cold, v_cold, page_table,
                               page_tier, lengths, *, window: int = 0,
                               softcap_val: float = 0.0):
    """Oracle for kernels/paged_decode.py: the same flash-decode page loop in
    pure jnp, vectorized over (batch, kv_head).

    q: (B, H, D); pools (n, page, KVH, D); page_table/page_tier (B, NP);
    lengths (B,).  The loop visits every logical page and relies on exact
    float semantics for tier-agnostic correctness: a fully masked page scores
    NEG_INF everywhere, whose exp underflows to exactly 0.0 in float32, so
    out-of-range pages (and, under ``window``, the skipped cold prefix)
    contribute nothing bit-for-bit.  The op sequence mirrors the kernel
    (shared masked_scores / online_softmax_update helpers), which is what
    makes the kernel-vs-oracle tests in interpret mode exact rather than
    approximate.
    """
    from repro.kernels.decode_attention import (masked_scores,
                                                online_softmax_update)
    B, H, D = q.shape
    page, KVH = k_hot.shape[1], k_hot.shape[2]
    NP = page_table.shape[1]
    G = H // KVH
    qg = q.reshape(B, KVH, G, D).astype(jnp.float32)
    lengths = lengths.astype(jnp.int32)

    def gather(pool_hot, pool_cold, i):
        phys = page_table[:, i]
        t = page_tier[:, i]
        hot = pool_hot[jnp.clip(phys, 0, pool_hot.shape[0] - 1)]
        cold = pool_cold[jnp.clip(phys, 0, pool_cold.shape[0] - 1)]
        pg = jnp.where(t[:, None, None, None] == 0, hot, cold)  # (B,page,KVH,D)
        return pg.transpose(0, 2, 1, 3).astype(jnp.float32)     # (B,KVH,page,D)

    def body(i, carry):
        acc, m, l = carry
        k = gather(k_hot, k_cold, i)
        v = gather(v_hot, v_cold, i)
        s = masked_scores(qg, k, i * page, lengths, window=window,
                          softcap_val=softcap_val)
        return online_softmax_update(s, v, acc, m, l)

    acc, m, l = jax.lax.fori_loop(
        0, NP, body,
        (jnp.zeros((B, KVH, G, D), jnp.float32),
         jnp.full((B, KVH, G), NEG_INF, jnp.float32),
         jnp.zeros((B, KVH, G), jnp.float32)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype).reshape(B, H, D)


# ------------------------------------------------------------ mamba2 SSD ----

def ssd_ref(x, dt, A, Bm, Cm, *, h0=None):
    """Sequential oracle. x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm, Cm: (B,S,N). Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(dtt * A)                                  # (B,H)
        h = h * a[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32), dtt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


def ssd_chunked_ref(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None):
    """Chunked (SSD-algorithm) oracle — matmul-heavy formulation.

    Same I/O as ssd_ref; matches it to fp tolerance. This is the math the
    Pallas kernel implements per (batch, chunk) grid cell.
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # dt=0 padding is neutral: decay 1, zero state contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    K = S_p // Q
    xc = x.reshape(B_, K, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(B_, K, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B_, K, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B_, K, Q, N).astype(jnp.float32)

    logdec = dtc * A                                   # (B,K,Q,H), <= 0
    l = jnp.cumsum(logdec, axis=2)                     # inclusive
    total = l[:, :, -1, :]                             # (B,K,H)

    # intra-chunk: G[t,s] = (C_t . B_s) exp(l_t - l_s) dt_s, s <= t.
    # Mask the exponent BEFORE exp: for s > t the difference is positive and
    # can overflow, and a post-exp `where` still leaks inf into the VJP.
    CB = jnp.einsum("bktn,bksn->bkts", Cc, Bc)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    diff = l[:, :, :, None, :] - l[:, :, None, :, :]            # (B,K,t,s,H)
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    G = CB[..., None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bktsh,bkshp->bkthp", G, xc)

    # inter-chunk via scan carrying h (B,H,P,N)
    h = jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def body(h, inp):
        xk, dtk, bk, ck, lk, tot = inp
        y_inter = jnp.einsum("btn,bhpn->bthp", ck, h) * jnp.exp(lk)[..., None]
        w = jnp.exp(tot[:, None, :] - lk) * dtk        # (B,Q,H)
        h = h * jnp.exp(tot)[:, :, None, None] + \
            jnp.einsum("bthp,btn,bth->bhpn", xk, bk, w)
        return h, y_inter

    xs = tuple(a.swapaxes(0, 1) for a in (xc, dtc, Bc, Cc, l, total))
    h, y_inter = jax.lax.scan(body, h, xs)
    y = (y_intra + y_inter.swapaxes(0, 1)).reshape(B_, S_p, H, P)[:, :S]
    return y.astype(x.dtype), h


def ssd_decode_ref(h, xt, dtt, A, bt, ct):
    """One decode step. h: (B,H,P,N); xt: (B,H,P); dtt: (B,H); bt, ct: (B,N).
    Returns (y (B,H,P), h')."""
    a = jnp.exp(dtt.astype(jnp.float32) * A)
    h = h.astype(jnp.float32) * a[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32),
        dtt.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(jnp.float32))
    return y.astype(xt.dtype), h


# ----------------------------------------------------------------- mLSTM ----

def mlstm_ref(q, k, v, log_i, log_f, *, state=None):
    """Sequential stabilized mLSTM oracle (xLSTM eq. 19-27).

    q, k: (B,S,H,Dk); v: (B,S,H,Dv); log_i, log_f: (B,S,H) pre-activation gate
    logs (log_f = logsigmoid(f_pre), log_i = i_pre). Returns (h (B,S,H,Dv),
    (C, n, m) final state) with C: (B,H,Dk,Dv), n: (B,H,Dk), m: (B,H).
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    scale = Dk ** -0.5
    if state is None:
        C = jnp.zeros((B, H, Dk, Dv), jnp.float32)
        n = jnp.zeros((B, H, Dk), jnp.float32)
        m = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C, n, m = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        # first step: m == -inf makes f_p nan via inf-inf; define it as 0
        f_p = jnp.where(jnp.isfinite(m), f_p, 0.0)
        C = C * f_p[..., None, None] + i_p[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kt.astype(jnp.float32) * scale, vt.astype(jnp.float32))
        n = n * f_p[..., None] + i_p[..., None] * kt.astype(jnp.float32) * scale
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, log_i, log_f))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return hs.swapaxes(0, 1).astype(v.dtype), (C, n, m)


def mlstm_chunked_ref(q, k, v, log_i, log_f, *, chunk: int = 64, state=None):
    """Chunkwise-parallel stabilized mLSTM == mlstm_ref to fp tolerance.

    Per chunk (length L, cumulative forget F_t = Σ_{s<=t} lf_s, u_s = li_s -
    F_s, running max g_t = max_{s<=t} u_s, M_t = max(m_prev, g_t)):

        h_t  = exp(m_prev - M_t) (q_t C_prev) +
               Σ_{s<=t} exp(u_s - M_t) (q_t.k_s) v_s           (all matmuls)
        n_t  analogous;  den_t = max(|n_t.q_t|, exp(-(F_t + M_t)))
        C' = exp(m_prev - M_L) C_prev + Σ_s exp(u_s - M_L) k_s v_s^T
        m' = F_L + M_L

    This removes the per-timestep scan: saved state is per *chunk*, and the
    intra-chunk work is (L x L) masked matmuls — the memory/compute shape the
    Pallas kernel (and the xlstm train-cell §Perf fix) wants.
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    K = S // L
    scale = Dk ** -0.5
    if state is None:
        C = jnp.zeros((B, H, Dk, Dv), jnp.float32)
        n = jnp.zeros((B, H, Dk), jnp.float32)
        m = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C, n, m = state

    qc = q.reshape(B, K, L, H, Dk).astype(jnp.float32)
    kc = k.reshape(B, K, L, H, Dk).astype(jnp.float32) * scale
    vc = v.reshape(B, K, L, H, Dv).astype(jnp.float32)
    lic = log_i.reshape(B, K, L, H).astype(jnp.float32)
    lfc = log_f.reshape(B, K, L, H).astype(jnp.float32)

    F = jnp.cumsum(lfc, axis=2)                    # (B,K,L,H)
    u = lic - F
    g = jax.lax.cummax(u, axis=2)
    Ftot = F[:, :, -1]                             # (B,K,H)

    mask = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, inp):
        C, n, m = carry                            # (B,H,Dk,Dv),(B,H,Dk),(B,H)
        qk_, kk, vk, Fk, uk, gk, Ft = inp
        M = jnp.maximum(m[:, None, :], gk)         # (B,L,H)
        w_state = jnp.exp(m[:, None, :] - M)       # (B,L,H)
        w_state = jnp.where(jnp.isfinite(m)[:, None, :], w_state, 0.0)
        # intra weights: W[t,s] = exp(u_s - M_t) for s <= t (mask pre-exp)
        diff = uk[:, None, :, :] - M[:, :, None, :]          # (B,t,s,H)
        W = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("bthd,bshd->btsh", qk_, kk)      # (B,t,s,H)
        num = jnp.einsum("btsh,bshv->bthv", scores * W, vk) + \
            jnp.einsum("bthd,bhdv->bthv", qk_, C) * w_state[..., None]
        # normalizer: n_t = w_state * n_prev + Σ_{s<=t} exp(u_s - M_t) k_s
        nvec = jnp.einsum("btsh,bshd->bthd", W, kk) + \
            n[:, None] * w_state[..., None]
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", nvec, qk_))
        m_t = Fk + M                               # (B,L,H)
        den = jnp.maximum(den, jnp.exp(-m_t))
        h = num / den[..., None]

        # chunk-end state
        ML = jnp.maximum(m, gk[:, -1])             # (B,H)
        ws = jnp.exp(jnp.where(jnp.isfinite(m), m - ML, -jnp.inf))
        wk = jnp.exp(uk - ML[:, None, :])          # (B,L,H)
        C = C * ws[..., None, None] + jnp.einsum("bshd,bshv,bsh->bhdv",
                                                 kk, vk, wk)
        n = n * ws[..., None] + jnp.einsum("bshd,bsh->bhd", kk, wk)
        m = Ft + ML
        return (C, n, m), h

    xs = tuple(a.swapaxes(0, 1) for a in (qc, kc, vc, F, u, g, Ftot))
    (C, n, m), hs = jax.lax.scan(body, (C, n, m), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, Dv)
    return h.astype(v.dtype), (C, n, m)


def slstm_ref(x_ifzo, *, state=None, r_ifzo=None):
    """Sequential sLSTM with exponential input gate + normalizer/stabilizer.

    x_ifzo: (B, S, H, 4, D) pre-activations for i, f, z, o per head;
    r_ifzo: optional recurrent weights (H, 4, D, D) applied to h_{t-1}.
    Returns (h (B,S,H,D), final state (c, n, m, h)).
    """
    B, S, H, four, D = x_ifzo.shape
    if state is None:
        c = jnp.zeros((B, H, D), jnp.float32)
        n = jnp.zeros((B, H, D), jnp.float32)
        m = jnp.full((B, H, D), -jnp.inf, jnp.float32)
        h = jnp.zeros((B, H, D), jnp.float32)
    else:
        c, n, m, h = state

    def step(carry, xt):
        c, n, m, h = carry
        pre = xt.astype(jnp.float32)
        if r_ifzo is not None:
            pre = pre + jnp.einsum("bhd,hgde->bhge", h, r_ifzo.astype(jnp.float32))
        i_p, f_p, z_p, o_p = pre[:, :, 0], pre[:, :, 1], pre[:, :, 2], pre[:, :, 3]
        lf = jax.nn.log_sigmoid(f_p)
        m_new = jnp.maximum(lf + m, i_p)
        i_g = jnp.exp(i_p - m_new)
        f_g = jnp.where(jnp.isfinite(m), jnp.exp(lf + m - m_new), 0.0)
        c = f_g * c + i_g * jnp.tanh(z_p)
        n = f_g * n + i_g
        h = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h), h

    (c, n, m, h), hs = jax.lax.scan(step, (c, n, m, h), x_ifzo.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x_ifzo.dtype), (c, n, m, h)
