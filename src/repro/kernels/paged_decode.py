"""Paged flash-decode over tiered KV pools (Pallas TPU).

The serving-time replacement for the concat-based cold-KV read: instead of
materializing `concat(cold_prefix, hot_window)` before attention, KV lives in
fixed-size sequence *pages* split across two physical pools —

  k_hot / v_hot    (n_hot,  page, KVH, D)  device memory (HBM)
  k_cold / v_cold  (n_cold, page, KVH, D)  host memory (pinned_host on TPU)

with a per-slot page table mapping logical page i of slot b to a physical
page in one of the pools:

  page_table (B, NP) int32   physical index into the pool named by the tier
  page_tier  (B, NP) int32   0 = hot pool, 1 = cold pool

Each slot's *cold boundary* is simply the prefix of its tier row that is 1 —
per-slot, not global, which is what kills the page-grain false sharing the
paper argues against: a short slot's pages never ride along when a long
slot's history is demoted.

The pools the kernel reads are **persistent** in the serving engine
(models/kvcache.py::PagedKVPools): decode scatters each new token's KV into
its physical hot page through the same table before the kernel runs, admit /
demote / free mutate single pages, and the table arrays are re-uploaded only
when the PageTable's version changes.  The ``pool_layout`` / ``gather_pools``
/ ``pack_kv_pools`` helpers below build the pool layout *from a dense cache*
— the one-shot form used by model-level parity tests and ad-hoc callers, not
by the engine's steady-state loop (which never re-packs).

The kernel runs one (batch, kv_head) grid cell as a flash-decode loop over
that slot's logical pages.  Every page — hot or cold — is streamed into a
double-buffered VMEM window with `pltpu.make_async_copy`: while page i is in
the online-softmax update, the DMA for page i+1 is already in flight, so the
host->VMEM copy of cold pages overlaps with compute exactly like Sentinel's
migration threads overlap training compute.  With `window > 0` the loop
starts at the first page that intersects the attention window, skipping the
cold prefix entirely.

Oracle: repro.kernels.ref.paged_decode_attention_ref — the same page loop in
pure jnp, bit-exact against this kernel in interpret mode (same op sequence,
see kernels/decode_attention.masked_scores).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import (NEG_INF, masked_scores,
                                            online_softmax_update)


def _kernel(table_ref, tier_ref, len_ref, q_ref, k_hot, v_hot, k_cold, v_cold,
            o_ref, k_win, v_win, sem, *, page, G, D, window, softcap_val,
            n_hot, n_cold):
    h = pl.program_id(1)
    length = len_ref[0]
    npages = pl.cdiv(length, page)
    lo = jnp.maximum(0, (length - window) // page) if window else 0

    def start(i, slot):
        """Kick off the async copy of logical page i into window ``slot``."""
        phys = table_ref[0, i]

        @pl.when(tier_ref[0, i] == 0)
        def _():
            p = jnp.clip(phys, 0, n_hot - 1)
            pltpu.make_async_copy(k_hot.at[p, :, h], k_win.at[slot],
                                  sem.at[slot, 0]).start()
            pltpu.make_async_copy(v_hot.at[p, :, h], v_win.at[slot],
                                  sem.at[slot, 1]).start()

        @pl.when(tier_ref[0, i] != 0)
        def _():
            p = jnp.clip(phys, 0, n_cold - 1)
            pltpu.make_async_copy(k_cold.at[p, :, h], k_win.at[slot],
                                  sem.at[slot, 0]).start()
            pltpu.make_async_copy(v_cold.at[p, :, h], v_win.at[slot],
                                  sem.at[slot, 1]).start()

    def wait(slot):
        # the wait only needs dst shape/dtype for semaphore accounting, so a
        # fixed hot-pool source stands in for whichever pool the copy used
        pltpu.make_async_copy(k_hot.at[0, :, h], k_win.at[slot],
                              sem.at[slot, 0]).wait()
        pltpu.make_async_copy(v_hot.at[0, :, h], v_win.at[slot],
                              sem.at[slot, 1]).wait()

    q = q_ref[0, 0].astype(jnp.float32)                        # (G, D)

    @pl.when(lo < npages)
    def _warmup():
        start(lo, jax.lax.rem(lo, 2))

    def body(i, carry):
        acc, m, l = carry
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < npages)
        def _():  # next page's DMA overlaps this page's softmax update
            start(i + 1, jax.lax.rem(i + 1, 2))

        wait(slot)
        s = masked_scores(q, k_win[slot].astype(jnp.float32), i * page,
                          length, window=window, softcap_val=softcap_val)
        return online_softmax_update(s, v_win[slot].astype(jnp.float32),
                                     acc, m, l)

    acc, m, l = jax.lax.fori_loop(
        lo, npages, body,
        (jnp.zeros((G, D), jnp.float32), jnp.full((G,), NEG_INF, jnp.float32),
         jnp.zeros((G,), jnp.float32)))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_hot, v_hot, k_cold, v_cold, page_table,
                           page_tier, lengths, *, window: int = 0,
                           softcap_val: float = 0.0, interpret: bool = False):
    """q: (B, H, D); pools (n, page, KVH, D); page_table/page_tier (B, NP);
    lengths: (B,) valid tokens per slot (>= 1). Returns (B, H, D)."""
    B, H, D = q.shape
    page, KVH = k_hot.shape[1], k_hot.shape[2]
    NP = page_table.shape[1]
    G = H // KVH
    n_hot, n_cold = k_hot.shape[0], k_cold.shape[0]

    qg = q.reshape(B, KVH, G, D)
    kernel = functools.partial(_kernel, page=page, G=G, D=D, window=window,
                               softcap_val=softcap_val, n_hot=n_hot,
                               n_cold=n_cold)
    out = pl.pallas_call(
        kernel,
        grid=(B, KVH),
        in_specs=[
            pl.BlockSpec((1, NP), lambda b, h: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, NP), lambda b, h: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, page, D), k_hot.dtype),    # double-buffered K window
            pltpu.VMEM((2, page, D), v_hot.dtype),    # double-buffered V window
            pltpu.SemaphoreType.DMA((2, 2)),          # (buffer, k/v)
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), page_tier.astype(jnp.int32),
      lengths.astype(jnp.int32), qg, k_hot, v_hot, k_cold, v_cold)
    return out.reshape(B, H, D)


def pool_layout(cold_tokens, num_pages: int, page_tokens: int):
    """Layer-independent pool layout from per-slot cold boundaries.

    ``cold_tokens`` (len B, concrete ints): per-slot cold boundary in tokens;
    pages fully below the boundary go to the cold pool.  Physical page order
    deliberately interleaves slots (slot-major over logical pages) so tests
    exercise real indirection rather than an identity table.  Returns
    (page_table, page_tier, hot_idx, cold_idx) where the idx tuples list the
    (slot, logical_page) each physical pool page holds, in pool order —
    compute once per decode step, then gather every layer's pools from it.
    """
    B = len(cold_tokens)
    cold_pages = [int(c) // page_tokens for c in cold_tokens]
    hot_idx, cold_idx = [], []            # (b, i) per physical page, in order
    table = [[0] * num_pages for _ in range(B)]
    tier = [[0] * num_pages for _ in range(B)]
    for i in range(num_pages):            # slot-major interleave
        for b in range(B):
            if i < cold_pages[b]:
                table[b][i], tier[b][i] = len(cold_idx), 1
                cold_idx.append((b, i))
            else:
                table[b][i], tier[b][i] = len(hot_idx), 0
                hot_idx.append((b, i))
    return (jnp.asarray(table, jnp.int32), jnp.asarray(tier, jnp.int32),
            tuple(hot_idx), tuple(cold_idx))


def gather_pools(k_cache, v_cache, layout, page_tokens: int):
    """One layer's (k_hot, v_hot, k_cold, v_cold) pools for a shared layout.
    k_cache/v_cache: dense (B, S, KVH, D)."""
    B, S, KVH, D = k_cache.shape
    assert S % page_tokens == 0, (S, page_tokens)
    NP = S // page_tokens
    _, _, hot_idx, cold_idx = layout
    kp = k_cache.reshape(B, NP, page_tokens, KVH, D)
    vp = v_cache.reshape(B, NP, page_tokens, KVH, D)

    def gather(pages, idx):
        if not idx:
            return jnp.zeros((1, page_tokens, KVH, D), pages.dtype)
        bs = jnp.asarray([b for b, _ in idx])
        ps = jnp.asarray([i for _, i in idx])
        return pages[bs, ps]

    return (gather(kp, hot_idx), gather(vp, hot_idx),
            gather(kp, cold_idx), gather(vp, cold_idx))


def pack_kv_pools(k_cache, v_cache, cold_tokens, page_tokens: int):
    """Pack dense caches (B, S, KVH, D) into the paged pool layout.  Returns
    (k_hot, v_hot, k_cold, v_cold, page_table, page_tier); convenience over
    pool_layout + gather_pools for single-layer callers and tests."""
    layout = pool_layout(cold_tokens, k_cache.shape[1] // page_tokens,
                         page_tokens)
    return (*gather_pools(k_cache, v_cache, layout, page_tokens),
            layout[0], layout[1])
