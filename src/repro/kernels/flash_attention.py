"""Flash attention for TPU (Pallas): blocked online-softmax with VMEM tiling.

Supports causal, sliding-window, logit-softcap and GQA (KV heads indexed via
the BlockSpec index map — repeated KV heads are never materialized in HBM or
VMEM). Layout: q (B, H, Sq, D); k, v (B, KVH, Skv, D).

Grid is (batch, head, q_block, kv_block) with the kv dimension innermost and
sequential; the running (acc, m, l) online-softmax state lives in VMEM
scratch, so each q block's output tile is revisited across kv blocks — the
standard TPU flash schedule. Block shapes default to 128 (MXU-aligned).

Oracle: repro.kernels.ref.attention_ref (tests sweep shapes/dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 20


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *, scale, causal,
            window, softcap_val, bq, bk, skv, sq):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)

    # positions: queries right-aligned against the kv timeline
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = kpos < skv                                 # padded kv tail
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_s[...] * alpha + jnp.sum(p, axis=1)
    acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new
    l_s[...] = l_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap_val: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Skv, KVH, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = D ** -0.5

    qt = q.transpose(0, 2, 1, 3)                    # (B,H,Sq,D)
    kt = k.transpose(0, 2, 1, 3)                    # (B,KVH,Skv,D)
    vt = v.transpose(0, 2, 1, 3)

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qt.shape[2] // bq
    nk = kt.shape[2] // bk

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, softcap_val=softcap_val,
                               bq=bq, bk=bk, skv=Skv, sq=Sq)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    if pq:
        out = out[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)
