"""Kernel dispatch: jit'd public ops that pick the Pallas TPU kernel or the
pure-jnp oracle (CPU / debugging) per backend and flag.

``use_pallas(True)`` or env REPRO_USE_PALLAS=1 forces the Pallas path (with
interpret=True automatically on CPU so tests exercise the kernel body).
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import ref as _ref

_FORCE = {"pallas": os.environ.get("REPRO_USE_PALLAS", "") == "1"}


def use_pallas(on: bool = True):
    _FORCE["pallas"] = on


def _pallas_enabled() -> bool:
    return _FORCE["pallas"] or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap_val=0.0):
    if _pallas_enabled():
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap_val=softcap_val, interpret=_interpret())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap_val=softcap_val)


def decode_attention(q, k_cache, v_cache, lengths, *, window=0, softcap_val=0.0):
    if _pallas_enabled():
        from repro.kernels import decode_attention as da
        return da.decode_attention(q, k_cache, v_cache, lengths, window=window,
                                   softcap_val=softcap_val, interpret=_interpret())
    return _ref.decode_attention_ref(q, k_cache, v_cache, lengths, window=window,
                                     softcap_val=softcap_val)


def paged_decode_attention(q, k_hot, v_hot, k_cold, v_cold, page_table,
                           page_tier, lengths, *, window=0, softcap_val=0.0):
    """Flash-decode over paged, tiered KV pools (hot=device, cold=host).
    See kernels/paged_decode.py for the pool/page-table layout.  The pools
    may be larger than the table addresses (the engine's persistent pools
    carry free pages and a trailing garbage page); the kernel only visits
    pages the table maps for each slot's length."""
    if _pallas_enabled():
        from repro.kernels import paged_decode as pd
        return pd.paged_decode_attention(
            q, k_hot, v_hot, k_cold, v_cold, page_table, page_tier, lengths,
            window=window, softcap_val=softcap_val, interpret=_interpret())
    return _ref.paged_decode_attention_ref(
        q, k_hot, v_hot, k_cold, v_cold, page_table, page_tier, lengths,
        window=window, softcap_val=softcap_val)


def ssd(x, dt, A, Bm, Cm, *, chunk=256, h0=None):
    if _pallas_enabled():
        from repro.kernels import mamba2 as m2
        return m2.ssd(x, dt, A, Bm, Cm, chunk=chunk, h0=h0, interpret=_interpret())
    return _ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)


ssd_decode = _ref.ssd_decode_ref     # single-step: pure jnp is already optimal
slstm = _ref.slstm_ref

# mLSTM execution mode: 0 = sequential scan (baseline), N = chunkwise-parallel
# with chunk length N (the xlstm §Perf lever; REPRO_MLSTM_CHUNK or set below)
_MLSTM = {"chunk": int(os.environ.get("REPRO_MLSTM_CHUNK", "0"))}


def mlstm_chunk_mode(chunk: int):
    _MLSTM["chunk"] = chunk


def mlstm(q, k, v, log_i, log_f, *, state=None):
    c = _MLSTM["chunk"]
    if c and q.shape[1] > 1 and q.shape[1] % c == 0:
        return _ref.mlstm_chunked_ref(q, k, v, log_i, log_f, chunk=c,
                                      state=state)
    return _ref.mlstm_ref(q, k, v, log_i, log_f, state=state)
