"""Flash-decode for TPU (Pallas): one query token against a KV cache.

Layout: q (B, H, D); k_cache, v_cache (B, S, KVH, D); lengths (B,). The grid
is (batch, kv_head, kv_block) — all G=H/KVH query heads of a KV head are
processed together as a (G, D) tile so the MXU sees a matmul, not a matvec.
Online-softmax state in VMEM scratch, kv blocks sequential.

Oracle: repro.kernels.ref.decode_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 20


def masked_scores(q, k, base, length, *, window: int, softcap_val: float):
    """QK^T scores for one KV block, scaled/softcapped/length-masked.

    q: (..., G, D); k: (..., bk, D); base: first key position of the block.
    Shared by the contiguous flash-decode kernel, the paged kernel
    (kernels/paged_decode.py) and their jnp oracles — keeping the op sequence
    identical is what makes kernel-vs-oracle comparisons bit-exact in
    interpret mode.
    """
    nd = q.ndim
    s = jax.lax.dot_general(
        q, k, (((nd - 1,), (nd - 1,)), (tuple(range(nd - 2)),) * 2),
        preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    kpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, s.ndim - 1)
    length = jnp.asarray(length)
    length = length.reshape(length.shape + (1,) * (s.ndim - length.ndim))
    ok = kpos < length
    if window:
        ok &= kpos >= (length - window)
    return jnp.where(ok, s, NEG_INF)


def online_softmax_update(s, v, acc, m, l):
    """One online-softmax block update. s: (..., G, bk); v: (..., bk, D);
    state acc: (..., G, D), m/l: (..., G). Returns (acc, m, l)."""
    nd = s.ndim
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jax.lax.dot_general(
        p, v, (((nd - 1,), (nd - 2,)), (tuple(range(nd - 2)),) * 2),
        preferred_element_type=jnp.float32)
    return acc_new, m_new, l_new


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
            window, softcap_val, bk, s_total):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, D)
    s = masked_scores(q, k, ki * bk, len_ref[0], window=window,
                      softcap_val=softcap_val)
    acc[...], m_s[...], l_s[...] = online_softmax_update(
        s, v_ref[0, :, 0].astype(jnp.float32), acc[...], m_s[...], l_s[...])

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     softcap_val: float = 0.0, block_k: int = 256,
                     interpret: bool = False):
    """q: (B, H, D); caches (B, S, KVH, D); lengths (B,) -> (B, H, D)."""
    B, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH

    bk = min(block_k, S)
    pk = (-S) % bk
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nk = k_cache.shape[1] // bk

    qg = q.reshape(B, KVH, G, D)
    kernel = functools.partial(_kernel, window=window, softcap_val=softcap_val,
                               bk=bk, s_total=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, D)
