"""Mamba2 SSD chunk scan for TPU (Pallas).

The chunked SSD algorithm (intra-chunk quadratic attention-like term + inter-
chunk recurrence) with the per-(batch, head-block) state carried in VMEM
scratch across the sequential chunk grid dimension — HBM traffic is one read
of x/dt/B/C and one write of y; the (H, P, N) state never leaves VMEM.

Layouts: x (B, H, S, P); dt (B, H, S); A (H,); Bm, Cm (B, S, N).
Grid: (batch, head_block, chunk) with chunk innermost/sequential.

Oracle: repro.kernels.ref.ssd_chunked_ref (== ssd_ref sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_s, *, bh, q):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    x = x_ref[0].astype(jnp.float32)                 # (bh, q, P)
    dt = dt_ref[0].astype(jnp.float32)               # (bh, q)
    A = a_ref[...].astype(jnp.float32)               # (bh,)
    Bm = b_ref[0].astype(jnp.float32)                # (q, N)
    Cm = c_ref[0].astype(jnp.float32)                # (q, N)

    logdec = dt * A[:, None]                         # (bh, q)
    l = jnp.cumsum(logdec, axis=1)                   # inclusive
    total = l[:, -1]                                 # (bh,)

    # intra-chunk: G[h,t,s] = (C_t . B_s) exp(l_t - l_s) dt_s  for s <= t
    # (exponent masked before exp: s > t entries overflow otherwise)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (q,q)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = (spos <= tpos)[None]
    decay = jnp.exp(jnp.where(mask, l[:, :, None] - l[:, None, :], -jnp.inf))
    G = CB[None] * decay * dt[:, None, :]                          # (bh,t,s)
    y = jax.lax.dot_general(G, x, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)    # (bh,t,P)

    # inter-chunk: y += exp(l_t) * C_t @ h^T   (h: (bh, P, N))
    h = h_s[...]
    ch = jax.lax.dot_general(
        jnp.broadcast_to(Cm[None], (x.shape[0], q, Cm.shape[-1])), h,
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    y = y + ch * jnp.exp(l)[..., None]

    # state update: h' = exp(total) h + sum_s exp(total - l_s) dt_s x_s B_s^T
    w = jnp.exp(total[:, None] - l) * dt             # (bh, q)
    xw = x * w[..., None]                            # (bh, q, P)
    hb = jax.lax.dot_general(
        xw, jnp.broadcast_to(Bm[None], (x.shape[0], q, Bm.shape[-1])),
        (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    h_s[...] = h * jnp.exp(total)[:, None, None] + hb

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0] = h_s[...]


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256, h0=None, block_heads: int = 8,
        interpret: bool = False):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bm, Cm: (B, S, N).
    Returns (y (B, S, H, P), h_final (B, H, P, N)). h0 must be None (training
    from zero state; pass-through to the jnp reference otherwise)."""
    if h0 is not None:
        from repro.kernels.ref import ssd_chunked_ref
        return ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    q = min(chunk, S)
    assert S % q == 0, f"seq {S} % chunk {q} != 0"
    nc = S // q
    bh = min(block_heads, H)
    assert H % bh == 0
    nh = H // bh

    xt = x.transpose(0, 2, 1, 3)                     # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)                      # (B,H,S)

    kernel = functools.partial(_kernel, bh=bh, q=q)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, bh, q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, bh, q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((bh,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bh, q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, bh, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xt.shape, x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bm, Cm)
    return y.transpose(0, 2, 1, 3), h
