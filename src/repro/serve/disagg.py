"""Prefill/decode disaggregation over a device mesh.

Prefill and decode contend for different resources: prefill is a
compute-bound burst over the whole prompt, decode a bandwidth-bound
steady-state loop.  Colocated on one device, every admission serializes a
prompt's worth of compute into the decode stream (the cost model's
``extra_*`` channels).  Disaggregated, prefill runs on its own device group
and the finished KV pages stream over the device↔device edge into the
decode pools — the admission cost becomes a *pipe* (the link), overlapped
behind decode, instead of serialized compute.

``DisaggregatedEngine`` subclasses ``ContinuousBatcher`` on the pools
layout.  ``devices`` is split by ``launch.mesh.disagg_groups``; prefill
runs on the prefill group and the finished ``(last, fresh)`` KV streams to
the decode side, where every decode device is a *shard*:

  * each decode device owns its own ``PagedKVPools`` + ``PageTable``, all
    of them under one ``MeshPageTable`` global slot namespace (names
    ``("prefill", "dev0", ..., "devN-1")``; the single-decode-device case
    keeps the original ``("prefill", "decode")`` pair and the original
    code paths, bit for bit);
  * the plan's ``slot_devices`` (``runtime.plan_serving(...,
    decode_devices=N)``) assigns each batch slot to its owning shard —
    prefix sharing is intra-shard only, and each step runs one sub-batch
    forward per shard against that shard's pools on that shard's device;
  * ``_alloc_admit_pages`` stages the admitted pages on the prefill
    device's ``PageTable`` and moves them into the owning shard's slot as
    a ``MeshPageTable.migrate_slot`` tier transition, so every page
    crossing an edge is a first-class, byte-conserving migration — the
    per-edge ledger matches ``predict_pool_counters()
    ["edge_migration_bytes"]`` integer-exactly, shared-prefix admits
    included (shared pages stay put on the decode side; only the private
    tail crosses);
  * ``apply_plan`` adopting a re-plan whose ``slot_devices`` moves an
    active slot re-homes it as the same first-class ``migrate_slot``
    transition (hot pages over the shard↔shard edge, cold pages host-
    internal), charged against the returned churn.

With ``tp_prefill=True`` and >1 prefill device, the prefill group runs the
prompt tensor-parallel under ``sharding.serve_rules``.  Measured on the
forced-multi-device CPU backend this is numerically equivalent but *not*
bit-exact to single-device prefill (~1e-6 relative drift from the
row-parallel psum reduction order), so it is opt-in; the default keeps
prefill on one device of the group and the engine's tokens bit-identical
to the colocated all-HBM engine.

Everything else (steady-state zero-re-pack decode, boundary demotions,
prefix sharing, plan adoption) is inherited unchanged.

``price_disagg`` is the planner-side model of the same trade: it prices a
workload colocated (prefill serialized, all the HBM) against disaggregated
(prefill stripped from the decode stream, KV streaming priced as a
``TierGraph`` edge pipe, decode on its own share of the HBM, optionally
split across N shards) — the ``bench_serve --disagg`` throughput gate.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.launch.mesh import disagg_groups
from repro.models import kvcache, model
from repro.runtime.plan import validate_slot_devices
from repro.runtime.policies import simulate
from repro.runtime.tiergraph import TierGraph
from repro.serve.engine import ContinuousBatcher


class DisaggregatedEngine(ContinuousBatcher):
    """A ``ContinuousBatcher`` whose prefill runs on a separate device group
    and whose decode batch is sharded across the decode group.

    ``devices`` (or a ``jax.sharding.Mesh``) is split by
    ``launch.mesh.disagg_groups``; with one device both groups alias it and
    the engine degrades gracefully (same program, same logits, the mesh
    page-table ledger still counts the logical edge traffic).  With N > 1
    decode devices each batch slot lives on exactly one shard — taken from
    the plan's ``slot_devices`` (round-robin when the plan carries none) —
    and decode runs one sub-batch forward per shard.  Requires the
    persistent-pools layout (``cfg.use_paged_decode``), which is what makes
    steady-state decode re-pack-free — the streamed pages land directly in
    the decode pools.
    """

    def __init__(self, params, cfg, batch_slots: int, max_seq: int,
                 scfg=None, plan=None, slot_tenants=None, devices=None,
                 tp_prefill: bool = False):
        if plan is None:
            raise ValueError("DisaggregatedEngine requires a plan (the "
                             "pools layout is planned)")
        prefill_devs, decode_devs = disagg_groups(devices)
        self.prefill_devices = list(prefill_devs)
        self.prefill_device = prefill_devs[0]
        self.decode_devices = list(decode_devs)
        self.decode_device = decode_devs[0]
        self.n_shards = len(self.decode_devices)
        params = jax.device_put(params, self.decode_device)
        super().__init__(params, cfg, batch_slots, max_seq, scfg=scfg,
                         plan=plan, paged=True, slot_tenants=slot_tenants)
        # prompts prefill whole on the prefill group and stream across the
        # device edge — pool-direct suffix chunks would write the decode
        # pools from the wrong device, so the legacy dense path stays on
        self._pool_prefill_ok = False
        if self.prefill_chunk_tokens:
            raise ValueError(
                "chunked prefill is colocated-engine only: the "
                "DisaggregatedEngine prefills whole prompts on the prefill "
                "group (set prefill_chunk_tokens=0)")
        if self.pool is None:
            raise ValueError(
                "DisaggregatedEngine needs the persistent pools layout: "
                "set cfg.use_paged_decode (and not cfg.prefix_lm)")
        pg = self.page_tokens
        self.device_hot_peak: dict = {}    # shard name -> peak hot pool bytes
        self._dev_note_version = None
        if self.n_shards == 1:
            # a plan placed for N shards cannot silently colocate
            sd = getattr(self.plan, "slot_devices", None)
            if sd is not None:
                validate_slot_devices(sd, batch_slots, 1)
            self.slot_devices = None
            self.pools = [self.pool]
            self.mesh_table = kvcache.MeshPageTable(
                [kvcache.PageTable(1, max_seq // pg, pg), self.ptable],
                names=("prefill", "decode"),
                page_bytes=pg * self._row_bytes)
        else:
            kinds = tuple(cfg.prologue) + tuple(cfg.period)
            if not all(k in kvcache.ATTN_KINDS for k in kinds) \
                    or cfg.num_prefix_tokens or cfg.num_codebooks:
                raise ValueError(
                    "multi-shard decode needs a pure-attention stack: every "
                    "layer's KV must live in the physical page pools (the "
                    "per-shard sub-batch forwards have no dense per-slot "
                    "caches to split)")
            sd = getattr(self.plan, "slot_devices", None)
            if sd is None:
                sd = [s % self.n_shards for s in range(batch_slots)]
            self.slot_devices = validate_slot_devices(sd, batch_slots,
                                                      self.n_shards)
            dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
            # one B-slot pool per shard, each pinned to its device (cold
            # pages ride the owning shard's host path): a slot's pages live
            # only in its owning shard's pool, and the global slot index
            # doubles as the local one — re-homing lands in an empty
            # same-index row
            self.pools = [self.pool] + [
                kvcache.PagedKVPools(cfg, batch_slots, max_seq, pg, dt,
                                     device=d)
                for d in self.decode_devices[1:]]
            self.pool.device = self.decode_device
            for entry in self.pool._attn_entries():
                _, e = entry
                e["k_cold"] = kvcache.to_host(e["k_cold"], self.decode_device)
                e["v_cold"] = kvcache.to_host(e["v_cold"], self.decode_device)
            self.params_shards = [self.params] + [
                jax.device_put(params, d) for d in self.decode_devices[1:]]
            self.mesh_table = kvcache.MeshPageTable(
                [kvcache.PageTable(1, max_seq // pg, pg)]
                + [p.table for p in self.pools],
                names=("prefill",) + tuple(
                    f"dev{d}" for d in range(self.n_shards)),
                page_bytes=pg * self._row_bytes)
        # one staging slot on the prefill side: a request's pages are born
        # there and migrate to their decode slot as one tier transition
        self._stage = self.mesh_table.gslot(0, 0)
        base_prefill = self._prefill           # the jitted model.prefill
        self.tp_prefill = bool(tp_prefill) and len(self.prefill_devices) > 1
        if self.tp_prefill:
            import numpy as np

            from repro import sharding as shd
            from repro.launch import specs
            pmesh = jax.sharding.Mesh(np.asarray(self.prefill_devices),
                                      ("model",))
            rules = shd.serve_rules(pmesh)
            p_sds, axes = specs.param_structs(cfg)
            self.params_prefill = jax.device_put(
                params, specs.shardings_from_axes(axes, rules, p_sds))
            self._prefill_mesh, self._prefill_rules = pmesh, rules
        else:
            self.params_prefill = jax.device_put(params, self.prefill_device)

        def prefill_remote(p, batch):
            del p                              # decode-side params unused
            if self.tp_prefill:
                with self._prefill_mesh, shd.axis_rules(self._prefill_rules):
                    last, fresh = base_prefill(self.params_prefill, batch)
            else:
                batch = jax.device_put(batch, self.prefill_device)
                last, fresh = base_prefill(self.params_prefill, batch)
            if self.n_shards == 1:
                # stream the finished KV over the device<->device edge
                return (jax.device_put(last, self.decode_device),
                        jax.device_put(fresh, self.decode_device))
            # multi-shard: the KV streams to the *owning* shard inside
            # _admit_pool; the last-row logits come back uncommitted so
            # last_tok never pins the shared decode state to one shard
            return jnp.asarray(jax.device_get(last)), fresh

        self._prefill = prefill_remote

    def _shard_of(self, slot: int) -> int:
        return self.slot_devices[slot] if self.n_shards > 1 else 0

    def _dev_label(self, d: int) -> str:
        return "decode" if self.n_shards == 1 else f"dev{d}"

    def _gslot(self, d: int, slot: int) -> int:
        return self.mesh_table.gslot(1 + d, slot)

    # ------------------------------------------------------------- admits --
    def _alloc_admit_pages(self, slot: int, n: int) -> None:
        d = self._shard_of(slot)
        need = n - self.pools[d].table.n_pages[slot]
        if need <= 0:
            return
        stage_table = self.mesh_table.tables[0]
        for _ in range(need):
            stage_table.alloc(0, 0)            # prefill writes land here
        self.mesh_table.migrate_slot(self._stage, self._gslot(d, slot))

    def _admit_pool(self, slot: int, tok_host, fresh, S: int, prefix_key):
        if self.n_shards == 1:
            return super()._admit_pool(slot, tok_host, fresh, S, prefix_key)
        pg = self.page_tokens
        d = self._shard_of(slot)
        pool = self.pools[d]
        table = pool.table
        # stale donor registrations for this slot die with its pages
        for key in [k for k, (s, _) in self._prefix_donor.items()
                    if s == slot]:
            del self._prefix_donor[key]
        pool.free_slot(slot)
        shared_pages = 0
        if prefix_key is not None:
            donor = self._prefix_donor.get(prefix_key)
            # intra-shard only: physical pages cannot alias across HBMs
            if donor is not None and donor[0] != slot and \
                    self._shard_of(donor[0]) == d and \
                    table.n_pages[donor[0]] > 0:
                lcp = 0
                for a, b in zip(tok_host, donor[1]):
                    if a != b:
                        break
                    lcp += 1
                shared_pages = min(lcp // pg, table.n_pages[donor[0]])
                if shared_pages:
                    pool.share(slot, donor[0], shared_pages)
            self._prefix_donor[prefix_key] = (slot, tok_host)
        n = -(-S // pg)
        self._alloc_admit_pages(slot, n)
        # the private tail's KV crosses the prefill->shard edge here
        fresh = jax.device_put(fresh, self.decode_devices[d])
        pool.admit_rows(fresh, slot, range(shared_pages, n))
        pool.splice_other(fresh, slot)
        target = self._slot_cold_target(slot, S)
        while table.cold_tokens(slot) < target:
            if pool.demote_boundary(slot):
                self.sim_migration_bytes += pg * self._row_bytes

    # -------------------------------------------------------------- decode --
    def _pool_decode_step(self):
        if self.n_shards == 1:
            return super()._pool_decode_step()
        pg = self.page_tokens
        outs = []
        for d in range(self.n_shards):
            pool = self.pools[d]
            idxs = [s for s in range(self.B) if self.slot_devices[s] == d]
            act = [self.active[s] for s in idxs]
            if not any(act):
                continue
            for s in idxs:
                if self.active[s]:
                    pool.ensure_write_page(s, self._host_len[s])
            table_arr, tier_arr = pool.arrays()
            idx = jnp.asarray(idxs, jnp.int32)
            view = {"page_table": table_arr[idx], "page_tier": tier_arr[idx],
                    "page_tokens": pg, "active": jnp.asarray(act, bool),
                    "garbage_page": pool.garbage}
            logits, new_tree, _ = model.forward(
                self.params_shards[d], self.cfg,
                {"tokens": self.last_tok[idx][:, None]},
                caches=pool.tree, cache_index=self.lengths[idx],
                decode=True, paged_view=view)
            pool.tree = new_tree
            outs.append((idxs, logits))
        for s in range(self.B):
            if not self.active[s]:
                continue
            pool = self.pools[self.slot_devices[s]]
            target = self._slot_cold_target(s, self._host_len[s] + 1)
            while pool.table.cold_tokens(s) < target:
                if pool.demote_boundary(s):
                    self.sim_migration_bytes += pg * self._row_bytes
        self._note_tenant_pages()
        tok = jax.device_get(self.last_tok).copy()
        for idxs, logits in outs:
            td = jax.device_get(jnp.argmax(
                logits[:, -1, :self.cfg.vocab_size], axis=-1))
            for i, s in enumerate(idxs):
                tok[s] = int(td[i])
        return jnp.asarray(tok, jnp.int32)

    # ----------------------------------------------------------- re-plans --
    def apply_plan(self, new_plan):
        """Adopt a re-plan on the sharded pools: demote active slots toward
        the new hot windows on their current owner, then re-home every
        active slot whose ``slot_devices`` entry moved as a first-class
        ``MeshPageTable.migrate_slot`` transition (hot pages over the
        shard↔shard edge, cold pages host-internal; a finished slot's stale
        pages are dropped, not copied).  Returns boundary bytes plus the
        re-homing bytes — the churn the online replanner weighs."""
        if self.n_shards == 1:
            return super().apply_plan(new_plan)
        if hasattr(new_plan, "changes"):       # a PlanDelta, not a plan
            new_plan = self.plan.apply_delta(new_plan)
        page = max(1, new_plan.page_tokens)
        if self.max_seq % page:
            page = next(p for p in range(page, 0, -1)
                        if self.max_seq % p == 0)
        if page != self.page_tokens:
            raise ValueError(
                f"re-plan changes page geometry ({page} != "
                f"{self.page_tokens} tokens/page) — pools cannot be "
                "re-paged in place")
        tenants = getattr(new_plan, "slot_tenants", None)
        if tenants and len(tenants) != self.B:
            raise ValueError(
                f"slot_tenants has {len(tenants)} entries for {self.B} "
                f"batch slots (plan/batch geometry mismatch)")
        self.plan = new_plan
        if tenants:
            self.slot_tenants = list(tenants)
        mig0 = self.sim_migration_bytes
        for s in range(self.B):
            if not self.active[s]:
                continue                       # freed on its next admit
            pool = self.pools[self.slot_devices[s]]
            target = self._slot_cold_target(s, self._host_len[s])
            while pool.table.cold_tokens(s) < target:
                if pool.demote_boundary(s):
                    self.sim_migration_bytes += \
                        self.page_tokens * self._row_bytes
        rehome = 0.0
        new_sd = getattr(new_plan, "slot_devices", None)
        if new_sd is not None:
            new_sd = validate_slot_devices(new_sd, self.B, self.n_shards)
            for s in range(self.B):
                old, new = self.slot_devices[s], new_sd[s]
                if old == new:
                    continue
                if self.active[s]:
                    rehome += self._rehome_slot(s, old, new)
                elif self.pools[old].table.n_pages[s]:
                    # a finished slot's stale pages are dropped on
                    # ownership change, not copied across the edge
                    self.pools[old].free_slot(s)
            self.slot_devices = new_sd
        # tenancy/ownership may have moved without a table event
        self._tenant_note_version = -1
        self._note_tenant_pages()
        return (self.sim_migration_bytes - mig0) + rehome

    def _rehome_slot(self, slot: int, old: int, new: int) -> float:
        """Move one live slot's pages between shards: the ``migrate_slot``
        tier transition for the table/ledger, plus the per-page pool data
        copy the table contract leaves to the caller.  Returns the bytes
        moved (hot over the edge + cold host-internal)."""
        src_pool, dst_pool = self.pools[old], self.pools[new]
        st, dt = src_pool.table, dst_pool.table
        n = st.n_pages[slot]
        if n == 0:
            return 0.0
        src_phys = list(st.table[slot][:n])
        src_tier = list(st.tier[slot][:n])
        base = dt.n_pages[slot]
        out = self.mesh_table.migrate_slot(self._gslot(old, slot),
                                           self._gslot(new, slot))
        dst_phys = list(dt.table[slot][base:base + n])
        for i in range(n):
            hot = src_tier[i] == 0
            kk, vv = ("k_hot", "v_hot") if hot else ("k_cold", "v_cold")
            sp, dp = src_phys[i], dst_phys[i]
            for entry in src_pool._attn_entries(dst_pool.tree):
                stacked, s_ent, d_ent = entry
                if stacked:
                    val_k, val_v = s_ent[kk][:, sp], s_ent[vv][:, sp]
                else:
                    val_k, val_v = s_ent[kk][sp], s_ent[vv][sp]
                if hot:                        # the shard<->shard edge copy
                    val_k = jax.device_put(val_k, self.decode_devices[new])
                    val_v = jax.device_put(val_v, self.decode_devices[new])
                else:                          # host-internal re-homing
                    val_k = jnp.asarray(jax.device_get(val_k))
                    val_v = jnp.asarray(jax.device_get(val_v))
                if stacked:
                    k2 = d_ent[kk].at[:, dp].set(val_k)
                    v2 = d_ent[vv].at[:, dp].set(val_v)
                else:
                    k2 = d_ent[kk].at[dp].set(val_k)
                    v2 = d_ent[vv].at[dp].set(val_v)
                if not hot:
                    k2 = kvcache.to_host(k2, self.decode_devices[new])
                    v2 = kvcache.to_host(v2, self.decode_devices[new])
                d_ent[kk], d_ent[vv] = k2, v2
        return out["hot_bytes"] + out["cold_bytes"]

    # ----------------------------------------------------------- counters --
    def _note_tenant_pages(self):
        """Per-tenant *and* per-shard hot-footprint peaks (distinct physical
        hot pages; a page counts once per device holding a copy), sampled at
        the same layout events as the base engine."""
        ver = tuple(p.table.version for p in self.pools)
        if ver == self._dev_note_version and self._tenant_note_version != -1:
            return                         # no layout event since last sample
        self._dev_note_version = ver
        self._tenant_note_version = self.pools[0].table.version
        per_t: dict = {}
        per_d: dict = {}
        for s in range(self.B):
            d = self._shard_of(s)
            t = self.pools[d].table
            hot = {(d, t.table[s][i]) for i in range(t.n_pages[s])
                   if t.tier[s][i] == 0}
            per_d.setdefault(self._dev_label(d), set()).update(hot)
            tn = self._slot_tenant(s)
            if tn is not None:
                per_t.setdefault(tn, set()).update(hot)
        page_bytes = self.page_tokens * self._row_bytes
        for tn, pages in per_t.items():
            v = len(pages) * page_bytes
            if v > self.tenant_hot_peak.get(tn, 0):
                self.tenant_hot_peak[tn] = v
        for dn, pages in per_d.items():
            v = len(pages) * page_bytes
            if v > self.device_hot_peak.get(dn, 0):
                self.device_hot_peak[dn] = v

    @property
    def xdev_migration_bytes(self) -> float:
        """Bytes that crossed a prefill->decode edge (the MeshPageTable
        ledger; matches ``predict_pool_counters(..., dense_admit=True)``
        integer-exactly, shared-prefix admits included — shared pages stay
        put on the decode side, only the private tail crosses)."""
        return sum(b for (src, _), b in self.mesh_table.edge_bytes.items()
                   if src == "prefill")

    @property
    def edge_migration_bytes(self) -> dict:
        """The full per-edge ledger ``{(src, dst): bytes}`` — admit streams
        plus re-homing transitions, byte-conserving by construction
        (``MeshPageTable.check``)."""
        return dict(self.mesh_table.edge_bytes)

    def counters(self) -> dict:
        out = super().counters()
        if self.n_shards > 1:
            for k in self.pools[0].stats:
                out[k] = sum(p.stats[k] for p in self.pools)
            out["table_version"] = sum(p.table.version for p in self.pools)
        out["xdev_migration_bytes"] = self.xdev_migration_bytes
        out["edge_migration_bytes"] = self.edge_migration_bytes
        out["device_hot_peak"] = dict(self.device_hot_peak)
        return out


def price_disagg(trace, cm, decode_fast_bytes: float, *,
                 policy: str = "sentinel", graph: Optional[TierGraph] = None,
                 decode_devices: int = 1, **knobs) -> dict:
    """Price a serving trace colocated vs disaggregated at equal total HBM.

    Colocated: one device with ``2 * decode_fast_bytes`` of HBM runs both
    phases; each admission's prefill serializes into the decode stream (the
    ``extra_*`` channels of the recorded ``StepTraffic``).  Disaggregated:
    decode keeps ``decode_fast_bytes`` (its half of the same total), the
    ``extra_*`` channels move to the prefill group, and the finished KV
    streams over the prefill->decode edge(s) of ``graph`` (default: the
    ``TierGraph.mesh`` with ``decode_devices`` decode shards plus the
    prefill device) priced per edge as a pipe — overlapped behind decode
    instead of serialized.  With ``decode_devices = N > 1`` the decode
    stream splits evenly across N shard pipes (each with its share of the
    HBM) and the slowest shard paces the step.

    The admitted-prefill tokens behind each step's KV stream are recovered
    from ``StepTraffic.extra_flops`` when the trace prices compute
    (``flops_per_token``), else from the admit byte channel
    ``StepTraffic.extra_fast`` (computed prefill tokens × KV row bytes);
    a trace carrying admissions that neither channel can attribute raises
    instead of silently pricing the stream as zero.

    Returns ``{"colocated": CostReport, "disagg": CostReport,
    "edge_bytes": float, "graph": TierGraph}``.
    """
    if decode_devices < 1:
        raise ValueError(f"price_disagg(decode_devices={decode_devices}): "
                         "need at least one decode shard")
    graph = graph if graph is not None else \
        TierGraph.mesh(decode_devices + 1, cm,
                       decode_fast_bytes / decode_devices)
    res_c = simulate(trace, cm, 2.0 * decode_fast_bytes, policy, **knobs)
    colocated = cm.price(res_c.step_traffic)
    res_d = simulate(trace, cm, decode_fast_bytes, policy, **knobs)
    kv_row = trace.num_layers * trace.kv_token_bytes
    flops_tok = getattr(trace, "flops_per_token", 0.0)
    if not flops_tok and not kv_row and \
            getattr(trace, "prefill_tokens", None):
        raise ValueError(
            "price_disagg cannot attribute the prefill->decode KV stream: "
            "the trace admits prompts but has neither flops_per_token nor "
            "kv_token_bytes, so no StepTraffic channel (extra_flops / "
            "extra_fast) carries the admitted tokens")
    N = decode_devices
    prefill_name = f"dev{N}"
    stripped, edge_flows, dev_series, edge_total = [], [], [], 0.0
    for tr in res_d.step_traffic:
        # prefill tokens admitted this step; their KV is what crosses the
        # device<->device link.  extra_flops attributes them when the trace
        # prices compute; the admit byte channel extra_fast (= computed
        # prefill tokens x KV row bytes) covers flops-less traces.
        if flops_tok:
            ptok = tr.extra_flops / flops_tok
        elif kv_row:
            ptok = tr.extra_fast / kv_row
        else:
            ptok = 0.0
        flow = ptok * kv_row
        edge_total += flow
        base = replace(tr, extra_flops=0.0, extra_fast=0.0,
                       prefill_flops=0.0, prefill_read=0.0)
        stripped.append(base)
        flows = {}
        per_dev = {}
        for d in range(N):
            per_dev[f"dev{d}"] = base if N == 1 else replace(
                base, flops=base.flops / N, fast_read=base.fast_read / N,
                slow_read=base.slow_read / N,
                demand_read=base.demand_read / N, mig_in=base.mig_in / N,
                mig_out=base.mig_out / N, migs=base.migs / N)
            if flow:
                flows[(prefill_name, f"dev{d}")] = flow / N
        # the prefill group's own pipe runs concurrently with the shards:
        # its prompt compute (the extra/prefill channels) is one more
        # max() arm, never serialized into the decode stream
        per_dev[prefill_name] = replace(
            tr, flops=0.0, fast_read=0.0, slow_read=0.0, demand_read=0.0,
            mig_in=0.0, mig_out=0.0, migs=0.0, stall=0.0)
        edge_flows.append(flows)
        dev_series.append(per_dev)
    disagg = cm.price_on_graph(stripped, graph, edge_flows,
                               device_traffic=dev_series)
    return {"colocated": colocated, "disagg": disagg,
            "edge_bytes": edge_total, "graph": graph}
