"""Prefill/decode disaggregation over a device mesh.

Prefill and decode contend for different resources: prefill is a
compute-bound burst over the whole prompt, decode a bandwidth-bound
steady-state loop.  Colocated on one device, every admission serializes a
prompt's worth of compute into the decode stream (the cost model's
``extra_*`` channels).  Disaggregated, prefill runs on its own device group
and the finished KV pages stream over the device↔device edge into the
decode pools — the admission cost becomes a *pipe* (the link), overlapped
behind decode, instead of serialized compute.

``DisaggregatedEngine`` subclasses ``ContinuousBatcher`` on the pools
layout and changes exactly two things:

  * ``_prefill`` runs on the prefill device group (``launch.mesh.
    disagg_groups`` — the first import of the launch layer by the serving
    stack) and ``jax.device_put``s the finished ``(last, fresh)`` KV to the
    decode device.  The computation is the same jitted program, so logits
    are bit-identical to the single-device engine.
  * ``_alloc_admit_pages`` stages the admitted pages on the prefill
    device's ``PageTable`` and moves them into the decode slot as a
    ``MeshPageTable.migrate_slot`` tier transition, so every page crossing
    the edge is a first-class, byte-conserving migration — the
    ``("prefill", "decode")`` ledger entry matches
    ``predict_pool_counters()["xdev_migration_bytes"]`` integer-exactly.

Everything else (steady-state zero-re-pack decode, boundary demotions,
prefix sharing, plan adoption) is inherited unchanged.

``price_disagg`` is the planner-side model of the same trade: it prices a
workload colocated (prefill serialized, all the HBM) against disaggregated
(prefill stripped from the decode stream, KV streaming priced as a
``TierGraph`` edge pipe, decode on its own half of the HBM) — the
``bench_serve --disagg`` throughput gate.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional, Sequence

import jax

from repro.launch.mesh import disagg_groups
from repro.models import kvcache
from repro.runtime.policies import simulate
from repro.runtime.tiergraph import TierGraph
from repro.serve.engine import ContinuousBatcher


class DisaggregatedEngine(ContinuousBatcher):
    """A ``ContinuousBatcher`` whose prefill runs on a separate device group.

    ``devices`` (or a ``jax.sharding.Mesh``) is split by
    ``launch.mesh.disagg_groups``; with one device both groups alias it and
    the engine degrades gracefully (same program, same logits, the mesh
    page-table ledger still counts the logical edge traffic).  Requires the
    persistent-pools layout (``cfg.use_paged_decode``), which is what makes
    steady-state decode re-pack-free — the streamed pages land directly in
    the decode pools.
    """

    def __init__(self, params, cfg, batch_slots: int, max_seq: int,
                 scfg=None, plan=None, slot_tenants=None, devices=None):
        if plan is None:
            raise ValueError("DisaggregatedEngine requires a plan (the "
                             "pools layout is planned)")
        prefill_devs, decode_devs = disagg_groups(devices)
        self.prefill_device = prefill_devs[0]
        self.decode_device = decode_devs[0]
        params = jax.device_put(params, self.decode_device)
        super().__init__(params, cfg, batch_slots, max_seq, scfg=scfg,
                         plan=plan, paged=True, slot_tenants=slot_tenants)
        # prompts prefill whole on the prefill group and stream across the
        # device edge — pool-direct suffix chunks would write the decode
        # pools from the wrong device, so the legacy dense path stays on
        self._pool_prefill_ok = False
        if self.prefill_chunk_tokens:
            raise ValueError(
                "chunked prefill is colocated-engine only: the "
                "DisaggregatedEngine prefills whole prompts on the prefill "
                "group (set prefill_chunk_tokens=0)")
        if self.pool is None:
            raise ValueError(
                "DisaggregatedEngine needs the persistent pools layout: "
                "set cfg.use_paged_decode (and not cfg.prefix_lm)")
        pg = self.page_tokens
        # one staging slot on the prefill side: a request's pages are born
        # there and migrate to their decode slot as one tier transition
        self.mesh_table = kvcache.MeshPageTable(
            [kvcache.PageTable(1, max_seq // pg, pg), self.ptable],
            names=("prefill", "decode"),
            page_bytes=pg * self._row_bytes)
        self._stage = self.mesh_table.gslot(0, 0)
        self.params_prefill = jax.device_put(params, self.prefill_device)
        base_prefill = self._prefill           # the jitted model.prefill

        def prefill_remote(p, batch):
            del p                              # decode-side params unused
            batch = jax.device_put(batch, self.prefill_device)
            last, fresh = base_prefill(self.params_prefill, batch)
            # stream the finished KV over the device<->device edge
            return (jax.device_put(last, self.decode_device),
                    jax.device_put(fresh, self.decode_device))

        self._prefill = prefill_remote

    # ------------------------------------------------------------- admits --
    def _alloc_admit_pages(self, slot: int, n: int) -> None:
        need = n - self.ptable.n_pages[slot]
        if need <= 0:
            return
        stage_table = self.mesh_table.tables[0]
        for _ in range(need):
            stage_table.alloc(0, 0)            # prefill writes land here
        self.mesh_table.migrate_slot(self._stage,
                                     self.mesh_table.gslot(1, slot))

    # ----------------------------------------------------------- counters --
    @property
    def xdev_migration_bytes(self) -> float:
        """Bytes that crossed the prefill->decode edge (the MeshPageTable
        ledger; matches predict_pool_counters integer-exactly when no
        prefix pages are shared on the decode side)."""
        return self.mesh_table.edge_bytes.get(("prefill", "decode"), 0.0)

    def counters(self) -> dict:
        out = super().counters()
        out["xdev_migration_bytes"] = self.xdev_migration_bytes
        return out


def price_disagg(trace, cm, decode_fast_bytes: float, *,
                 policy: str = "sentinel", graph: Optional[TierGraph] = None,
                 **knobs) -> dict:
    """Price a serving trace colocated vs disaggregated at equal total HBM.

    Colocated: one device with ``2 * decode_fast_bytes`` of HBM runs both
    phases; each admission's prefill serializes into the decode stream (the
    ``extra_*`` channels of the recorded ``StepTraffic``).  Disaggregated:
    decode keeps ``decode_fast_bytes`` (its half of the same total), the
    ``extra_*`` channels move to the prefill group, and the finished KV
    streams over the ``dev1 -> dev0`` edge of ``graph`` (default: the
    2-device ``TierGraph.mesh``) priced per edge as a pipe — overlapped
    behind decode instead of serialized.

    Returns ``{"colocated": CostReport, "disagg": CostReport,
    "edge_bytes": float, "graph": TierGraph}``.
    """
    graph = graph if graph is not None else \
        TierGraph.mesh(2, cm, decode_fast_bytes)
    res_c = simulate(trace, cm, 2.0 * decode_fast_bytes, policy, **knobs)
    colocated = cm.price(res_c.step_traffic)
    res_d = simulate(trace, cm, decode_fast_bytes, policy, **knobs)
    kv_row = trace.num_layers * trace.kv_token_bytes
    flops_tok = getattr(trace, "flops_per_token", 0.0)
    stripped, edge_flows, edge_total = [], [], 0.0
    for tr in res_d.step_traffic:
        # prefill tokens admitted this step, recovered from the extra
        # channel; their KV is what crosses the device<->device link
        ptok = tr.extra_flops / flops_tok if flops_tok else 0.0
        flow = ptok * kv_row
        edge_total += flow
        edge_flows.append({("dev1", "dev0"): flow} if flow else {})
        stripped.append(replace(tr, extra_flops=0.0, extra_fast=0.0,
                                prefill_flops=0.0, prefill_read=0.0))
    disagg = cm.price_on_graph(stripped, graph, edge_flows)
    return {"colocated": colocated, "disagg": disagg,
            "edge_bytes": edge_total, "graph": graph}
