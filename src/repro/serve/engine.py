"""Batched serving engine: prefill + lockstep decode with KV caches.

``serve_step`` (one token for the whole batch against a filled cache) is the
function the decode-shape dry-run cells lower; ``generate`` drives it for the
examples/benchmarks with greedy or temperature sampling.

Sentinel-Serve: ``ContinuousBatcher`` optionally consults a decode-phase
``ServePlan`` (core/planner.plan_serve).  With a plan, each slot's KV cache is
tiered — the cold prefix (tokens older than the plan's hot window) lives in
host memory, the hot window in HBM — and slot refills splice the prefilled
cache into both tiers asynchronously.  Logits are bit-identical to the
all-HBM path: the merged view reads the same values, only their placement
(and therefore fetch bandwidth) differs.

Two tiered layouts:

  concat (``paged=False``)  one *global* cold boundary (``plan.cold_len``);
      the cold tree is a sequence slice, reads concatenate cold+hot.  Simple,
      but every slot pays the same boundary and a refill re-hosts the full
      global prefix for that slot.
  paged  (``paged=True``)   *per-slot* boundaries at page granularity
      (``plan.cold_len_slot``), backed by kvcache.PagedTieredCache plus a
      kvcache.PageTable that allocates/frees/demotes physical pages — the
      layout the paged decode kernel (kernels/paged_decode.py) consumes.  A
      refill touches only the refilled slot's pages; boundary advances demote
      single pages of the slot that grew.

``sim_migration_bytes`` counts every byte the batcher moves device<->host
(cold re-hosting), so the two layouts' migration traffic is directly
comparable (benchmarks/bench_serve.py --paged gates paged <= concat).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import kvcache, model


@dataclass
class ServeConfig:
    max_seq: int = 2048
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = -1             # -1: never stop early


def make_prefill(cfg):
    def prefill(params, batch):
        return model.prefill(params, cfg, batch)
    return jax.jit(prefill)


def make_serve_step(cfg):
    """(params, tokens(B,1[,K]), caches, index) -> (logits, caches)."""
    def step(params, tokens, caches, index):
        return model.decode_step(params, cfg, tokens, caches, index)
    return jax.jit(step, donate_argnums=(2,))


def sample(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ContinuousBatcher:
    """Slot-based continuous batching: a fixed B-slot decode batch; finished
    or empty slots are refilled from a request queue via per-slot prefill
    (cache splice), so decode throughput never waits for stragglers.

    All slots decode in lockstep against per-slot lengths (the flash-decode
    kernel and the jnp path both mask by `lengths`), which is the standard
    TPU-friendly formulation of continuous batching.
    """

    def __init__(self, params, cfg, batch_slots: int, max_seq: int,
                 scfg: Optional[ServeConfig] = None, plan=None,
                 paged: bool = False):
        if paged and plan is None:
            raise ValueError("paged=True requires a ServePlan (plan=...)")
        self.params, self.cfg = params, cfg
        self.B, self.max_seq = batch_slots, max_seq
        self.scfg = scfg or ServeConfig(max_seq=max_seq)
        self.plan = plan
        self.cold_len = plan.cold_len(max_seq) if plan is not None else 0
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        dt_bytes = 2 if dt == jnp.bfloat16 else 4
        self._row_bytes = kvcache.kv_token_bytes(cfg, dt_bytes) \
            * cfg.num_layers                       # KV bytes per token, all layers
        self.sim_migration_bytes = 0.0             # device<->host cold traffic
        self.paged = self.tiered = self.caches = self.ptable = None
        if paged:
            page = max(1, plan.page_tokens)
            if max_seq % page:                     # buffer must tile in pages
                page = next(p for p in range(page, 0, -1) if max_seq % p == 0)
            self.page_tokens = page
            self.paged = kvcache.init_paged_cache(cfg, batch_slots, max_seq,
                                                  page, dt)
            self.ptable = kvcache.PageTable(batch_slots, max_seq // page,
                                            page)
        elif self.cold_len > 0:
            self.tiered = kvcache.init_tiered_cache(cfg, batch_slots, max_seq,
                                                    self.cold_len, dt)
        else:
            self.caches = kvcache.init_cache(cfg, batch_slots, max_seq, dt)
        self.lengths = jnp.zeros((batch_slots,), jnp.int32)
        self.active = [False] * batch_slots
        self.budget = [0] * batch_slots         # tokens left to generate
        self.last_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.outputs = [[] for _ in range(batch_slots)]
        self.queue: list = []
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, cfg, b, max_seq=max_seq))

    def submit(self, tokens, num_tokens: int):
        self.queue.append((tokens, num_tokens))

    def _slot_cold_target(self, slot: int, seq_len: int) -> int:
        """Slot's cold boundary at ``seq_len`` tokens, in whole engine pages
        (the plan's page_tokens may have been adjusted to divide max_seq)."""
        return self.plan.cold_len_slot(slot, seq_len, self.page_tokens)

    def _admit(self):
        for slot in range(self.B):
            if self.active[slot] or not self.queue:
                continue
            tokens, budget = self.queue.pop(0)
            S = tokens.shape[-1]
            last, fresh = self._prefill(self.params,
                                        {"tokens": tokens[None]})
            # splice this request's prefilled cache row into the batch cache
            # (async dispatch: overlaps with in-flight decode work)
            if self.paged is not None:
                # per-slot boundary: only THIS slot's cold pages are re-hosted
                cold = self._slot_cold_target(slot, S)
                self.ptable.splice_slot(slot, S, cold)
                self.paged.hot = kvcache.splice_slot(self.paged.hot, fresh,
                                                     slot, self.B)
                self.paged.set_boundary(slot, 0)
                if cold:
                    self.paged.demote_rows(slot, cold)
                self.sim_migration_bytes += cold * self._row_bytes
            elif self.tiered is not None:
                fc, fh = kvcache.split_seq_cache(fresh, self.max_seq,
                                                 self.cold_len)
                self.tiered.cold = kvcache.to_host(kvcache.splice_slot(
                    self.tiered.cold, fc, slot, self.B))
                self.tiered.hot = kvcache.splice_slot(
                    self.tiered.hot, fh, slot, self.B)
                # global boundary: the full cold prefix re-hosts on refill
                self.sim_migration_bytes += self.cold_len * self._row_bytes
            else:
                self.caches = kvcache.splice_slot(self.caches, fresh, slot,
                                                  self.B)
            self.lengths = self.lengths.at[slot].set(S)
            self.last_tok = self.last_tok.at[slot].set(
                jnp.argmax(last[0, :self.cfg.vocab_size]).astype(jnp.int32))
            self.active[slot] = True
            self.budget[slot] = budget
            self.outputs[slot] = [int(self.last_tok[slot])]
            self.budget[slot] -= 1

    def step(self):
        """One lockstep decode step across all active slots — each slot writes
        its KV at its own length (vector cache_index -> row-wise scatter)."""
        self._admit()
        if not any(self.active):
            return False
        paged_view = None
        if self.paged is not None:
            caches = self.paged.merged()
            if self.cfg.use_paged_decode:
                # hand attention the engine's page layout so decode reads KV
                # through ops.paged_decode_attention (hot/cold pools + page
                # table) instead of the dense masked-merge view; boundaries
                # are concrete ints (pool packing happens at trace time) and
                # the layer-independent layout is built once per step here,
                # so each attention layer only gathers its own pools
                from repro.kernels.paged_decode import pool_layout
                boundaries = [int(b) for b in
                              jnp.asarray(self.paged.boundaries)]
                paged_view = {
                    "boundaries": boundaries,
                    "page_tokens": self.page_tokens,
                    "layout": pool_layout(boundaries,
                                          self.max_seq // self.page_tokens,
                                          self.page_tokens),
                }
        elif self.tiered is not None:
            caches = self.tiered.merged()
        else:
            caches = self.caches
        logits, new_caches, _ = model.forward(
            self.params, self.cfg, {"tokens": self.last_tok[:, None]},
            caches=caches, cache_index=self.lengths,
            decode=True, paged_view=paged_view)
        if self.paged is not None:
            self.paged.hot = new_caches
            # advance each active slot's own boundary: when the new length
            # pushes a page out of the slot's hot window, demote just that
            # page (hot -> cold pool in the table, rows re-hosted)
            for s in range(self.B):
                if not self.active[s]:
                    continue
                new_len = int(self.lengths[s]) + 1
                while self.ptable.n_pages[s] * self.page_tokens < new_len:
                    self.ptable.alloc(s, 0)        # decode grew into a new page
                target = self._slot_cold_target(s, new_len)
                moved = self.paged.demote_rows(s, target)
                while self.ptable.cold_tokens(s) < target:
                    self.ptable.demote(s, self.ptable.cold_pages(s))
                self.sim_migration_bytes += moved * self._row_bytes
        elif self.tiered is not None:
            _, hot = kvcache.split_seq_cache(new_caches, self.max_seq,
                                             self.cold_len)
            self.tiered.hot = hot
            # this step's KV writes land at each slot's length; a write
            # inside the prefix (short slots) re-hosts only that slot's row,
            # not a re-split of the whole batch cache
            for s in range(self.B):
                if self.active[s] and int(self.lengths[s]) < self.cold_len:
                    pos = int(self.lengths[s])
                    self.tiered.cold = kvcache.to_host(kvcache.copy_slot_rows(
                        self.tiered.cold, new_caches, s, pos, pos + 1,
                        self.max_seq))
                    self.sim_migration_bytes += self._row_bytes
        else:
            self.caches = new_caches
        tok = jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1) \
            .astype(jnp.int32)
        self.last_tok = tok
        self.lengths = self.lengths + jnp.asarray(
            [1 if a else 0 for a in self.active], jnp.int32)
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            self.outputs[slot].append(int(tok[slot]))
            self.budget[slot] -= 1
            if self.budget[slot] <= 0 or \
                    int(tok[slot]) == self.scfg.eos_id:
                self.active[slot] = False
        return True

    def run(self):
        results = []
        while self.queue or any(self.active):
            done_before = [(i, o) for i, (a, o) in
                           enumerate(zip(self.active, self.outputs)) if not a]
            if not self.step():
                break
            for i in range(self.B):
                if not self.active[i] and self.outputs[i]:
                    results.append(self.outputs[i])
                    self.outputs[i] = []
        return results


def serve_trace_for(cfg, requests: Sequence[tuple], *, slots: int,
                    params=None, block_tokens: int = 16,
                    recent_window: int = 32, history_period: int = 4,
                    dtype_bytes: int = 2, layer_group: int = 1):
    """Build the serving-phase trace (hmsim.ServeTrace) for this model and
    request stream — the profiling step of the decode-phase planner.  KV
    bytes/token come from the cache geometry; weight bytes and flops/token
    from the parameter count (2N MACs/token) when ``params`` is given, else
    from the config's dense-layer dimensions.  ``layer_group`` coarsens the
    object granularity to one KV block per *group* of layers (same total
    bytes, fewer objects) — the simulator cost scales with object count while
    the byte geometry is what decides placement quality."""
    from repro.core import hmsim
    kv_tok = kvcache.kv_token_bytes(cfg, dtype_bytes)
    layers = max(1, -(-cfg.num_layers // max(1, layer_group)))
    if params is not None:
        n_params = sum(int(a.size) for a in jax.tree.leaves(params))
    else:
        n_params = (12 * cfg.num_layers * cfg.d_model ** 2
                    + cfg.vocab_size * cfg.d_model)
    return hmsim.build_serve_trace(
        requests, num_slots=slots, num_layers=layers,
        kv_token_bytes=kv_tok * cfg.num_layers / layers,
        block_tokens=block_tokens,
        recent_window=recent_window, history_period=history_period,
        flops_per_token=2.0 * n_params,
        weight_bytes=float(n_params) * dtype_bytes)


def generate(params, cfg, prompts, num_tokens: int,
             scfg: Optional[ServeConfig] = None, key=None):
    """prompts: {"tokens": (B, S)[, "prefix_embed"]}. Returns (B, num_tokens)."""
    scfg = scfg or ServeConfig()
    key = key if key is not None else jax.random.PRNGKey(0)
    B = prompts["tokens"].shape[0]
    S = prompts["tokens"].shape[1] + (cfg.num_prefix_tokens
                                      if "prefix_embed" in prompts else 0)
    max_seq = max(scfg.max_seq, S + num_tokens)

    last_logits, caches = model.prefill(params, cfg, prompts, max_seq=max_seq)
    step_fn = make_serve_step(cfg)

    outs = []
    if cfg.num_codebooks:
        last_logits = last_logits.reshape(B, cfg.num_codebooks, -1)
    tok = sample(last_logits[..., :cfg.vocab_size], key, scfg.temperature)
    for i in range(num_tokens):
        outs.append(tok)
        feed = tok[:, None] if not cfg.num_codebooks else tok[:, None, :]
        logits, caches = step_fn(params, feed.astype(jnp.int32), caches,
                                 jnp.asarray(S + i, jnp.int32))
        key, sub = jax.random.split(key)
        if cfg.num_codebooks:
            logits = logits.reshape(B, cfg.num_codebooks, -1)
        tok = sample(logits[..., :cfg.vocab_size], sub, scfg.temperature)
    return jnp.stack(outs, axis=1)
