"""Batched serving engine: prefill + lockstep decode with KV caches.

``serve_step`` (one token for the whole batch against a filled cache) is the
function the decode-shape dry-run cells lower; ``generate`` drives it for the
examples/benchmarks with greedy or temperature sampling.

Sentinel-Serve: ``ContinuousBatcher`` optionally consults a decode-phase
``ServePlan`` (core/planner.plan_serve).  With a plan, each slot's KV cache is
tiered — the cold prefix (tokens older than the plan's hot window) lives in
host memory, the hot window in HBM — and slot refills splice the prefilled
cache into both tiers asynchronously.  Logits are bit-identical to the
all-HBM path: the merged view reads the same values, only their placement
(and therefore fetch bandwidth) differs.

Three tiered layouts:

  concat (``paged=False``)  one *global* cold boundary (``plan.cold_len``);
      the cold tree is a sequence slice, reads concatenate cold+hot.  Simple,
      but every slot pays the same boundary and a refill re-hosts the full
      global prefix for that slot.
  paged  (``paged=True``)   *per-slot* boundaries at page granularity
      (``plan.cold_len_slot``), backed by kvcache.PagedTieredCache plus a
      kvcache.PageTable that allocates/frees/demotes physical pages.  The
      dense hot tree remains the working copy; the masked merge reads it.
  pools  (``paged=True`` + ``cfg.use_paged_decode``)  the persistent
      physical page pools (kvcache.PagedKVPools) ARE the cache: decode
      writes each token's KV into its physical hot page through the page
      table and attention reads the pools via ops.paged_decode_attention.
      Steady-state ``step()`` performs zero dense re-packs and zero
      boundary host-syncs — layout state lives host-side in the PageTable
      and changes only on admit / page-crossing / demote / free events.
      Requests submitted with a ``prefix_key`` share their common prompt
      prefix *physically*: full pages below the fork point map to the same
      refcounted physical pages (copy-on-write on the first divergent
      write), so N tenants with one system prompt hold its KV once.

``sim_migration_bytes`` counts every byte the batcher moves device<->host
(cold re-hosting), so the layouts' migration traffic is directly comparable
(benchmarks/bench_serve.py --paged gates paged <= concat; --shared-prefix
gates shared < unshared).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import kvcache, model


@dataclass
class ServeConfig:
    max_seq: int = 2048
    temperature: float = 0.0     # 0 = greedy
    eos_id: int = -1             # -1: never stop early


@dataclass
class _PrefillJob:
    """Per-slot admission state machine: ``queued -> prefilling(done_tokens)
    -> active``.  A job binds one queued request to its batch slot; the
    prefill scheduler (``_drain_prefill``) advances ``done`` chunk by chunk
    — across decode steps when ``prefill_chunk_tokens`` bounds the per-step
    budget, and across ``apply_plan`` re-plans (nothing in the job refers to
    the plan; boundaries are applied at finalize)."""
    tokens: Any                  # device prompt (S,)
    tok_host: tuple              # host mirror, cached once at submit()
    S: int                       # prompt length
    budget: int                  # decode tokens requested
    prefix_key: Any
    tenant: Any
    done: int = 0                # prompt tokens whose KV is materialized
    shared_pages: int = 0        # full pages mapped onto the donor (skipped)
    started: bool = False        # pages freed/shared, donor registered
    last: Any = None             # last computed row's logits (1, vocab)


def make_prefill(cfg):
    def prefill(params, batch):
        return model.prefill(params, cfg, batch)
    return jax.jit(prefill)


def make_serve_step(cfg):
    """(params, tokens(B,1[,K]), caches, index) -> (logits, caches)."""
    def step(params, tokens, caches, index):
        return model.decode_step(params, cfg, tokens, caches, index)
    return jax.jit(step, donate_argnums=(2,))


def sample(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ContinuousBatcher:
    """Slot-based continuous batching: a fixed B-slot decode batch; finished
    or empty slots are refilled from a request queue via per-slot prefill
    (cache splice), so decode throughput never waits for stragglers.

    All slots decode in lockstep against per-slot lengths (the flash-decode
    kernel and the jnp path both mask by `lengths`), which is the standard
    TPU-friendly formulation of continuous batching.
    """

    def __init__(self, params, cfg, batch_slots: int, max_seq: int,
                 scfg: Optional[ServeConfig] = None, plan=None,
                 paged: bool = False, slot_tenants=None,
                 prefill_chunk_tokens: Optional[int] = None):
        if paged and plan is None:
            raise ValueError("paged=True requires a ServePlan (plan=...)")
        self.params, self.cfg = params, cfg
        self.B, self.max_seq = batch_slots, max_seq
        self.scfg = scfg or ServeConfig(max_seq=max_seq)
        self.plan = plan
        # prefill scheduling: at most this many prompt tokens of pending
        # prefill run per step() before the decode dispatch (0 = unlimited,
        # i.e. every admit prefills in one shot like the legacy path).
        # Defaults from the plan so `runtime.plan(...)` can carry the knob.
        if prefill_chunk_tokens is None:
            prefill_chunk_tokens = getattr(plan, "prefill_chunk_tokens", 0) \
                if plan is not None else 0
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        # multi-tenant plans partition the batch slots: a request tagged with
        # a tenant is only admitted into that tenant's slots, so one bursty
        # tenant can never occupy the whole batch.  ``slot_tenants=`` lets an
        # un-planned (all-HBM) reference run replay the same admission
        # schedule, keeping logits comparable slot for slot.
        if slot_tenants is None and plan is not None:
            slot_tenants = getattr(plan, "slot_tenants", None)
        self.slot_tenants = list(slot_tenants) if slot_tenants else None
        if self.slot_tenants and len(self.slot_tenants) != batch_slots:
            # silent wrap-around would mis-assign tenant ownership — the
            # plan must have been built for this batch geometry
            raise ValueError(
                f"slot_tenants has {len(self.slot_tenants)} entries for "
                f"{batch_slots} batch slots (plan/batch geometry mismatch)")
        self.tenant_hot_peak: dict = {}        # tenant -> peak hot pool bytes
        self._tenant_note_version = -1         # last-sampled table version
        self.cold_len = plan.cold_len(max_seq) if plan is not None else 0
        dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        dt_bytes = 2 if dt == jnp.bfloat16 else 4
        self._row_bytes = kvcache.kv_token_bytes(cfg, dt_bytes) \
            * cfg.num_layers                       # KV bytes per token, all layers
        self.sim_migration_bytes = 0.0             # device<->host cold traffic
        # per-decode-step deltas of sim_migration_bytes (admission + boundary
        # demotions attributed to the step that performed them): the engine's
        # replayed traffic series, priced by a CostModel and matched
        # integer-exactly by predict_pool_counters()["step_migration_bytes"].
        # The series is tracked against a persistent high-water marker, not a
        # per-step local, so bytes moved BETWEEN steps (apply_plan adopting a
        # re-plan) land in the next step's entry instead of vanishing —
        # sum(step_migration_bytes) == sim_migration_bytes always.
        self.step_migration_bytes: list = []
        self._mig_accounted = 0.0
        self.paged = self.tiered = self.caches = self.ptable = None
        self.pool = None
        if paged:
            page = max(1, plan.page_tokens)
            if max_seq % page:                     # buffer must tile in pages
                page = next(p for p in range(page, 0, -1) if max_seq % p == 0)
            self.page_tokens = page
            if cfg.use_paged_decode and not cfg.prefix_lm:
                # persistent pools: the page table owns physical placement,
                # decode writes through it (no dense mirror to re-pack)
                self.pool = kvcache.PagedKVPools(cfg, batch_slots, max_seq,
                                                 page, dt)
                self.ptable = self.pool.table
            else:
                self.paged = kvcache.init_paged_cache(cfg, batch_slots,
                                                      max_seq, page, dt)
                self.ptable = kvcache.PageTable(batch_slots, max_seq // page,
                                                page)
        elif self.cold_len > 0:
            self.tiered = kvcache.init_tiered_cache(cfg, batch_slots, max_seq,
                                                    self.cold_len, dt)
        else:
            self.caches = kvcache.init_cache(cfg, batch_slots, max_seq, dt)
        self.lengths = jnp.zeros((batch_slots,), jnp.int32)
        self.active = [False] * batch_slots
        self.budget = [0] * batch_slots         # tokens left to generate
        self.last_tok = jnp.zeros((batch_slots,), jnp.int32)
        self.outputs = [[] for _ in range(batch_slots)]
        # per-tenant admission queues (None = untagged): _pop_for_slot walks
        # only the queues a slot may draw from, instead of the old
        # O(slots x queue) scan over one flat list.  ``_qseq`` stamps global
        # FIFO order so cross-tenant arrival order is preserved exactly.
        self._queues: dict = {}
        self._qseq = 0
        # host-side mirrors: per-slot lengths and the active set, kept in
        # lockstep with the device arrays so per-step bookkeeping (page
        # targets, boundary advances) never reads a device array back
        self._host_len = [0] * batch_slots
        self._active_mask = jnp.zeros((batch_slots,), bool)
        self._active_inc = jnp.zeros((batch_slots,), jnp.int32)
        self._prefix_donor: dict = {}          # prefix_key -> (slot, tokens)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, cfg, b, max_seq=max_seq))
        # prefill scheduler state + counters
        self._jobs: dict = {}                  # slot -> _PrefillJob
        self._step_prefill = 0                 # prompt tokens run this step
        self.step_prefill_tokens: list = []    # per-step series (counters())
        self.prefill_compute_tokens = 0        # prompt rows actually run
        self.prefill_skipped_tokens = 0        # rows skipped via shared pages
        # pool-direct prefill (suffix compute straight into the physical
        # pages) needs every layer's cache to be a pool entry: pure-attention
        # stacks only, and not the disaggregated engine (which prefills on
        # its own device group).  Everything else keeps the legacy dense
        # one-shot prefill + admit_rows splice.
        kinds = tuple(cfg.prologue) + tuple(cfg.period)
        self._pool_prefill_ok = (
            self.pool is not None
            and all(k in kvcache.ATTN_KINDS for k in kinds)
            and not cfg.prefix_lm and not cfg.num_prefix_tokens
            and not cfg.num_codebooks)
        if self.prefill_chunk_tokens and not self._pool_prefill_ok:
            raise ValueError(
                "prefill_chunk_tokens requires the persistent-pools layout "
                "with a pure-attention stack (pool-direct prefill)")

    def submit(self, tokens, num_tokens: int, prefix_key=None, tenant=None):
        """Queue a request.  ``prefix_key`` (hashable) marks requests that
        share a common prompt prefix (e.g. one system prompt per tenant):
        on the pools layout their common full pages map to the same physical
        pages, refcounted, with copy-on-write past the fork point.
        ``tenant`` restricts admission to the tenant's own slots when the
        plan carries ``slot_tenants`` (untagged requests admit anywhere)."""
        if tenant is not None and self.slot_tenants and \
                tenant not in self.slot_tenants:
            # an unknown tag would never match a slot: the request would sit
            # in the queue forever and run() would drop it silently
            raise ValueError(f"tenant {tenant!r} owns no batch slot "
                             f"(slot_tenants={self.slot_tenants})")
        # host mirror cached once here: admissions (LCP against the donor)
        # and the chunker never re-run jax.device_get on the prompt
        tok_host = tuple(int(t) for t in jax.device_get(tokens))
        self._queues.setdefault(tenant, deque()).append(
            (self._qseq, tokens, tok_host, num_tokens, prefix_key, tenant))
        self._qseq += 1

    @property
    def queue(self) -> list:
        """Pending requests in global FIFO order, as ``(tokens, num_tokens,
        prefix_key, tenant)`` — the legacy flat-queue view (tests drive
        ``while b.queue or any(b.active)``); admission itself walks the
        per-tenant deques directly."""
        items = sorted((it for q in self._queues.values() for it in q),
                       key=lambda it: it[0])
        return [(t, n, pk, tn) for _, t, _, n, pk, tn in items]

    def _slot_tenant(self, slot: int):
        return self.slot_tenants[slot] if self.slot_tenants else None

    def _pop_for_slot(self, slot: int):
        """Pop the earliest-submitted request admissible to ``slot`` (FIFO
        within each tenant; untagged requests match any slot).  Only the
        slot's own tenant queue and the untagged queue are consulted — the
        per-tenant split replaces the old O(slots x queue) flat scan while
        preserving global FIFO order exactly."""
        tn = self._slot_tenant(slot)
        best_q = None
        for key in (self._queues.keys() if tn is None else (None, tn)):
            q = self._queues.get(key)
            if q and (best_q is None or q[0][0] < best_q[0][0]):
                best_q = q
        return best_q.popleft() if best_q else None

    def _note_tenant_pages(self):
        """Record each tenant's current hot-pool footprint (distinct
        physical hot pages across its slots — shared pages count once) and
        fold it into the per-tenant peak counters the SLO report reads.
        Event-driven like the rest of the pools bookkeeping: the footprint
        can only change when the page table mutates, so a steady-state step
        is a single version compare."""
        if not self.slot_tenants or self.ptable is None:
            return
        if self.ptable.version == self._tenant_note_version:
            return                         # no layout event since last sample
        self._tenant_note_version = self.ptable.version
        per: dict = {}
        for s in range(self.B):
            tn = self._slot_tenant(s)
            if tn is None:
                continue
            per.setdefault(tn, set()).update(
                self.ptable.table[s][i]
                for i in range(self.ptable.n_pages[s])
                if self.ptable.tier[s][i] == 0)
        page_bytes = self.page_tokens * self._row_bytes
        for tn, pages in per.items():
            v = len(pages) * page_bytes
            if v > self.tenant_hot_peak.get(tn, 0):
                self.tenant_hot_peak[tn] = v

    def _refresh_active(self):
        """Re-derive the cached device-side active mask (event-driven: only
        called when a slot starts or finishes, never per step)."""
        self._active_mask = jnp.asarray(self.active, bool)
        self._active_inc = jnp.asarray(
            [1 if a else 0 for a in self.active], jnp.int32)

    def _slot_cold_target(self, slot: int, seq_len: int) -> int:
        """Slot's cold boundary at ``seq_len`` tokens, in whole engine pages
        (the plan's page_tokens may have been adjusted to divide max_seq)."""
        return self.plan.cold_len_slot(slot, seq_len, self.page_tokens)

    def _admit_pool(self, slot: int, tok_host, fresh, S: int, prefix_key):
        """Admit into the persistent pools: free the slot's page refs, map
        shared-prefix full pages onto the donor's physical pages, allocate
        private pages for the rest, write the prefilled rows into them, and
        advance the cold boundary by per-page demotion.  Every operation is
        an incremental delta on the slot's own pages.  (Legacy dense-prefill
        splice — the pool-direct scheduler writes through attention instead;
        this path remains for the disaggregated engine and mixed stacks.)"""
        pg = self.page_tokens
        # stale donor registrations for this slot die with its pages
        for key in [k for k, (s, _) in self._prefix_donor.items()
                    if s == slot]:
            del self._prefix_donor[key]
        self.pool.free_slot(slot)
        shared_pages = 0
        if prefix_key is not None:
            donor = self._prefix_donor.get(prefix_key)
            if donor is not None and donor[0] != slot and \
                    self.ptable.n_pages[donor[0]] > 0:
                lcp = 0
                for a, b in zip(tok_host, donor[1]):
                    if a != b:
                        break
                    lcp += 1
                # only full pages strictly below the write region are shared,
                # so the page decode writes into is never a shared page
                shared_pages = min(lcp // pg, self.ptable.n_pages[donor[0]])
                if shared_pages:
                    self.pool.share(slot, donor[0], shared_pages)
            self._prefix_donor[prefix_key] = (slot, tok_host)
        n = -(-S // pg)
        self._alloc_admit_pages(slot, n)
        self.pool.admit_rows(fresh, slot, range(shared_pages, n))
        self.pool.splice_other(fresh, slot)
        # cold boundary: demote page by page toward the plan's target (shared
        # pages already cold, or deduped through a twin, move zero bytes)
        target = self._slot_cold_target(slot, S)
        while self.ptable.cold_tokens(slot) < target:
            if self.pool.demote_boundary(slot):
                self.sim_migration_bytes += pg * self._row_bytes

    def _alloc_admit_pages(self, slot: int, n: int) -> None:
        """Grow ``slot`` to ``n`` hot pages for an admission.  The seam the
        disaggregated engine overrides: there the pages are staged on the
        prefill device's table and cross the device↔device edge as a
        ``MeshPageTable.migrate_slot`` transition instead of being allocated
        in place."""
        for _ in range(self.ptable.n_pages[slot], n):
            self.ptable.alloc(slot, 0)

    def _admit(self):
        """Bind queued requests to free slots and advance pending prefill.

        Pool-direct stacks go through the admission state machine: binding
        creates a ``_PrefillJob`` (``queued -> prefilling``) and
        ``_drain_prefill`` runs page-aligned chunks up to the per-step
        budget; a slot flips ``-> active`` only when its whole prompt's KV
        is materialized.  Other layouts admit one-shot as before."""
        for slot in range(self.B):
            if self.active[slot] or slot in self._jobs:
                continue
            item = self._pop_for_slot(slot)
            if item is None:
                continue                   # no queued request for this tenant
            _, tokens, tok_host, budget, prefix_key, tenant = item
            S = int(tokens.shape[-1])
            if self._pool_prefill_ok:
                self._jobs[slot] = _PrefillJob(tokens, tok_host, S, budget,
                                               prefix_key, tenant)
            else:
                self._admit_dense(slot, tokens, tok_host, S, budget,
                                  prefix_key)
        if self._jobs:
            self._drain_prefill()

    def _admit_dense(self, slot: int, tokens, tok_host, S: int, budget: int,
                     prefix_key):
        """Legacy one-shot admission: dense full-prompt prefill, then a
        layout-specific cache splice (async dispatch: overlaps with
        in-flight decode work)."""
        last, fresh = self._prefill(self.params,
                                    {"tokens": tokens[None]})
        if self.pool is not None:
            self._admit_pool(slot, tok_host, fresh, S, prefix_key)
        elif self.paged is not None:
            # per-slot boundary: only THIS slot's cold pages are re-hosted
            cold = self._slot_cold_target(slot, S)
            self.ptable.splice_slot(slot, S, cold)
            self.paged.hot = kvcache.splice_slot(self.paged.hot, fresh,
                                                 slot, self.B)
            self.paged.set_boundary(slot, 0)
            if cold:
                self.paged.demote_rows(slot, cold)
            self.sim_migration_bytes += cold * self._row_bytes
        elif self.tiered is not None:
            fc, fh = kvcache.split_seq_cache(fresh, self.max_seq,
                                             self.cold_len)
            self.tiered.cold = kvcache.to_host(kvcache.splice_slot(
                self.tiered.cold, fc, slot, self.B))
            self.tiered.hot = kvcache.splice_slot(
                self.tiered.hot, fh, slot, self.B)
            # global boundary: the full cold prefix re-hosts on refill
            self.sim_migration_bytes += self.cold_len * self._row_bytes
        else:
            self.caches = kvcache.splice_slot(self.caches, fresh, slot,
                                              self.B)
        self.prefill_compute_tokens += S
        self._step_prefill += S
        self._activate(slot, S, last, budget)

    def _activate(self, slot: int, S: int, last, budget: int):
        """Common tail of every admission: slot state flips to active with
        the prompt's last-row logits decoding its first token."""
        self.lengths = self.lengths.at[slot].set(S)
        self._host_len[slot] = S
        self.last_tok = self.last_tok.at[slot].set(
            jnp.argmax(last[0, :self.cfg.vocab_size]).astype(jnp.int32))
        self.active[slot] = True
        self.budget[slot] = budget
        self.outputs[slot] = [int(self.last_tok[slot])]
        self.budget[slot] -= 1
        self._refresh_active()
        self._note_tenant_pages()

    def _drain_prefill(self):
        """Run pending prefill jobs, at most ``prefill_chunk_tokens`` prompt
        tokens this step (0 = no budget: each job completes in one shot).
        Jobs drain in slot order; chunk ends are page-aligned except a final
        partial page, and the budget may overdraw by less than one page so a
        tiny budget still guarantees progress."""
        pg = self.page_tokens
        budget = self.prefill_chunk_tokens
        spent = 0
        for slot in sorted(self._jobs):
            job = self._jobs[slot]
            while slot in self._jobs:
                if budget and spent >= budget:
                    return                 # budget exhausted: resume next step
                if not job.started:
                    self._start_job(slot, job)
                pages_left = -(-(job.S - job.done) // pg)
                take = pages_left if not budget else \
                    min(pages_left, max(1, (budget - spent) // pg))
                end = min(job.S, job.done + take * pg)
                spent += end - job.done
                self._run_chunk(slot, job, end)
                if job.done >= job.S:
                    self._finish_job(slot, job)

    def _start_job(self, slot: int, job: _PrefillJob):
        """First touch of a job: free the slot's old pages, map shared-
        prefix full pages onto the donor's physical pages (the *compute
        skip*: those rows' KV is already materialized, so prefill starts at
        ``done = shared_pages * page_tokens``), and register this prompt as
        the new donor."""
        pg = self.page_tokens
        for key in [k for k, (s, _) in self._prefix_donor.items()
                    if s == slot]:
            del self._prefix_donor[key]
        self.pool.free_slot(slot)
        shared = 0
        if job.prefix_key is not None:
            donor = self._prefix_donor.get(job.prefix_key)
            if donor is not None and donor[0] != slot and \
                    self.ptable.n_pages[donor[0]] > 0:
                lcp = 0
                for a, b in zip(job.tok_host, donor[1]):
                    if a != b:
                        break
                    lcp += 1
                # capped three ways: full pages of common prefix, pages the
                # donor actually holds (a mid-prefill donor's pages are valid
                # only up to its own ``done``), and strictly below the
                # prompt's last token — the suffix pass must compute at least
                # one row (the next-token logits), and a shared page is
                # never written
                shared = min(lcp // pg, self.ptable.n_pages[donor[0]],
                             (job.S - 1) // pg)
                if shared:
                    self.pool.share(slot, donor[0], shared)
            self._prefix_donor[job.prefix_key] = (slot, job.tok_host)
        job.shared_pages = shared
        job.done = shared * pg
        job.started = True
        self.prefill_skipped_tokens += job.done

    def _run_chunk(self, slot: int, job: _PrefillJob, end: int):
        """One page-aligned prefill chunk ``tokens[done:end]`` straight into
        the slot's physical pages (model.prefill_suffix with this slot's
        page-table row): attention writes the chunk's KV through the table
        and each row attends back over the donor pages + earlier chunks, so
        the rows are bit-identical to the same rows of a one-shot dense
        prefill."""
        pg = self.page_tokens
        first = self.ptable.n_pages[slot]
        n = -(-end // pg)
        self._alloc_admit_pages(slot, n)
        self.pool.stats["admit_page_writes"] += n - first
        table, tier = self.pool.arrays()
        view = {"page_table": table[slot][None], "page_tier": tier[slot][None],
                "page_tokens": pg, "active": None, "prefill": True}
        job.last, self.pool.tree = model.prefill_suffix(
            self.params, self.cfg,
            {"tokens": job.tokens[job.done:end][None]},
            caches=self.pool.tree, start=job.done, paged_view=view)
        self.prefill_compute_tokens += end - job.done
        self._step_prefill += end - job.done
        job.done = end

    def _finish_job(self, slot: int, job: _PrefillJob):
        """Prompt fully materialized: advance the cold boundary to the
        *current* plan's target (re-plans adopted mid-prefill land here) and
        flip the slot active."""
        del self._jobs[slot]
        target = self._slot_cold_target(slot, job.S)
        while self.ptable.cold_tokens(slot) < target:
            if self.pool.demote_boundary(slot):
                self.sim_migration_bytes += self.page_tokens * self._row_bytes
        self._activate(slot, job.S, job.last, job.budget)

    def _pool_decode_step(self):
        """One decode forward on the persistent-pools layout: write-page
        guarantee, the batched forward through the page-table view, and the
        post-step cold-boundary advance.  Returns the decoded tokens (B,)
        int32.  The seam the disaggregated engine overrides to run one
        sub-batch forward per decode shard against that shard's own pools."""
        # pre-step page guarantee per active slot: the write page exists
        # and is private (CoW fires here on the first divergent write
        # past a shared-prefix fork point — a no-op otherwise)
        for s in range(self.B):
            if self.active[s]:
                self.pool.ensure_write_page(s, self._host_len[s])
        paged_view = self.pool.paged_view(self._active_mask)
        logits, new_caches, _ = model.forward(
            self.params, self.cfg, {"tokens": self.last_tok[:, None]},
            caches=self.pool.tree, cache_index=self.lengths,
            decode=True, paged_view=paged_view)
        self.pool.tree = new_caches
        # advance each grown slot's own cold boundary by whole pages;
        # twin-deduped shared pages advance the boundary with zero copy
        for s in range(self.B):
            if not self.active[s]:
                continue
            target = self._slot_cold_target(s, self._host_len[s] + 1)
            while self.ptable.cold_tokens(s) < target:
                if self.pool.demote_boundary(s):
                    self.sim_migration_bytes += \
                        self.page_tokens * self._row_bytes
        self._note_tenant_pages()
        return jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1) \
            .astype(jnp.int32)

    def step(self):
        """One lockstep decode step across all active slots — each slot writes
        its KV at its own length (vector cache_index -> row-wise scatter).

        On the pools layout the steady-state body is re-pack-free and
        host-sync-free: the caches handed to the model ARE the persistent
        pools, the page-table arrays are cached until the table mutates, and
        all boundary/length bookkeeping runs on host-side mirrors.  Layout
        work happens only at events (admit, a slot growing into a new page,
        a boundary advance)."""
        self._step_prefill = 0
        self._admit()
        if not any(self.active):
            if self._jobs:
                # prefill-only step: the chunk budget ran but no slot is
                # ready to decode yet — still a step for accounting (the
                # migration/prefill series stay aligned with real steps)
                self.step_prefill_tokens.append(self._step_prefill)
                self.step_migration_bytes.append(
                    self.sim_migration_bytes - self._mig_accounted)
                self._mig_accounted = self.sim_migration_bytes
                return True
            return False
        if self.pool is not None:
            tok = self._pool_decode_step()
        else:
            if self.paged is not None:
                caches = self.paged.merged()
            elif self.tiered is not None:
                caches = self.tiered.merged()
            else:
                caches = self.caches
            logits, new_caches, _ = model.forward(
                self.params, self.cfg, {"tokens": self.last_tok[:, None]},
                caches=caches, cache_index=self.lengths, decode=True)
            if self.paged is not None:
                self.paged.hot = new_caches
                # advance each active slot's own boundary: when the new
                # length pushes a page out of the slot's hot window, demote
                # just that page (hot -> cold pool in the table, rows
                # re-hosted)
                for s in range(self.B):
                    if not self.active[s]:
                        continue
                    new_len = self._host_len[s] + 1
                    while self.ptable.n_pages[s] * self.page_tokens < new_len:
                        self.ptable.alloc(s, 0)    # decode grew into a new page
                    target = self._slot_cold_target(s, new_len)
                    moved = self.paged.demote_rows(s, target)
                    while self.ptable.cold_tokens(s) < target:
                        self.ptable.demote(s, self.ptable.cold_pages(s))
                    self.sim_migration_bytes += moved * self._row_bytes
                self._note_tenant_pages()
            elif self.tiered is not None:
                _, hot = kvcache.split_seq_cache(new_caches, self.max_seq,
                                                 self.cold_len)
                self.tiered.hot = hot
                # this step's KV writes land at each slot's length; a write
                # inside the prefix (short slots) re-hosts only that slot's
                # row, not a re-split of the whole batch cache
                for s in range(self.B):
                    if self.active[s] and self._host_len[s] < self.cold_len:
                        pos = self._host_len[s]
                        self.tiered.cold = kvcache.to_host(
                            kvcache.copy_slot_rows(
                                self.tiered.cold, new_caches, s, pos, pos + 1,
                                self.max_seq))
                        self.sim_migration_bytes += self._row_bytes
            else:
                self.caches = new_caches
            tok = jnp.argmax(logits[:, -1, :self.cfg.vocab_size], axis=-1) \
                .astype(jnp.int32)
        self.last_tok = tok
        self.lengths = self.lengths + self._active_inc
        tok_host = jax.device_get(tok)         # the decoded tokens themselves
        was_active = list(self.active)
        for slot in range(self.B):
            if not was_active[slot]:
                continue
            self._host_len[slot] += 1
            self.outputs[slot].append(int(tok_host[slot]))
            self.budget[slot] -= 1
            if self.budget[slot] <= 0 or \
                    int(tok_host[slot]) == self.scfg.eos_id:
                self.active[slot] = False
        if self.active != was_active:
            self._refresh_active()
        self.step_prefill_tokens.append(self._step_prefill)
        self.step_migration_bytes.append(
            self.sim_migration_bytes - self._mig_accounted)
        self._mig_accounted = self.sim_migration_bytes
        return True

    def apply_plan(self, new_plan):
        """Adopt a re-plan (or an incremental ``runtime.PlanDelta``) on the
        live pools layout, between decode steps.

        The online replanner (``runtime/online.py``) emits deltas; applying
        one here re-targets every active slot's cold boundary under the new
        plan's hot windows through the page-table version machinery — page-
        grain demotions, refcount-aware, zero copies for twin-deduped shared
        pages — and re-partitions slot tenancy for subsequent admissions.
        Grown windows cost nothing (cold pages are never promoted back).
        In-flight prefill jobs are unaffected and resume under the new plan
        (their cold boundary is applied at finalize, from the plan current
        *then*).  Returns the migration bytes moved; they are attributed to
        the *next*
        decode step's ``step_migration_bytes`` entry, exactly as
        ``predict_pool_counters(..., plan_schedule=...)`` replays it."""
        if self.pool is None:
            raise ValueError("apply_plan requires the persistent-pools "
                             "layout (use_paged_decode=True)")
        if hasattr(new_plan, "changes"):       # a PlanDelta, not a plan
            new_plan = self.plan.apply_delta(new_plan)
        page = max(1, new_plan.page_tokens)
        if self.max_seq % page:
            page = next(p for p in range(page, 0, -1)
                        if self.max_seq % p == 0)
        if page != self.page_tokens:
            raise ValueError(
                f"re-plan changes page geometry ({page} != "
                f"{self.page_tokens} tokens/page) — pools cannot be "
                "re-paged in place")
        tenants = getattr(new_plan, "slot_tenants", None)
        if tenants and len(tenants) != self.B:
            raise ValueError(
                f"slot_tenants has {len(tenants)} entries for {self.B} "
                f"batch slots (plan/batch geometry mismatch)")
        self.plan = new_plan
        if tenants:
            self.slot_tenants = list(tenants)
        mig0 = self.sim_migration_bytes
        for s in range(self.B):
            if not self.active[s]:
                continue                       # freed on its next admit
            target = self._slot_cold_target(s, self._host_len[s])
            while self.ptable.cold_tokens(s) < target:
                if self.pool.demote_boundary(s):
                    self.sim_migration_bytes += \
                        self.page_tokens * self._row_bytes
        # tenancy may have moved without a table event — force a resample
        self._tenant_note_version = -1
        self._note_tenant_pages()
        return self.sim_migration_bytes - mig0

    def counters(self) -> dict:
        """The live counter export the online replanner profiles: the
        migration totals/series priced by the ``CostModel``, per-tenant hot-
        pool peaks, the pools' event counters, and the page-table layout
        version — the same shape ``predict_pool_counters`` predicts."""
        out = {"sim_migration_bytes": self.sim_migration_bytes,
               "step_migration_bytes": list(self.step_migration_bytes),
               "tenant_hot_peak": dict(self.tenant_hot_peak),
               "table_version": self.ptable.version if self.ptable else 0,
               "prefill_compute_tokens": self.prefill_compute_tokens,
               "prefill_skipped_tokens": self.prefill_skipped_tokens,
               # bytes of shared KV the skipped rows attend back into —
               # the StepTraffic.prefill_read term the cost model prices
               "prefill_read_bytes":
                   self.prefill_skipped_tokens * self._row_bytes,
               "step_prefill_tokens": list(self.step_prefill_tokens)}
        if self.pool is not None:
            out.update(self.pool.stats)
        return out

    def run(self):
        results = []
        while self.queue or self._jobs or any(self.active):
            if not self.step():
                break
            for i in range(self.B):
                if not self.active[i] and self.outputs[i]:
                    results.append(self.outputs[i])
                    self.outputs[i] = []
        return results


def predict_pool_counters(requests: Sequence[tuple], plan, *, slots: int,
                          max_seq: int, page_tokens: int, row_bytes: float,
                          slot_tenants=None,
                          plan_schedule: Sequence[tuple] = (),
                          prefill_chunk_tokens: int = 0,
                          dense_admit: bool = False,
                          slot_devices=None) -> dict:
    """Pure-Python replay of the pools-layout batcher's bookkeeping: given
    the request stream ``[(prompt, decode_tokens[, tenant[, prefix_key]]),
    ...]`` and a plan, predict ``sim_migration_bytes`` (total and the
    per-decode-step ``step_migration_bytes`` series a CostModel prices),
    the pool's ``page_copies`` / ``admit_page_writes`` counters, and the
    per-tenant hot-pool byte peaks
    — *exactly* (integer-for-integer) what a ``ContinuousBatcher``
    (``paged=True`` + ``use_paged_decode``) will report
    on the same deterministic stream.  This is the engine/simulator
    agreement contract: the simulator predicts, the engine counts, the two
    never drift (``tests/test_multi_tenant.py`` pins it).

    ``prompt`` is either the prompt token *count* or the prompt token
    *sequence*; requests carrying a ``prefix_key`` must pass the sequence —
    the replay mirrors the engine's donor registry (LCP against the last
    prompt registered under the key, full pages mapped onto the donor's
    physical pages, refcounted, cold twins deduping shared demotions), so
    ``admit_page_writes`` / ``xdev_migration_bytes`` count only the private
    tail and stay integer-exact for shared-prefix admits.  ``dense_admit``
    replays the one-shot dense admission path (the disaggregated engine's
    ``_admit_pool``), whose shared-page cap differs from the pool-direct
    prefill scheduler's by the final-row carve-out.

    ``slot_devices`` (defaulting to the plan's) splits the replay across
    decode shards: sharing is intra-shard only, ``device_hot_peak`` tracks
    each shard's distinct-hot-page byte peak, and ``edge_migration_bytes``
    ledgers every ``(src, dst)`` device edge — prefill->shard admit streams
    and shard->shard slot re-homings (a ``plan_schedule`` entry whose plan
    moves an active slot's owner) — integer-exactly as the engine's
    ``MeshPageTable`` counts them.

    The replay mirrors the engine's event order: per step, binding of queued
    requests to free slots (FIFO within each tenant), the prefill drain
    (page-aligned chunks in slot order, at most ``prefill_chunk_tokens``
    prompt tokens per step — 0 replays the legacy one-shot admission),
    write-page growth for every active slot, then per-slot cold-boundary
    demotions toward the plan's target; peaks are sampled after each
    admission finalize and after each step's demotions, the same points the
    engine samples.  Steps in which only prefill ran (budget exhausted
    before any slot went active) still append a ``step_migration_bytes``
    entry, exactly as ``ContinuousBatcher.step()`` does.

    ``plan_schedule`` makes the replay *segment-aware* for online
    re-planning: ``[(step, new_plan_or_delta), ...]`` means "the engine
    called ``apply_plan`` before decode step ``step``".  The replay switches
    plans at exactly that point — re-targeting active slots' cold boundaries
    and re-partitioning slot tenancy — and, like the engine's marker-based
    accounting, attributes the re-layout bytes to that step's
    ``step_migration_bytes`` entry, so the two stay integer-identical
    across a re-plan boundary (sum of the series == the total on both
    sides)."""
    pg = page_tokens
    if slot_tenants is None and plan is not None:
        slot_tenants = getattr(plan, "slot_tenants", None)
    if slot_tenants and len(slot_tenants) != slots:
        raise ValueError(f"slot_tenants has {len(slot_tenants)} entries for "
                         f"{slots} slots (plan/batch geometry mismatch)")
    if slot_devices is None and plan is not None:
        slot_devices = getattr(plan, "slot_devices", None)
    if slot_devices:
        slot_devices = list(slot_devices)
        if len(slot_devices) != slots:
            raise ValueError(f"slot_devices has {len(slot_devices)} entries "
                             f"for {slots} slots")

    def parse(r):
        p = r[0]
        if isinstance(p, (list, tuple)):
            toks = tuple(int(t) for t in p)
            plen = len(toks)
        else:
            plen, toks = int(p), None
        pk = r[3] if len(r) > 3 else None
        if pk is not None and toks is None:
            raise ValueError("a prefix_key needs the prompt's token values "
                             "(pass the token sequence, not its length): "
                             "the replay LCPs them against the donor")
        return (plen, int(r[1]), r[2] if len(r) > 2 else None, toks, pk)

    queue = [parse(r) for r in requests]
    active = [False] * slots
    host_len = [0] * slots
    budget = [0] * slots
    # physical-page model, mirroring PageTable: per-slot phys ids + tiers
    # (cold-prefix), refcounts, and the cold-twin memo that dedupes shared
    # demotions — without prefix sharing it degenerates to the old counters
    ptab: list = [[] for _ in range(slots)]
    ptier: list = [[] for _ in range(slots)]
    hot_ref: dict = {}
    cold_ref: dict = {}
    cold_twin: dict = {}                   # hot phys -> its live cold twin
    twin_of: dict = {}
    donors: dict = {}                      # prefix_key -> (slot, tokens)
    next_phys = [0]
    mig = 0.0
    copies = admit_writes = 0
    peaks: dict = {}
    dev_peaks: dict = {}
    edge_bytes: dict = {}
    step_mig: list = []

    def slot_tn(s):
        return slot_tenants[s] if slot_tenants else None

    def dev(s):
        return slot_devices[s] if slot_devices else 0

    def dev_name(d):
        return f"dev{d}" if slot_devices else "decode"

    def fresh():
        next_phys[0] += 1
        return next_phys[0]

    def release(tier, phys):
        refs = cold_ref if tier else hot_ref
        refs[phys] -= 1
        if refs[phys] == 0:                # PageTable._release: memo death
            if tier == 0:
                twin = cold_twin.pop(phys, None)
                if twin is not None:
                    twin_of.pop(twin, None)
            else:
                src = twin_of.pop(phys, None)
                if src is not None:
                    cold_twin.pop(src, None)

    def free_slot(s):
        for t, p in zip(ptier[s], ptab[s]):
            release(t, p)
        ptab[s], ptier[s] = [], []

    def alloc(s):
        p = fresh()
        hot_ref[p] = 1
        ptab[s].append(p)
        ptier[s].append(0)

    def share(s, donor_slot, n):
        for i in range(n):
            p, t = ptab[donor_slot][i], ptier[donor_slot][i]
            (cold_ref if t else hot_ref)[p] += 1
            ptab[s].append(p)
            ptier[s].append(t)

    def cold_pages(s):
        c = 0
        for t in ptier[s]:
            if t != 1:
                break
            c += 1
        return c

    def note():
        per_t: dict = {}
        per_d: dict = {}
        for s in range(slots):
            hot = {p for p, t in zip(ptab[s], ptier[s]) if t == 0}
            per_d.setdefault(dev(s), set()).update(hot)
            tn = slot_tn(s)
            if tn is not None:
                per_t.setdefault(tn, set()).update(hot)
        for tn, pages in per_t.items():
            v = len(pages) * pg * row_bytes
            if v > peaks.get(tn, 0):
                peaks[tn] = v
        for d, pages in per_d.items():
            v = len(pages) * pg * row_bytes
            if v > dev_peaks.get(dev_name(d), 0):
                dev_peaks[dev_name(d)] = v

    def demote_one(s):
        # PageTable.demote: first sharer copies and memoizes a cold twin,
        # later sharers reuse it — shared bytes migrate exactly once
        nonlocal mig, copies
        idx = cold_pages(s)
        src = ptab[s][idx]
        twin = cold_twin.get(src)
        if twin is not None and cold_ref.get(twin, 0) > 0:
            cold_ref[twin] += 1
            cold_phys, copied = twin, False
        else:
            cold_phys = fresh()
            cold_ref[cold_phys] = 1
            copied = True
            if hot_ref[src] > 1:           # others still share: memoize
                cold_twin[src] = cold_phys
                twin_of[cold_phys] = src
        release(0, src)
        ptab[s][idx] = cold_phys
        ptier[s][idx] = 1
        if copied:
            mig += pg * row_bytes
            copies += 1

    def demote_to(s, target):
        while cold_pages(s) * pg < target:
            demote_one(s)

    def start_slot(s, prompt_len, toks, pk):
        # _start_job / _admit_pool head: stale donor registrations for the
        # slot die with its pages, then prefix-share against the donor —
        # intra-shard only (MeshPageTable refuses cross-device aliasing)
        for key in [k for k, (ds, _) in donors.items() if ds == s]:
            del donors[key]
        free_slot(s)
        shared = 0
        if pk is not None:
            donor = donors.get(pk)
            if donor is not None and donor[0] != s and ptab[donor[0]] \
                    and dev(donor[0]) == dev(s):
                lcp = 0
                for a, b in zip(toks, donor[1]):
                    if a != b:
                        break
                    lcp += 1
                cap = lcp // pg
                if not dense_admit:        # the suffix pass computes >= 1 row
                    cap = min(cap, (prompt_len - 1) // pg)
                shared = min(cap, len(ptab[donor[0]]))
                if shared:
                    share(s, donor[0], shared)
            donors[pk] = (s, toks)
        return shared

    schedule = sorted(((int(t), p) for t, p in plan_schedule),
                      key=lambda e: e[0])
    # slot -> [done, prompt, decode, started, tokens, prefix_key]
    jobs: dict = {}
    while queue or jobs or any(active):
        mig0 = mig
        while schedule and schedule[0][0] <= len(step_mig):
            _, nxt = schedule.pop(0)       # ContinuousBatcher.apply_plan
            if hasattr(nxt, "changes"):    # a PlanDelta, not a plan
                nxt = plan.apply_delta(nxt)
            plan = nxt
            tenants = getattr(plan, "slot_tenants", None)
            if tenants:
                if len(tenants) != slots:
                    raise ValueError(
                        f"slot_tenants has {len(tenants)} entries for "
                        f"{slots} slots (plan/batch geometry mismatch)")
                slot_tenants = list(tenants)
            for s in range(slots):
                if active[s]:
                    demote_to(s, plan.cold_len_slot(s, host_len[s], pg))
            new_sd = getattr(plan, "slot_devices", None)
            if new_sd and slot_devices and list(new_sd) != slot_devices:
                # slot re-homing: the demoted-first hot tail crosses the
                # shard<->shard edge (MeshPageTable.migrate_slot; cold pages
                # move host-internally and never touch a device edge)
                if len(new_sd) != slots:
                    raise ValueError(
                        f"slot_devices has {len(new_sd)} entries for "
                        f"{slots} slots")
                for s in range(slots):
                    if new_sd[s] == slot_devices[s]:
                        continue
                    if active[s]:
                        hot = sum(1 for t in ptier[s] if t == 0)
                        key = (dev_name(slot_devices[s]),
                               dev_name(new_sd[s]))
                        edge_bytes[key] = edge_bytes.get(key, 0.0) \
                            + hot * pg * row_bytes
                        # migrate_slot lands *exclusive* pages on the
                        # destination and releases the source refs (any
                        # remaining sharers keep the source pages, twin
                        # memos die with the refs)
                        moved = []
                        for t_, p in zip(ptier[s], ptab[s]):
                            release(t_, p)
                            p2 = fresh()
                            (cold_ref if t_ else hot_ref)[p2] = 1
                            moved.append(p2)
                        ptab[s] = moved
                    elif s not in jobs and ptab[s]:
                        # a finished slot's stale pages are dropped on
                        # ownership change, not copied across the edge
                        free_slot(s)
                slot_devices = list(new_sd)
            note()
        for s in range(slots):             # ContinuousBatcher._admit: bind
            if active[s] or s in jobs or not queue:
                continue
            tn_s = slot_tn(s)
            qi = next((i for i, q in enumerate(queue)
                       if tn_s is None or q[2] is None or q[2] == tn_s),
                      None)
            if qi is None:
                continue
            p, d, _, toks, pk = queue.pop(qi)
            jobs[s] = [0, p, d, False, toks, pk]   # queued -> prefilling(0)
        spent = 0                          # _drain_prefill: slot order,
        stop = False                       # page-aligned chunks, one budget
        for s in sorted(jobs):
            if stop:
                break
            job = jobs[s]
            while s in jobs:
                if prefill_chunk_tokens and spent >= prefill_chunk_tokens:
                    stop = True            # resume next step, all slots
                    break
                if not job[3]:             # _start_job: free + prefix share
                    job[0] = start_slot(s, job[1], job[4], job[5]) * pg
                    job[3] = True
                done, p = job[0], job[1]
                pages_left = -(-(p - done) // pg)
                take = pages_left if not prefill_chunk_tokens else \
                    min(pages_left,
                        max(1, (prefill_chunk_tokens - spent) // pg))
                end = min(p, done + take * pg)
                spent += end - done
                new = -(-end // pg) - len(ptab[s])
                if new:
                    admit_writes += new
                    key = ("prefill", dev_name(dev(s)))
                    edge_bytes[key] = edge_bytes.get(key, 0.0) \
                        + new * pg * row_bytes
                    for _ in range(new):
                        alloc(s)
                job[0] = end
                if end >= p:               # _finish_job -> active
                    del jobs[s]
                    demote_to(s, plan.cold_len_slot(s, p, pg))
                    host_len[s], active[s], budget[s] = p, True, job[2] - 1
                    note()
        if not any(active):
            if jobs:
                step_mig.append(mig - mig0)  # prefill-only step
                continue
            break
        for s in range(slots):             # pool.ensure_write_page
            if active[s] and len(ptab[s]) * pg < host_len[s] + 1:
                alloc(s)
        for s in range(slots):             # post-forward boundary advance
            if active[s]:
                demote_to(s, plan.cold_len_slot(s, host_len[s] + 1, pg))
        note()
        for s in range(slots):
            if active[s]:
                host_len[s] += 1
                budget[s] -= 1
                if budget[s] <= 0:
                    active[s] = False
        step_mig.append(mig - mig0)        # one engine decode step's delta
    # xdev_migration_bytes: the planner's predicted device<->device edge
    # traffic under prefill/decode disaggregation — every *private* admitted
    # page is prefilled on the prefill group and crosses the edge exactly
    # once; shared-prefix pages stay put on the decode side and never cross
    # (serve/disagg.py's MeshPageTable ledger matches it integer-exactly)
    return {"migration_bytes": mig, "page_copies": copies,
            "admit_page_writes": admit_writes, "tenant_hot_peak": peaks,
            "step_migration_bytes": step_mig,
            "device_hot_peak": dev_peaks,
            "edge_migration_bytes": edge_bytes,
            "xdev_migration_bytes": admit_writes * pg * row_bytes}


def serve_trace_for(cfg, requests: Sequence[tuple], *, slots: int,
                    params=None, block_tokens: int = 16,
                    recent_window: int = 32, history_period: int = 4,
                    dtype_bytes: int = 2, layer_group: int = 1,
                    shared_prefix_tokens: int = 0):
    """Build the serving-phase trace (hmsim.ServeTrace) for this model and
    request stream — the profiling step of the decode-phase planner.  KV
    bytes/token come from the cache geometry; weight bytes and flops/token
    from the parameter count (2N MACs/token) when ``params`` is given, else
    from the config's dense-layer dimensions.  ``layer_group`` coarsens the
    object granularity to one KV block per *group* of layers (same total
    bytes, fewer objects) — the simulator cost scales with object count while
    the byte geometry is what decides placement quality.

    Requests may be ``(prompt, decode)`` or ``(prompt, decode, prefix_id)``;
    with ``shared_prefix_tokens > 0``, requests carrying the same prefix_id
    share the KV blocks of their first ``shared_prefix_tokens`` prompt
    tokens (tagged via ``KVObject.shared_key`` — the trace-level mirror of
    the engine's physical page sharing)."""
    from repro.core import hmsim
    kv_tok = kvcache.kv_token_bytes(cfg, dtype_bytes)
    layers = max(1, -(-cfg.num_layers // max(1, layer_group)))
    if params is not None:
        n_params = sum(int(a.size) for a in jax.tree.leaves(params))
    else:
        n_params = (12 * cfg.num_layers * cfg.d_model ** 2
                    + cfg.vocab_size * cfg.d_model)
    return hmsim.build_serve_trace(
        requests, num_slots=slots, num_layers=layers,
        kv_token_bytes=kv_tok * cfg.num_layers / layers,
        block_tokens=block_tokens,
        recent_window=recent_window, history_period=history_period,
        flops_per_token=2.0 * n_params,
        weight_bytes=float(n_params) * dtype_bytes,
        shared_prefix_tokens=shared_prefix_tokens)


def generate(params, cfg, prompts, num_tokens: int,
             scfg: Optional[ServeConfig] = None, key=None):
    """prompts: {"tokens": (B, S)[, "prefix_embed"]}. Returns (B, num_tokens)."""
    scfg = scfg or ServeConfig()
    key = key if key is not None else jax.random.PRNGKey(0)
    B = prompts["tokens"].shape[0]
    S = prompts["tokens"].shape[1] + (cfg.num_prefix_tokens
                                      if "prefix_embed" in prompts else 0)
    max_seq = max(scfg.max_seq, S + num_tokens)

    last_logits, caches = model.prefill(params, cfg, prompts, max_seq=max_seq)
    step_fn = make_serve_step(cfg)

    outs = []
    if cfg.num_codebooks:
        last_logits = last_logits.reshape(B, cfg.num_codebooks, -1)
    tok = sample(last_logits[..., :cfg.vocab_size], key, scfg.temperature)
    for i in range(num_tokens):
        outs.append(tok)
        feed = tok[:, None] if not cfg.num_codebooks else tok[:, None, :]
        logits, caches = step_fn(params, feed.astype(jnp.int32), caches,
                                 jnp.asarray(S + i, jnp.int32))
        key, sub = jax.random.split(key)
        if cfg.num_codebooks:
            logits = logits.reshape(B, cfg.num_codebooks, -1)
        tok = sample(logits[..., :cfg.vocab_size], sub, scfg.temperature)
    return jnp.stack(outs, axis=1)
