# Workload tracers (profiler for training, hmsim's trace model for serving),
# hardware specs, and deprecation shims for the pre-unification surfaces.
# The system itself — tier/object model, policy registry, planner — lives in
# repro.runtime (see docs/RUNTIME_API.md).
import warnings


def warn_deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    """Shared DeprecationWarning for the legacy core.* entry points.
    Default stacklevel 3: helper -> shim -> caller; add one per extra
    indirection frame."""
    warnings.warn(f"{old} is deprecated; use {new} "
                  "(see docs/RUNTIME_API.md)", DeprecationWarning,
                  stacklevel=stacklevel)
