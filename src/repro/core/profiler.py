"""Sentinel's data-object profiler, reimagined for XLA.

The paper profiles one training step by PTE-poisoning every page and forcing
one data object per page. Under JAX the dataflow graph *is* the ground truth:
walking the traced jaxpr of one train step yields every data object (tensor),
its exact size, its defining and last-consuming layer, and its access count —
zero runtime overhead and exact by construction (the workload repeatability the
paper leverages holds exactly: every step replays the same HLO).

Layers are attributed through ``jax.named_scope("period_i")`` (the model's
``unroll_periods=True`` profiling mode); backward-pass equations inherit the
scope under ``transpose(...)`` in the name stack, so one traced ``grad(loss)``
covers the full forward+backward timeline: forward period i -> step i,
backward period i -> step (2P - 1 - i), P = num_periods.

Call-like equations (inner scans, remat, pjit) are tracked as opaque objects at
the boundary (their outputs are the data objects Sentinel can migrate) while
their FLOPs/bytes recurse with step attribution — inner temporaries are
short-lived by construction and belong to the reserved-pool accounting.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PAGE = 4096

# elementwise / layout primitives XLA fuses into consumers when single-use
_FUSIBLE = frozenset({
    "add", "sub", "mul", "div", "neg", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "pow", "integer_pow", "max", "min", "abs", "sign",
    "convert_element_type", "select_n", "broadcast_in_dim", "reshape",
    "transpose", "squeeze", "expand_dims", "slice", "concatenate", "pad",
    "stop_gradient", "custom_jvp_call", "erf", "floor", "ceil", "round",
    "is_finite", "and", "or", "not", "xor", "eq", "ne", "lt", "le", "gt",
    "ge", "rem", "clamp", "real", "imag", "iota", "copy",
})


@dataclass
class DataObject:
    uid: int
    size: int                 # bytes
    birth: int                # layer-step index (-1 = pre-model / boundary)
    death: int                # last read step
    reads: int                # number of consuming equations
    kind: str                 # "weight" | "activation"
    shape: Tuple[int, ...] = ()
    dtype: str = ""
    accesses: List[int] = field(default_factory=list)  # distinct steps touched
    prim: str = ""            # producing primitive

    # XLA fuses single-consumer elementwise chains into their consumer: those
    # values never hit main memory. The memory-relevant object set excludes
    # them (mirrors the paper's "data object" = an actual allocation).
    @property
    def fused(self) -> bool:
        return self.prim in _FUSIBLE and self.reads <= 1

    @property
    def lifetime(self) -> int:
        return max(0, self.death - self.birth)

    @property
    def small(self) -> bool:
        return self.size < PAGE


@dataclass
class LayerStats:
    step: int
    flops: float = 0.0
    bytes_accessed: float = 0.0
    produced_long: float = 0.0   # bytes of long-lived objects born here
    produced_short: float = 0.0
    reads_long: float = 0.0      # bytes of long-lived objects last-read here


@dataclass
class TraceProfile:
    num_periods: int
    num_steps: int               # 2 * num_periods (fwd + bwd timeline)
    objects: List[DataObject] = field(default_factory=list)
    layers: Dict[int, LayerStats] = field(default_factory=dict)
    total_flops: float = 0.0

    # ---------------- aggregate views used by planner / benchmarks ----------
    def short_lived(self, max_span: int = 1, include_fused: bool = False) -> List[DataObject]:
        return [o for o in self.objects if o.kind == "activation"
                and o.lifetime <= max_span and (include_fused or not o.fused)]

    def long_lived(self, min_span: int = 2) -> List[DataObject]:
        return [o for o in self.objects
                if o.kind == "activation" and o.lifetime >= min_span
                and not o.fused]

    def weights(self) -> List[DataObject]:
        return [o for o in self.objects if o.kind == "weight"]

    def peak_bytes(self) -> float:
        """Peak concurrently-live bytes over the step timeline."""
        deltas = defaultdict(float)
        for o in self.objects:
            if o.kind == "activation" and o.fused:
                continue
            deltas[o.birth] += o.size
            deltas[o.death + 1] -= o.size
        peak = cur = 0.0
        for s in sorted(deltas):
            cur += deltas[s]
            peak = max(peak, cur)
        return peak

    def rs_bytes(self, mi: int) -> float:
        """RS(MI): the reserved fast-memory pool of paper §4.3 — peak
        *concurrently alive* short-lived bytes within any MI-step interval.
        The pool is reused as objects free (paper: "the space is dynamically
        shrunk ... when a page in the space is freed"), so RS is nearly
        MI-independent — matching the paper's observation that RS is stable.
        """
        alive = defaultdict(float)
        for o in self.short_lived():
            for s in range(o.birth, o.death + 1):
                alive[s] += o.size
        if not alive:
            return 0.0
        # max over intervals of (max alive within the interval) == global max
        return max(alive.values())

    def step_flops(self, s: int) -> float:
        ls = self.layers.get(s)
        return ls.flops if ls else 0.0

    def step_bytes(self, s: int) -> float:
        ls = self.layers.get(s)
        return ls.bytes_accessed if ls else 0.0


_PERIOD_RE = re.compile(r"period_(\d+)")

# Timeline layout (P = num_periods):
#   0            embed / input boundary (forward)
#   1 .. P       forward periods
#   P+1          head + loss (fwd & bwd — same point in time)
#   P+2 .. 2P+1  backward periods (period p -> 2P+1-p)
#   2P+2         embedding gradient + optimizer update


def timeline_steps(num_periods: int) -> int:
    return 2 * num_periods + 3


def _layer_of(name_stack: str, num_periods: int) -> Optional[int]:
    P = num_periods
    if "boundary_head" in name_stack:
        return P + 1
    if "boundary_in" in name_stack:
        return 2 * P + 2 if "transpose" in name_stack else 0
    if "boundary_opt" in name_stack:
        return 2 * P + 2
    m = _PERIOD_RE.search(name_stack)
    if not m:
        return None
    p = int(m.group(1))
    if "transpose" in name_stack:          # backward of period p
        return 2 * P + 1 - p
    return p + 1


def _dot_flops(eqn) -> float:
    (lc, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = float(np.prod([lhs.shape[i] for i in lc])) if lc else 1.0
    return 2.0 * float(out.size) * k


def _sub_jaxprs(eqn):
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for item in vals:
            if hasattr(item, "eqns"):
                subs.append(item)
            elif hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                subs.append(item.jaxpr)
    return subs


def _var_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    return int(aval.size) * aval.dtype.itemsize


def trace_profile(fn: Callable, *args, num_periods: int, **kwargs) -> TraceProfile:
    """Trace ``fn(*args)`` (typically grad(loss) or a train step) and build the
    data-object profile. Args may be ShapeDtypeStructs (no allocation)."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args)
    P = num_periods
    prof = TraceProfile(num_periods=P, num_steps=timeline_steps(P))
    objects: Dict[Any, DataObject] = {}
    uid = [0]

    def birth(var, step, kind, prim=""):
        if not hasattr(var, "count"):   # Literal constants aren't data objects
            return
        b = _var_bytes(var)
        if b == 0:
            return
        objects[var] = DataObject(uid[0], b, step, step, 0, kind,
                                  tuple(var.aval.shape), str(var.aval.dtype),
                                  [] if kind == "weight" else [step], prim)
        uid[0] += 1

    def read(var, step):
        if not hasattr(var, "count"):
            return
        o = objects.get(var)
        if o is not None:
            o.reads += 1
            o.death = max(o.death, step)
            if not o.accesses or o.accesses[-1] != step:
                o.accesses.append(step)

    def stats(step):
        return prof.layers.setdefault(step, LayerStats(step))

    def recurse_stats(eqns, default_step):
        """FLOPs/bytes attribution inside call-like eqns (no object tracking)."""
        for eqn in eqns:
            step = _layer_of(str(eqn.source_info.name_stack), P)
            step = default_step if step is None else step
            subs = _sub_jaxprs(eqn)
            if subs:
                for s in subs:
                    recurse_stats(s.eqns, step)
                continue
            ls = stats(step)
            f = _dot_flops(eqn) if eqn.primitive.name == "dot_general" else \
                float(sum(_var_bytes(v) for v in eqn.outvars)) / max(
                    1, eqn.outvars[0].aval.dtype.itemsize
                    if hasattr(eqn.outvars[0], "aval") else 1)
            ls.flops += f
            prof.total_flops += f
            ls.bytes_accessed += sum(_var_bytes(v)
                                     for v in list(eqn.invars) + list(eqn.outvars))

    for var in jaxpr.jaxpr.invars:
        birth(var, 0, "weight")

    last_step = 0  # unscoped eqns inherit the most recent scoped step
    for eqn in jaxpr.jaxpr.eqns:
        step = _layer_of(str(eqn.source_info.name_stack), P)
        step = last_step if step is None else step
        last_step = step
        for v in eqn.invars:
            read(v, step)
        for v in eqn.outvars:
            birth(v, step, "activation", eqn.primitive.name)
        subs = _sub_jaxprs(eqn)
        if subs:
            for s in subs:
                recurse_stats(s.eqns, step)
        else:
            ls = stats(step)
            f = _dot_flops(eqn) if eqn.primitive.name == "dot_general" else \
                float(sum(int(v.aval.size) for v in eqn.outvars
                          if hasattr(v, "aval") and hasattr(v.aval, "shape")))
            ls.flops += f
            prof.total_flops += f
            ls.bytes_accessed += sum(_var_bytes(v)
                                     for v in list(eqn.invars) + list(eqn.outvars))

    # outputs of the jaxpr are read at the end of the timeline
    for v in jaxpr.jaxpr.outvars:
        read(v, timeline_steps(P) - 1)

    prof.objects = list(objects.values())

    # per-layer long/short production aggregates
    for o in prof.objects:
        if o.kind != "activation":
            continue
        ls = stats(max(o.birth, 0))
        if o.lifetime <= 1:
            ls.produced_short += o.size
        else:
            ls.produced_long += o.size
            stats(max(o.death, 0)).reads_long += o.size
    return prof
