"""Page/pool model: object->page packing and the short-lived reserved pool.

XLA buffers are object-granular, so page-level false sharing (paper Obs. 3)
does not exist at runtime on TPU. This module *models* the paper's three
allocation regimes over a profiled trace so the page-grain baselines (IAL/LRU)
and the Fig. 11 ablations are reproducible:

  - "original":  bump allocation in birth order; small objects share pages
                 (false sharing present — pages mix hot and cold objects).
  - "profiled":  one object per page (the paper's profiling-phase layout;
                 inflates footprint, Table 1/5).
  - "sentinel":  objects grouped by their (birth, death) access signature —
                 the paper's bit-string grouping — sorted by access count and
                 packed, eliminating false sharing.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.profiler import PAGE, DataObject, TraceProfile


@dataclass
class Page:
    pid: int
    objects: List[DataObject] = field(default_factory=list)
    used: int = 0

    @property
    def accesses(self):
        steps = set()
        for o in self.objects:
            steps.update(o.accesses)
        return sorted(steps)

    @property
    def birth(self) -> int:
        return min(o.birth for o in self.objects)

    @property
    def death(self) -> int:
        return max(o.death for o in self.objects)

    @property
    def bytes(self) -> int:
        return PAGE

    @property
    def long_lived(self) -> bool:
        return any(o.death - o.birth >= 2 for o in self.objects)


def pack_pages(objects: List[DataObject], mode: str) -> Tuple[List[Page], Dict[int, Page]]:
    """Returns (pages, obj_uid -> page). Large objects get exclusive pages."""
    pages: List[Page] = []
    omap: Dict[int, Page] = {}

    def new_page() -> Page:
        p = Page(len(pages))
        pages.append(p)
        return p

    def place_exclusive(o: DataObject):
        n = (o.size + PAGE - 1) // PAGE
        p = new_page()
        p.objects.append(o)
        p.used = o.size
        omap[o.uid] = p
        for _ in range(n - 1):  # tail pages of a multi-page object
            q = new_page()
            q.objects.append(o)
            q.used = PAGE
        return p

    if mode == "profiled":
        for o in objects:
            place_exclusive(o)
        return pages, omap

    if mode == "original":
        cur = None
        for o in sorted(objects, key=lambda o: (o.birth, o.uid)):
            if o.size >= PAGE:
                place_exclusive(o)
                continue
            if cur is None or cur.used + o.size > PAGE:
                cur = new_page()
            cur.objects.append(o)
            cur.used += o.size
            omap[o.uid] = cur
        return pages, omap

    if mode == "sentinel":
        groups = defaultdict(list)
        for o in objects:
            if o.size >= PAGE:
                place_exclusive(o)
            else:
                groups[(o.birth, o.death)].append(o)
        for _, objs in sorted(groups.items()):
            objs.sort(key=lambda o: o.reads)   # paper: increasing access count
            cur = None
            for o in objs:
                if cur is None or cur.used + o.size > PAGE:
                    cur = new_page()
                cur.objects.append(o)
                cur.used += o.size
                omap[o.uid] = cur
        return pages, omap

    raise ValueError(mode)


def footprint(pages: List[Page]) -> int:
    return len(pages) * PAGE


def profiling_overhead(profile: TraceProfile) -> dict:
    """Table 1 / Table 5 reproduction: footprint growth of one-object-per-page
    during the profiling step, and of small objects specifically."""
    objs = [o for o in profile.objects if o.kind == "activation"]
    small = [o for o in objs if o.small]
    orig_pages, _ = pack_pages(objs, "original")
    prof_pages, _ = pack_pages(objs, "profiled")
    return {
        "orig_bytes": footprint(orig_pages),
        "profiled_bytes": footprint(prof_pages),
        "small_obj_bytes": sum(o.size for o in small),
        "small_obj_profiled_bytes": len(small) * PAGE,
        "overhead_frac": footprint(prof_pages) / max(1, footprint(orig_pages)) - 1,
    }


def false_sharing_stats(profile: TraceProfile) -> dict:
    """Obs. 3: how many pages mix short-lived and long-lived objects under
    the original allocation."""
    objs = [o for o in profile.objects if o.kind == "activation"]
    pages, _ = pack_pages(objs, "original")
    shared = [p for p in pages if len(p.objects) > 1]
    mixed = [p for p in shared
             if any(o.lifetime <= 1 for o in p.objects)
             and any(o.lifetime >= 2 for o in p.objects)]
    return {"pages": len(pages), "shared_pages": len(shared),
            "false_shared_pages": len(mixed),
            "false_sharing_frac": len(mixed) / max(1, len(pages))}
