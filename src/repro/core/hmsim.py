"""Heterogeneous-memory trace model + legacy simulator entry points.

This module owns the **serving-phase trace model** (``ServeTrace`` /
``KVObject`` / ``build_serve_trace``): prefill/decode phases over a slot-based
continuous batch, where the data objects are per-slot, per-layer KV *blocks*
with token-indexed access patterns — the inference analogue of the paper's
training-step objects.  Lifetimes are known exactly (a request's KV dies when
its slot is refilled), and the access schedule repeats every token, which is
precisely the structure Sentinel exploits.

The simulators that used to live here (``simulate_sentinel`` /
``simulate_caching`` / ``simulate_static`` / ``simulate_serve``) are now
**deprecation shims**: the implementations moved into the unified policy
registry (``repro.runtime.policies``), where each one is a registered policy
runnable on *any* workload::

    from repro import runtime
    runtime.simulate(profile_or_trace, hw, fast_bytes, "sentinel_mi", mi=2)

The shims emit ``DeprecationWarning`` and return results equal to the new
API's (``SimResult`` and ``ServeSimResult`` now alias
``runtime.PlacementResult``).  See docs/RUNTIME_API.md for the migration
guide.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import warn_deprecated
from repro.core.hardware import HWSpec
from repro.core.profiler import TraceProfile
# legacy re-exports: the unit model and result type live in the runtime now
from repro.runtime.objects import peak_object_bytes
from repro.runtime.policies import (PlacementResult, Unit,  # noqa: F401
                                    build_units)

SimResult = PlacementResult
ServeSimResult = PlacementResult


def _deprecated(old: str, new: str):
    warn_deprecated(f"core.hmsim.{old}", new, stacklevel=4)


def _step_times(profile: TraceProfile, hw: HWSpec) -> List[float]:
    """All-fast compute time per timeline step (roofline max of the two)."""
    return [max(profile.step_flops(s) / hw.peak_flops,
                profile.step_bytes(s) / hw.fast_bw)
            for s in range(profile.num_steps)]


# ----------------------------------------------------------- legacy shims ----

def simulate_sentinel(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                      mi: int, *, stall_on_case3: bool = True,
                      reserve_pool: bool = True,
                      granularity: str = "object",
                      page_mode: str = "sentinel") -> SimResult:
    """DEPRECATED: ``runtime.simulate(profile, hw, fast_bytes, 'sentinel_mi',
    mi=..., test_and_trial=False)``."""
    _deprecated("simulate_sentinel",
                "runtime.simulate(..., 'sentinel_mi', mi=...)")
    from repro import runtime
    return runtime.simulate(profile, hw, fast_bytes, "sentinel_mi", mi=mi,
                            test_and_trial=False,
                            stall_on_case3=stall_on_case3,
                            reserve_pool=reserve_pool,
                            granularity=granularity, page_mode=page_mode)


def simulate_sentinel_tt(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                         mi: int, **kw) -> SimResult:
    """DEPRECATED: test-and-trial (§4.4) is the ``sentinel_mi`` policy's
    default; use ``runtime.simulate(..., 'sentinel_mi', mi=...)``."""
    _deprecated("simulate_sentinel_tt",
                "runtime.simulate(..., 'sentinel_mi', mi=...)")
    from repro import runtime
    return runtime.simulate(profile, hw, fast_bytes, "sentinel_mi", mi=mi,
                            test_and_trial=True, **kw)


def simulate_caching(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                     policy: str = "ial", *, page_mode: str = "original",
                     repeats: int = 3, opts_per_step: int = 4) -> SimResult:
    """DEPRECATED: the page-grain daemons are the registered ``ial`` / ``lru``
    policies; use ``runtime.simulate(profile, hw, fast_bytes, 'ial')``."""
    _deprecated("simulate_caching", f"runtime.simulate(..., {policy!r})")
    from repro import runtime
    return runtime.simulate(profile, hw, fast_bytes, policy,
                            page_mode=page_mode, repeats=repeats,
                            opts_per_step=opts_per_step)


def simulate_static(profile: TraceProfile, hw: HWSpec,
                    where: str = "fast") -> SimResult:
    """DEPRECATED: static placement bounds are the registered ``all_fast`` /
    ``all_slow`` policies."""
    _deprecated("simulate_static", f"runtime.simulate(..., 'all_{where}')")
    from repro import runtime
    return runtime.simulate(profile, hw, 0.0, f"all_{where}")


# ===================================================================== serve ==
# Serving-phase trace model: prefill/decode phases over a slot-based continuous
# batch.  The data objects are per-slot, per-layer KV *blocks* with
# token-indexed access patterns — the inference analogue of the paper's
# training-step objects.
#
# Access model per decode step: a slot reads all blocks inside its recent
# attention window every token; older history blocks are re-read every
# ``history_period`` tokens (sparse/strided history attention — the
# "token skipping" structure of the Data_Placement_Optimization traces).
# Every KV object's access list is therefore monotone in token index.


@dataclass
class KVObject:
    """One per-slot, per-layer KV block (``block_tokens`` tokens of K+V).

    ``shared_key`` tags blocks that are the *same physical data* across
    requests (a common prompt prefix — one system prompt serving N tenants):
    blocks with equal keys occupy the same physical pages at runtime, so
    sharing-aware policies and the capacity/migration accounting count their
    bytes exactly once (the trace-level mirror of kvcache.PageTable
    refcounts)."""
    uid: int
    slot: int
    req: int
    layer: int
    block: int                 # block index within the request's token stream
    bytes: int
    birth: int                 # global decode step when first written
    death: int                 # last decode step of the owning request
    token_start: int           # token range covered, [start, end)
    token_end: int
    prefill: bool              # born during prefill (vs appended during decode)
    accesses: List[int] = field(default_factory=list)  # sorted decode steps
    shared_key: Optional[tuple] = None   # (prefix_id, layer, block) or None
    tenant: Optional[str] = None         # owning tenant id (multi-tenant runs)


@dataclass
class ServeTrace:
    """A fully resolved serving timeline for one continuous-batching run."""
    num_slots: int
    num_layers: int
    block_tokens: int
    recent_window: int
    history_period: int
    kv_token_bytes: float      # KV bytes per token per layer
    weight_bytes: float        # weight bytes streamed per decode step
    flops_per_token: float
    num_steps: int = 0
    objects: List[KVObject] = field(default_factory=list)
    admits: Dict[int, List[KVObject]] = field(default_factory=dict)
    births: Dict[int, List[KVObject]] = field(default_factory=dict)
    frees: Dict[int, List[KVObject]] = field(default_factory=dict)
    reads: Dict[int, List[KVObject]] = field(default_factory=dict)
    active: Dict[int, int] = field(default_factory=dict)
    prefill_tokens: Dict[int, int] = field(default_factory=dict)
    # prompt tokens per admit step the cache-aware engine does NOT compute:
    # full blocks of a shared prefix whose KV a donor already materialized
    # (engine._start_job's compute skip — the suffix pass attends back into
    # the shared pages instead of recomputing them)
    prefill_skip_tokens: Dict[int, int] = field(default_factory=dict)

    def rs_bytes(self) -> float:
        """Serving reserve pool (paper §4.3 restated per-token): the open,
        still-filling KV blocks every active slot writes into must stay fast."""
        return (self.num_slots * self.num_layers * self.block_tokens
                * self.kv_token_bytes)

    def write_bytes(self, t: int) -> float:
        """New KV appended at step t (one token per layer per active slot)."""
        return self.active.get(t, 0) * self.num_layers * self.kv_token_bytes

    def peak_kv_bytes(self) -> float:
        """Peak concurrently-live KV bytes — sharing-aware: blocks with the
        same ``shared_key`` are one physical allocation, so a shared group
        contributes its bytes once over the union of its members' lifetimes
        (exactly when at least one reference holds the pages live)."""
        return peak_object_bytes(self.objects)


def synthetic_requests(n: int, prompt_tokens: int = 96, decode_tokens: int = 48,
                       jitter: int = 3) -> List[tuple]:
    """Deterministic mixed request stream (no RNG: repeatability is the point)."""
    out = []
    for i in range(n):
        p = prompt_tokens + (i * 17) % (jitter * 16 + 1)
        d = decode_tokens + (i * 11) % (jitter * 8 + 1)
        out.append((p, d))
    return out


def build_serve_trace(requests: Sequence[tuple], num_slots: int,
                      num_layers: int, kv_token_bytes: float, *,
                      block_tokens: int = 16, recent_window: int = 32,
                      history_period: int = 4, flops_per_token: float = 1e9,
                      weight_bytes: float = 0.0,
                      shared_prefix_tokens: int = 0) -> ServeTrace:
    """Resolve a request stream ``[(prompt_tokens, decode_tokens), ...]`` into
    a slot-scheduled decode timeline with per-block KV objects.

    Requests may carry a third element ``prefix_id``: with
    ``shared_prefix_tokens > 0``, prefill blocks lying fully inside the
    first ``shared_prefix_tokens`` prompt tokens of same-``prefix_id``
    requests get equal ``shared_key`` tags — they are one physical
    allocation at runtime (engine page sharing), and the sharing-aware
    accounting counts them once."""
    tr = ServeTrace(num_slots, num_layers, block_tokens, recent_window,
                    history_period, float(kv_token_bytes), float(weight_bytes),
                    float(flops_per_token))
    slot_free = [0] * num_slots
    seen_prefix: set = set()
    uid = 0
    for req, r in enumerate(requests):
        p, d = r[0], r[1]
        prefix_id = r[2] if len(r) > 2 else None
        slot = min(range(num_slots), key=lambda s: slot_free[s])
        a = slot_free[slot]                 # admit step (slot refill)
        end = a + d - 1                     # last decode step
        slot_free[slot] = a + d
        tr.prefill_tokens[a] = tr.prefill_tokens.get(a, 0) + p
        if prefix_id is not None and shared_prefix_tokens > 0:
            if prefix_id in seen_prefix:
                # cache-aware prefill skips full shared blocks a donor
                # already materialized; capped below the last prompt token
                # (at least one suffix row is always computed), mirroring
                # engine._start_job's shared-page cap
                skip = (min(shared_prefix_tokens, p - 1)
                        // block_tokens) * block_tokens
                if skip > 0:
                    tr.prefill_skip_tokens[a] = \
                        tr.prefill_skip_tokens.get(a, 0) + skip
            seen_prefix.add(prefix_id)
        for t in range(a, end + 1):
            tr.active[t] = tr.active.get(t, 0) + 1

        def make_obj(layer, blk, ts, te, birth, is_prefill):
            nonlocal uid
            shared = None
            if prefix_id is not None and is_prefill and \
                    te <= shared_prefix_tokens:
                shared = (prefix_id, layer, blk)    # same physical pages
            o = KVObject(uid, slot, req, layer, blk,
                         int((te - ts) * kv_token_bytes), birth, end,
                         ts, te, is_prefill, shared_key=shared)
            uid += 1
            for t in range(birth, end + 1):
                tokens_now = p + (t - a) + 1
                recent = tokens_now - te < recent_window
                if recent or (t - birth) % history_period == 0:
                    o.accesses.append(t)
                    tr.reads.setdefault(t, []).append(o)
            tr.objects.append(o)
            (tr.admits if is_prefill else tr.births).setdefault(
                birth, []).append(o)
            tr.frees.setdefault(end + 1, []).append(o)

        n_pre = (p + block_tokens - 1) // block_tokens
        for layer in range(num_layers):
            for b in range(n_pre):
                make_obj(layer, b, b * block_tokens,
                         min((b + 1) * block_tokens, p), a, True)
            n_dec = (d + block_tokens - 1) // block_tokens
            for b in range(n_dec):
                ts = p + b * block_tokens
                make_obj(layer, n_pre + b, ts,
                         min(ts + block_tokens, p + d), a + b * block_tokens,
                         False)
    tr.num_steps = max(slot_free)
    return tr


def simulate_serve(trace: ServeTrace, hw: HWSpec, fast_bytes: float,
                   policy: str = "sentinel", **knobs) -> ServeSimResult:
    """DEPRECATED: ``runtime.simulate(trace, hw, fast_bytes, policy,
    **knobs)`` — same event loop, now shared with the training workloads."""
    _deprecated("simulate_serve", "runtime.simulate(trace, ...)")
    from repro import runtime
    return runtime.simulate(trace, hw, fast_bytes, policy, **knobs)
