"""Heterogeneous-memory simulator: replays a profiled trace under a placement
policy and a hardware spec, producing step time, migration counts and the
paper's Case 1/2/3 accounting.

This is the evaluation engine for the paper's figures (7, 8, 10, 11, 12 and
Tables 4/5): on CPU-only hardware we cannot run a real two-tier memory, so —
exactly like the paper's own analysis — performance comes from a bandwidth/
compute cost model:

    t(step) = max(flops/peak,  bytes_fast/fast_bw + bytes_slow/slow_bw)
              + stalls (demand fetches, Case-3 waits)

Migration bandwidth is a separate full-duplex channel (the paper's two
migration threads), drained concurrently with compute.

Units are data *objects* for Sentinel (object-granular, the paper's point) and
*pages* for the page-grain baselines (IAL from Yan et al. ASPLOS'19, LRU).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.allocator import pack_pages
from repro.core.hardware import HWSpec
from repro.core.profiler import TraceProfile


@dataclass
class Unit:
    uid: int
    bytes: int
    accesses: Sequence[int]     # sorted step indices
    long_lived: bool
    short_lived_resident: bool  # lives in the reserved pool (Sentinel)


@dataclass
class SimResult:
    policy: str
    step_time: float                      # seconds for one training step
    compute_time: float                   # lower bound (all-fast)
    migrations: int = 0                   # unit migrations (both directions)
    bytes_s2f: float = 0.0
    bytes_f2s: float = 0.0
    stall_time: float = 0.0
    slow_bytes_accessed: float = 0.0
    cases: Dict[int, int] = field(default_factory=lambda: {1: 0, 2: 0, 3: 0})
    mi: int = 0
    detail: dict = field(default_factory=dict)

    @property
    def slowdown(self) -> float:
        return self.step_time / max(self.compute_time, 1e-30)

    @property
    def throughput(self) -> float:
        return 1.0 / max(self.step_time, 1e-30)


def build_units(profile: TraceProfile, granularity: str = "object",
                page_mode: str = "sentinel") -> List[Unit]:
    """granularity 'object': Sentinel's view. 'page': pack objects into pages
    (page_mode 'original' reproduces false sharing)."""
    acts = [o for o in profile.objects
            if o.kind == "activation" and o.accesses and not o.fused]
    weights = [o for o in profile.objects if o.kind == "weight" and o.accesses]
    units: List[Unit] = []
    if granularity == "object":
        for o in acts:
            units.append(Unit(o.uid, o.size, sorted(set(o.accesses)),
                              o.lifetime >= 2, o.lifetime <= 1))
        for o in weights:
            units.append(Unit(o.uid, o.size, sorted(set(o.accesses)), True, False))
    else:
        pages, _ = pack_pages(acts + weights, page_mode)
        for p in pages:
            accesses = p.accesses
            if not accesses:
                continue
            long_lived = p.death - p.birth >= 2 or \
                any(o.kind == "weight" for o in p.objects)
            units.append(Unit(100_000_000 + p.pid, p.bytes, accesses,
                              long_lived, not long_lived))
    return units


def _step_times(profile: TraceProfile, hw: HWSpec) -> List[float]:
    """All-fast compute time per timeline step (roofline max of the two)."""
    return [max(profile.step_flops(s) / hw.peak_flops,
                profile.step_bytes(s) / hw.fast_bw)
            for s in range(profile.num_steps)]


# --------------------------------------------------------------- Sentinel ----

def simulate_sentinel(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                      mi: int, *, stall_on_case3: bool = True,
                      reserve_pool: bool = True,
                      granularity: str = "object",
                      page_mode: str = "sentinel") -> SimResult:
    """Sentinel (§4.4): MI-step intervals. At the start of interval A the data
    needed by interval B is prefetched slow->fast overlapped with A's compute;
    long-lived units not needed soon are evicted fast->slow mid-interval
    (this is what frees space for the residual-offload pattern: activations
    produced in forward interval i leave fast memory until their backward
    interval). Newly produced long-lived units are always born in fast.
    """
    units = build_units(profile, granularity, page_mode)
    steps = profile.num_steps
    t_step = _step_times(profile, hw)
    res = SimResult("sentinel", 0.0, sum(t_step), mi=mi)

    access_map: Dict[int, List[Unit]] = collections.defaultdict(list)
    for u in units:
        for s in u.accesses:
            access_map[s].append(u)

    rs = profile.rs_bytes(mi) if reserve_pool else 0.0
    budget = max(0.0, fast_bytes - rs)

    movable = [u for u in units if u.long_lived]
    in_fast: Dict[int, bool] = {u.uid: False for u in movable}
    fast_used = 0.0

    def next_access_after(u: Unit, s: int) -> Optional[int]:
        for a in u.accesses:
            if a > s:
                return a
        return None

    slow_resident = {u.uid for u in movable if u.bytes > budget}
    # (paper §4.5: fast memory must at least fit RS + the largest long-lived
    # object; units violating that are pinned slow and accessed there)

    def force_evict(need: float, now: int, horizon: int) -> float:
        """Make room for `need` bytes by evicting farthest-next-access units.
        Returns bytes evicted (caller charges the eviction channel)."""
        nonlocal fast_used
        victims = [u for u in movable if in_fast.get(u.uid, False)]
        victims.sort(key=lambda u: -(next_access_after(u, now) or 10 ** 9))
        freed = 0.0
        for u in victims:
            if fast_used + need <= budget:
                break
            in_fast[u.uid] = False
            fast_used -= u.bytes
            freed += u.bytes
            res.migrations += 1
            res.bytes_f2s += u.bytes
        return freed

    # initial prefetch: units needed by interval 0, by first-use order
    first = [u for u in movable if any(a < mi for a in u.accesses)
             and u.uid not in slow_resident]
    first.sort(key=lambda u: u.accesses[0])
    for u in first:
        if fast_used + u.bytes <= budget:
            in_fast[u.uid] = True
            fast_used += u.bytes
            res.migrations += 1
            res.bytes_s2f += u.bytes

    intervals = [(i, min(i + mi, steps)) for i in range(0, steps, mi)]
    total = 0.0

    for (lo, hi) in intervals:
        nxt_lo, nxt_hi = hi, min(hi + mi, steps)
        migs_before = res.migrations

        # ---- execute interval: compute + penalties + births + evictions ----
        interval_compute = 0.0
        forced_evict_bytes = 0.0
        for s in range(lo, hi):
            bytes_slow = 0.0
            for u in access_map.get(s, ()):
                if not u.long_lived:
                    continue
                if u.uid in slow_resident:
                    bytes_slow += u.bytes
                    res.slow_bytes_accessed += u.bytes
                    continue
                if u.accesses[0] == s and not in_fast.get(u.uid, False):
                    # birth: produced into fast, forcing eviction if full
                    if fast_used + u.bytes > budget:
                        forced_evict_bytes += force_evict(u.bytes, s, nxt_hi)
                    if fast_used + u.bytes <= budget:
                        in_fast[u.uid] = True
                        fast_used += u.bytes
                    else:                        # truly no room: spills slow
                        slow_resident.add(u.uid)
                        bytes_slow += u.bytes
                        res.slow_bytes_accessed += u.bytes
                elif not in_fast.get(u.uid, False):
                    bytes_slow += u.bytes        # read from slow
                    res.slow_bytes_accessed += u.bytes
            if not reserve_pool:
                # Fig. 11 "no space reservation": short-lived units demand
                # fast space; the shortfall is slow-accessed
                short_here = sum(u.bytes for u in access_map.get(s, ())
                                 if u.short_lived_resident)
                free = fast_bytes - fast_used
                overflow = max(0.0, short_here - max(0.0, free))
                bytes_slow += overflow
                res.slow_bytes_accessed += overflow
            t_fast = max(0.0, profile.step_bytes(s) - bytes_slow)
            t = max(profile.step_flops(s) / hw.peak_flops,
                    t_fast / hw.fast_bw + bytes_slow / hw.slow_bw)
            interval_compute += t

        # ---- eviction channel accounting (fast->slow, full duplex) ----
        evict_capacity = interval_compute * hw.mig_bw - forced_evict_bytes
        if evict_capacity < 0:                    # write-back pressure stalls
            stall = -evict_capacity / hw.mig_bw
            res.stall_time += stall
            total += stall
            evict_capacity = 0.0
        # scheduled mid-interval eviction: units not needed before nxt_hi
        candidates = [u for u in movable if in_fast.get(u.uid, False)]
        candidates.sort(key=lambda u: -(next_access_after(u, hi - 1) or 10 ** 9))
        for u in candidates:
            na = next_access_after(u, hi - 1)
            if na is not None and na < nxt_hi:
                continue                          # needed soon: keep
            if u.bytes > evict_capacity:
                break
            evict_capacity -= u.bytes
            in_fast[u.uid] = False
            fast_used -= u.bytes
            res.migrations += 1
            res.bytes_f2s += u.bytes

        # ---- prefetch for the next interval (slow->fast channel) ----
        pending = [u for u in movable
                   if not in_fast[u.uid] and u.uid not in slow_resident
                   and any(nxt_lo <= a < nxt_hi for a in u.accesses)]
        pending.sort(key=lambda u: next_access_after(u, nxt_lo - 1) or nxt_lo)
        capacity = interval_compute * hw.mig_bw
        space_blocked = False
        while pending:
            u = pending[0]
            if fast_used + u.bytes > budget:
                space_blocked = True
                break
            if u.bytes > capacity:
                break
            capacity -= u.bytes
            fast_used += u.bytes
            in_fast[u.uid] = True
            res.migrations += 1
            res.bytes_s2f += u.bytes
            pending.pop(0)

        # per-migration fixed overhead (move_pages/TLB shootdown on CPU HM,
        # DMA dispatch on TPU) — exposed on the critical path
        interval_migs = res.migrations - migs_before
        total += interval_migs * hw.mig_overhead

        total += interval_compute
        if nxt_lo >= steps:
            pass                                  # no next interval: no case
        elif not pending:
            res.cases[1] += 1
        elif space_blocked:
            res.cases[2] += 1                     # leave in slow
        else:
            res.cases[3] += 1
            if stall_on_case3:
                stall = 0.0
                for u in list(pending):
                    if fast_used + u.bytes <= budget:
                        stall += u.bytes / hw.mig_bw
                        fast_used += u.bytes
                        in_fast[u.uid] = True
                        res.migrations += 1
                        res.bytes_s2f += u.bytes
                        pending.remove(u)
                res.stall_time += stall
                total += stall
            # else: leave in slow, pay access penalty next interval

    res.step_time = total
    res.detail = {"fast_budget": budget, "rs": rs}
    return res


def simulate_sentinel_tt(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                         mi: int, **kw) -> SimResult:
    """Test-and-trial (§4.4): try both Case-3 resolutions, keep the winner."""
    a = simulate_sentinel(profile, hw, fast_bytes, mi, stall_on_case3=True, **kw)
    if a.cases[3] == 0:
        a.detail["tt_choice"] = "n/a"
        return a
    b = simulate_sentinel(profile, hw, fast_bytes, mi, stall_on_case3=False, **kw)
    best = a if a.step_time <= b.step_time else b
    best.detail["tt_choice"] = "stall" if best is a else "slow-access"
    best.detail["tt_steps_used"] = 2
    return best


# ---------------------------------------------------- page-grain baselines ----

def simulate_caching(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                     policy: str = "ial", *, page_mode: str = "original",
                     repeats: int = 3, opts_per_step: int = 4) -> SimResult:
    """Page-grain reactive baselines.

    IAL (Yan et al. ASPLOS'19): two FIFO lists (active/inactive). Pages are
    *not* demand-migrated — a periodic optimization pass (the paper's
    every-5-seconds daemon; here ``opts_per_step`` passes per training step)
    promotes recently re-accessed slow pages into fast memory and demotes
    inactive-list pages when fast memory is full. Between passes, slow pages
    are accessed in slow memory — the detection *lag* is exactly the paper's
    criticism, and page-grain false sharing (page_mode='original') makes the
    promoted bytes partly useless.

    LRU: same skeleton with recency ordering.

    Training repeats an identical timeline; we simulate ``repeats`` steps and
    report the last (steady state: weights and recurring-address pages have
    been classified).
    """
    units = build_units(profile, "page", page_mode)
    steps = profile.num_steps
    t_step = _step_times(profile, hw)
    res = SimResult(policy, 0.0, sum(t_step))

    access_map: Dict[int, List[Unit]] = collections.defaultdict(list)
    for u in units:
        for s in u.accesses:
            access_map[s].append(u)

    in_fast: Dict[int, bool] = {u.uid: False for u in units}
    fast_used = 0.0
    by_uid = {u.uid: u for u in units}
    # list state: uid -> last-touch tick; FIFO order by insertion
    active: collections.OrderedDict = collections.OrderedDict()
    inactive: collections.OrderedDict = collections.OrderedDict()
    touched_since_opt: collections.OrderedDict = collections.OrderedDict()
    seen_before: set = set()

    opt_every = max(1, steps // max(1, opts_per_step))

    def optimization_pass(bw_budget: float):
        """Promote recently re-touched slow pages; demote FIFO-head pages.
        Migration volume per pass is bounded by the elapsed-time bandwidth
        product (parallel copy threads, Yan et al.)."""
        nonlocal fast_used
        moved = 0
        for uid in list(touched_since_opt):
            if bw_budget <= 0:
                break
            u = by_uid[uid]
            if in_fast[uid]:
                # fast page touched again: inactive -> active promotion
                if uid in inactive:
                    inactive.pop(uid)
                    active[uid] = True
                elif policy == "lru" and uid in active:
                    active.move_to_end(uid)
                continue
            if uid not in seen_before:
                continue  # second-touch rule: first sighting only classifies
            # slow page was re-touched: candidate for promotion
            while fast_used + u.bytes > fast_bytes and bw_budget > 0:
                src = inactive if inactive else active
                if not src:
                    break
                vid, _ = src.popitem(last=False)      # FIFO/LRU head
                v = by_uid[vid]
                if in_fast[vid]:
                    in_fast[vid] = False
                    fast_used -= v.bytes
                    res.migrations += 1
                    res.bytes_f2s += v.bytes
                    bw_budget -= v.bytes
                    moved += 1
            if fast_used + u.bytes <= fast_bytes and bw_budget > 0:
                in_fast[uid] = True
                fast_used += u.bytes
                inactive[uid] = True
                res.migrations += 1
                res.bytes_s2f += u.bytes
                bw_budget -= u.bytes
                moved += 1
        seen_before.update(touched_since_opt)
        touched_since_opt.clear()
        return moved

    last_rep_time = 0.0
    for rep in range(repeats):
        rep_time = 0.0
        since_opt = 0.0
        for s in range(steps):
            bytes_slow = 0.0
            for u in access_map.get(s, ()):
                touched_since_opt[u.uid] = True
                if not in_fast[u.uid]:
                    bytes_slow += u.bytes
                    res.slow_bytes_accessed += u.bytes
            t_fast = max(0.0, profile.step_bytes(s) - bytes_slow)
            t = max(profile.step_flops(s) / hw.peak_flops,
                    t_fast / hw.fast_bw + bytes_slow / hw.slow_bw)
            rep_time += t
            since_opt += t
            if (s + 1) % opt_every == 0:
                # daemon runs on dedicated helper threads (Yan et al. use 4
                # copy + 8 migration threads): off the critical path
                optimization_pass(since_opt * hw.mig_bw)
                since_opt = 0.0
        last_rep_time = rep_time
    res.step_time = last_rep_time
    return res


# ------------------------------------------------------------------ static ----

def simulate_static(profile: TraceProfile, hw: HWSpec,
                    where: str = "fast") -> SimResult:
    bw = hw.fast_bw if where == "fast" else hw.slow_bw
    t = sum(max(profile.step_flops(s) / hw.peak_flops,
                profile.step_bytes(s) / bw)
            for s in range(profile.num_steps))
    r = SimResult(f"all-{where}", t, sum(_step_times(profile, hw)))
    return r


# ===================================================================== serve ==
# Serving-phase trace model: prefill/decode phases over a slot-based continuous
# batch.  The data objects are per-slot, per-layer KV *blocks* with
# token-indexed access patterns — the inference analogue of the paper's
# training-step objects.  Lifetimes are known exactly (a request's KV dies when
# its slot is refilled), and the access schedule repeats every token, which is
# precisely the structure Sentinel exploits.
#
# Access model per decode step: a slot reads all blocks inside its recent
# attention window every token; older history blocks are re-read every
# ``history_period`` tokens (sparse/strided history attention — the
# "token skipping" structure of the Data_Placement_Optimization traces).
# Every KV object's access list is therefore monotone in token index.


@dataclass
class KVObject:
    """One per-slot, per-layer KV block (``block_tokens`` tokens of K+V)."""
    uid: int
    slot: int
    req: int
    layer: int
    block: int                 # block index within the request's token stream
    bytes: int
    birth: int                 # global decode step when first written
    death: int                 # last decode step of the owning request
    token_start: int           # token range covered, [start, end)
    token_end: int
    prefill: bool              # born during prefill (vs appended during decode)
    accesses: List[int] = field(default_factory=list)  # sorted decode steps


@dataclass
class ServeTrace:
    """A fully resolved serving timeline for one continuous-batching run."""
    num_slots: int
    num_layers: int
    block_tokens: int
    recent_window: int
    history_period: int
    kv_token_bytes: float      # KV bytes per token per layer
    weight_bytes: float        # weight bytes streamed per decode step
    flops_per_token: float
    num_steps: int = 0
    objects: List[KVObject] = field(default_factory=list)
    admits: Dict[int, List[KVObject]] = field(default_factory=dict)
    births: Dict[int, List[KVObject]] = field(default_factory=dict)
    frees: Dict[int, List[KVObject]] = field(default_factory=dict)
    reads: Dict[int, List[KVObject]] = field(default_factory=dict)
    active: Dict[int, int] = field(default_factory=dict)
    prefill_tokens: Dict[int, int] = field(default_factory=dict)

    def rs_bytes(self) -> float:
        """Serving reserve pool (paper §4.3 restated per-token): the open,
        still-filling KV blocks every active slot writes into must stay fast."""
        return (self.num_slots * self.num_layers * self.block_tokens
                * self.kv_token_bytes)

    def write_bytes(self, t: int) -> float:
        """New KV appended at step t (one token per layer per active slot)."""
        return self.active.get(t, 0) * self.num_layers * self.kv_token_bytes

    def peak_kv_bytes(self) -> float:
        deltas: Dict[int, float] = collections.defaultdict(float)
        for o in self.objects:
            deltas[o.birth] += o.bytes
            deltas[o.death + 1] -= o.bytes
        peak = cur = 0.0
        for t in sorted(deltas):
            cur += deltas[t]
            peak = max(peak, cur)
        return peak


def synthetic_requests(n: int, prompt_tokens: int = 96, decode_tokens: int = 48,
                       jitter: int = 3) -> List[tuple]:
    """Deterministic mixed request stream (no RNG: repeatability is the point)."""
    out = []
    for i in range(n):
        p = prompt_tokens + (i * 17) % (jitter * 16 + 1)
        d = decode_tokens + (i * 11) % (jitter * 8 + 1)
        out.append((p, d))
    return out


def build_serve_trace(requests: Sequence[tuple], num_slots: int,
                      num_layers: int, kv_token_bytes: float, *,
                      block_tokens: int = 16, recent_window: int = 32,
                      history_period: int = 4, flops_per_token: float = 1e9,
                      weight_bytes: float = 0.0) -> ServeTrace:
    """Resolve a request stream ``[(prompt_tokens, decode_tokens), ...]`` into
    a slot-scheduled decode timeline with per-block KV objects."""
    tr = ServeTrace(num_slots, num_layers, block_tokens, recent_window,
                    history_period, float(kv_token_bytes), float(weight_bytes),
                    float(flops_per_token))
    slot_free = [0] * num_slots
    uid = 0
    for req, (p, d) in enumerate(requests):
        slot = min(range(num_slots), key=lambda s: slot_free[s])
        a = slot_free[slot]                 # admit step (slot refill)
        end = a + d - 1                     # last decode step
        slot_free[slot] = a + d
        tr.prefill_tokens[a] = tr.prefill_tokens.get(a, 0) + p
        for t in range(a, end + 1):
            tr.active[t] = tr.active.get(t, 0) + 1

        def make_obj(layer, blk, ts, te, birth, is_prefill):
            nonlocal uid
            o = KVObject(uid, slot, req, layer, blk,
                         int((te - ts) * kv_token_bytes), birth, end,
                         ts, te, is_prefill)
            uid += 1
            for t in range(birth, end + 1):
                tokens_now = p + (t - a) + 1
                recent = tokens_now - te < recent_window
                if recent or (t - birth) % history_period == 0:
                    o.accesses.append(t)
                    tr.reads.setdefault(t, []).append(o)
            tr.objects.append(o)
            (tr.admits if is_prefill else tr.births).setdefault(
                birth, []).append(o)
            tr.frees.setdefault(end + 1, []).append(o)

        n_pre = (p + block_tokens - 1) // block_tokens
        for layer in range(num_layers):
            for b in range(n_pre):
                make_obj(layer, b, b * block_tokens,
                         min((b + 1) * block_tokens, p), a, True)
            n_dec = (d + block_tokens - 1) // block_tokens
            for b in range(n_dec):
                ts = p + b * block_tokens
                make_obj(layer, n_pre + b, ts,
                         min(ts + block_tokens, p + d), a + b * block_tokens,
                         False)
    tr.num_steps = max(slot_free)
    return tr


@dataclass
class ServeSimResult:
    policy: str
    time: float                           # seconds for the whole timeline
    tokens: int                           # decode tokens produced
    compute_time: float                   # all-fast lower bound
    migrations: int = 0
    bytes_s2f: float = 0.0
    bytes_f2s: float = 0.0
    slow_bytes_accessed: float = 0.0
    detail: dict = field(default_factory=dict)

    @property
    def decode_throughput(self) -> float:  # tokens / second
        return self.tokens / max(self.time, 1e-30)

    @property
    def slowdown(self) -> float:
        return self.time / max(self.compute_time, 1e-30)


def simulate_serve(trace: ServeTrace, hw: HWSpec, fast_bytes: float,
                   policy: str = "sentinel", **knobs) -> ServeSimResult:
    """Replay the serving timeline under a registered placement policy.

    Per decode step: frees -> admissions (slot refill) -> decode-block births
    -> reads (split fast/slow by the policy's placement) -> roofline step time
    -> policy migration pass with ``step_time * mig_bw`` of off-critical-path
    bandwidth (the paper's migration threads), plus per-migration fixed
    overhead on the critical path.
    """
    from repro.core.policies import get_policy
    pol = get_policy(policy)(trace, hw, fast_bytes, **knobs)
    total = compute_lb = 0.0
    tokens = 0
    for t in range(trace.num_steps):
        pol.on_free(t, trace.frees.get(t, ()))
        pol.on_admit(t, trace.admits.get(t, ()))
        pol.on_birth(t, trace.births.get(t, ()))
        bf, bs = pol.on_reads(t, trace.reads.get(t, ()))
        writes = trace.write_bytes(t)
        flops = trace.active.get(t, 0) * trace.flops_per_token
        t_step = max(flops / hw.peak_flops,
                     (bf + writes + trace.weight_bytes) / hw.fast_bw
                     + bs / hw.slow_bw)
        # slot-refill prefill cost (prompt compute + KV writes, fast tier)
        p_tok = trace.prefill_tokens.get(t, 0)
        if p_tok:
            t_step += max(p_tok * trace.flops_per_token / hw.peak_flops,
                          p_tok * trace.num_layers * trace.kv_token_bytes
                          / hw.fast_bw)
        migs = pol.migrate(t, t_step * hw.mig_bw)
        total += t_step + migs * hw.mig_overhead
        compute_lb += max(flops / hw.peak_flops,
                          (bf + bs + writes + trace.weight_bytes) / hw.fast_bw)
        if p_tok:
            compute_lb += max(p_tok * trace.flops_per_token / hw.peak_flops,
                              p_tok * trace.num_layers * trace.kv_token_bytes
                              / hw.fast_bw)
        tokens += trace.active.get(t, 0)
    return ServeSimResult(policy, total, tokens, compute_lb,
                          migrations=pol.migrations, bytes_s2f=pol.bytes_s2f,
                          bytes_f2s=pol.bytes_f2s,
                          slow_bytes_accessed=pol.slow_bytes_accessed,
                          detail={"fast_bytes": fast_bytes,
                                  "peak_kv": trace.peak_kv_bytes(), **knobs})
