"""Sentinel's runtime on TPU: migration-interval-blocked activation offload.

The paper's mechanism maps onto XLA as follows (DESIGN.md §2):

  - long-lived data objects  = block-boundary residuals ("block_out") and
    optimizer state. The layer stack runs as scan-over-blocks of ``mi_periods``
    periods; the only values saved for backward are the tagged block carries,
    offloaded to ``pinned_host`` (slow memory). XLA emits asynchronous
    copy-start/copy-done pairs, overlapping migration with block compute —
    the paper's "migration happens in the middle of each interval".
  - short-lived data objects = everything inside a block: recomputed during
    backward from the prefetched carry, i.e. they only ever live in fast
    memory (HBM) — the reserved-pool policy ("never considered for
    migration") realized through rematerialization.
  - the migration interval   = ``mi_periods``. Small MI: more carries, more
    PCIe traffic, less recompute. Large MI: less traffic, more recompute and
    a larger intra-block working set (the Eq. 1 space constraint). The
    planner prunes and picks it from the profiled trace (core/planner.py).

Modes:
  "offload"   paper-faithful Sentinel: save block carries to host.
  "save_hbm"  same structure, carries stay in HBM (ablation / small models).
  "remat"     save nothing (full recompute; memory floor).
  "full"      no checkpointing (save everything; speed ceiling, memory peak).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.core.hardware import HWSpec, TPU_V5E
from repro.core.profiler import TraceProfile
from repro.runtime import PlacementPlan, mi_to_periods


@dataclass(frozen=True)
class SentinelConfig:
    mode: str = "offload"            # offload | save_hbm | remat | full
    mi_periods: int = 1
    offload_opt_state: bool = False  # optimizer moments live in pinned_host
    offload_names: tuple = ("block_out",)

    @property
    def uses_blocks(self) -> bool:
        return self.mode in ("offload", "save_hbm", "remat")


def remat_policy(scfg: SentinelConfig):
    cp = jax.checkpoint_policies
    if scfg.mode == "offload":
        return cp.save_and_offload_only_these_names(
            names_which_can_be_saved=[],
            names_which_can_be_offloaded=list(scfg.offload_names),
            offload_src="device", offload_dst="pinned_host")
    if scfg.mode == "save_hbm":
        return cp.save_only_these_names(*scfg.offload_names)
    if scfg.mode == "remat":
        return cp.nothing_saveable
    return None                      # "full": no checkpoint wrapper


def loss_kwargs(scfg: SentinelConfig) -> dict:
    """kwargs for model.loss_fn implementing this Sentinel config."""
    if scfg.mode == "full":
        return {}
    return {
        "remat_policy": remat_policy(scfg),
        "mi_periods": scfg.mi_periods,
        "tag_block_out": scfg.mode in ("offload", "save_hbm"),
    }


def from_plan(profile: TraceProfile, plan: PlacementPlan, *,
              cost_model=None, hw: Optional[HWSpec] = None,
              offload_opt_state: bool = False) -> SentinelConfig:
    """Planner output (``runtime.plan``) -> runtime config. The plan's MI is
    in timeline steps, which map 1:1 to periods inside the fwd/bwd regions.

    ``cost_model`` is reserved for machine-dependent rounding; the plan
    already encodes the machine it was priced on, so today neither it nor
    the deprecated ``hw=`` keyword (kept behind a warning) changes the
    result."""
    if hw is not None:
        from repro.core import warn_deprecated
        warn_deprecated("core.offload.from_plan(hw=...)",
                        "from_plan(profile, plan, cost_model=...)")
    mi = mi_to_periods(profile, plan.mi)
    # round to a divisor of num_periods so the blocked scan tiles exactly
    P = profile.num_periods
    divisors = [d for d in range(1, P + 1) if P % d == 0]
    mi = min(divisors, key=lambda d: abs(d - mi))
    return SentinelConfig(mode="offload", mi_periods=mi,
                          offload_opt_state=offload_opt_state)


def opt_state_sharding(rules, logical_axes, *, offload: bool):
    """NamedShardings for optimizer moments; pinned_host when offloaded
    (Sentinel: rarely-accessed long-lived objects live in slow memory)."""
    from repro.sharding import is_axes_leaf
    import jax.tree_util as jtu

    def one(ax):
        s = rules.sharding(ax)
        if offload:
            s = s.with_memory_kind("pinned_host")
        return s
    return jax.tree.map(one, logical_axes, is_leaf=is_axes_leaf)


def estimate_offload_traffic(profile: TraceProfile, mi_periods: int,
                             carry_bytes: int) -> dict:
    """Napkin numbers for the planner/benchmarks: bytes offloaded per step and
    the PCIe time vs compute time per block (Eq. 2 on TPU)."""
    P = profile.num_periods
    nb = max(1, P // max(1, mi_periods))
    bytes_off = 2 * nb * carry_bytes           # out in fwd, back in bwd
    return {"blocks": nb, "bytes_offloaded": bytes_off}
