"""Migration-interval planner (paper §4.4).

Given one profiled training step, the planner:
  1. computes RS(MI), Data(MI), T(MI) for every candidate interval,
  2. prunes by the paper's two constraints,
       space (Eq. 1):  Data(MI) < S - RS(MI)
       time  (Eq. 2):  T(MI)    > (S - RS(MI)) / BW
  3. evaluates surviving candidates on the HM simulator (the runtime system
     would use one real training step per candidate — same procedure, measured
     instead of simulated), resolving Case 3 by test-and-trial,
  4. returns the sweet spot.

The same object drives the JAX offload engine: ``mi_periods`` is the layer-scan
block size used by core/offload.py, and ``offload_uids`` the long-lived objects
worth migrating.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.hardware import HWSpec
from repro.core.hmsim import SimResult, simulate_sentinel_tt
from repro.core.profiler import TraceProfile


@dataclass
class Candidate:
    mi: int
    rs: float
    data: float          # max prefetch bytes over intervals
    t: float             # min compute seconds over intervals
    space_ok: bool
    time_ok: bool
    sim: Optional[SimResult] = None


@dataclass
class Plan:
    mi: int
    stall_on_case3: bool
    fast_bytes: float
    candidates: List[Candidate] = field(default_factory=list)
    sim: Optional[SimResult] = None
    steps_used: int = 0          # "p, m & t" budget actually consumed (Table 3)

    @property
    def throughput(self) -> float:
        return self.sim.throughput if self.sim else 0.0


def interval_stats(profile: TraceProfile, mi: int, hw: HWSpec):
    """(Data(MI), T(MI)) per interval: prefetch bytes needed by each interval
    and compute time available in the preceding one."""
    steps = profile.num_steps
    acts = [o for o in profile.objects if o.accesses]
    data_per: Dict[int, float] = {}
    t_per: Dict[int, float] = {}
    n_int = (steps + mi - 1) // mi
    for i in range(n_int):
        lo, hi = i * mi, min((i + 1) * mi, steps)
        t_per[i] = sum(max(profile.step_flops(s) / hw.peak_flops,
                           profile.step_bytes(s) / hw.fast_bw)
                       for s in range(lo, hi))
        data_per[i] = 0.0
    # the final boundary step (embedding grad + optimizer) touches every
    # weight/moment, but elementwise: it streams tile-by-tile and never needs
    # them resident together (ZeRO-Offload-style), so it is exempt from the
    # Eq. 1 capacity constraint (it still costs migration *time*).
    opt_step = steps - 1
    for o in acts:
        if o.kind == "weight" or o.lifetime >= 2:
            touched = sorted({a // mi for a in o.accesses if a != opt_step})
            for i in touched:
                # fetched for interval i (unless it was just produced there)
                if o.kind == "weight" or o.birth // mi != i:
                    data_per[i] += o.size
    return data_per, t_per


def enumerate_candidates(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                         max_mi: Optional[int] = None) -> List[Candidate]:
    out = []
    steps = profile.num_steps
    max_mi = max_mi or max(1, steps // 2)
    for mi in range(1, max_mi + 1):
        rs = profile.rs_bytes(mi)
        data_per, t_per = interval_stats(profile, mi, hw)
        data = max(data_per.values()) if data_per else 0.0
        t = min(t_per.values()) if t_per else 0.0
        space_ok = data < fast_bytes - rs
        time_ok = t > data / hw.mig_bw      # tight form of Eq. 2 (see note)
        out.append(Candidate(mi, rs, data, t, space_ok, time_ok))
    return out


def plan(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
         max_mi: Optional[int] = None, sim_all: bool = False) -> Plan:
    """Pick the optimal migration interval.

    Note on Eq. 2: the paper states T(MI) > (S - RS)/BW — the worst case of a
    full fast-memory refill. We prune with the tighter per-interval form
    T(MI) > Data(MI)/BW (a superset of the paper's surviving candidates) and
    let the measured sweep decide, exactly as the paper's runtime does.
    """
    cands = enumerate_candidates(profile, hw, fast_bytes, max_mi)
    survivors = [c for c in cands if c.space_ok and c.time_ok]
    if not survivors:                        # fall back: least-bad candidates
        survivors = [c for c in cands if c.space_ok] or cands
    steps_used = 1                           # the profiling step
    best: Optional[Candidate] = None
    pool = survivors if not sim_all else cands
    for c in pool:
        c.sim = simulate_sentinel_tt(profile, hw, fast_bytes, c.mi)
        steps_used += 1 + c.sim.detail.get("tt_steps_used", 0)
        if best is None or c.sim.step_time < best.sim.step_time:
            best = c
    stall = best.sim.detail.get("tt_choice", "stall") != "slow-access"
    p = Plan(mi=best.mi, stall_on_case3=stall, fast_bytes=fast_bytes,
             candidates=cands, sim=best.sim, steps_used=steps_used)
    return p


def mi_to_periods(profile: TraceProfile, mi: int) -> int:
    """Convert a timeline-step MI to layer-scan block size (periods per block)
    for the offload engine. Timeline steps map 1:1 to periods inside the
    forward/backward regions."""
    return max(1, min(mi, profile.num_periods))
