"""DEPRECATED module: the planner moved to ``repro.runtime.plan``.

Both halves of this module — the training migration-interval planner
(``plan``, paper §4.4) and the decode-phase serving planner (``plan_serve``,
Eq. 1/2 restated per token) — are now two dispatch arms of the single
``runtime.plan`` entry point, and the legacy ``Plan`` / ``ServePlan`` result
types are the unified, JSON-serializable ``runtime.PlacementPlan``::

    from repro import runtime
    plan = runtime.plan(profile_or_trace, hw, fast_bytes)

The wrappers below emit ``DeprecationWarning`` and return exactly what the
new API returns.  The candidate model and the planning helpers
(``enumerate_candidates``, ``interval_stats``, ``mi_to_periods``,
``slot_kv_weights``, ``serve_token_stats``) are re-exported unchanged.
Where each paper equation lands in the code is mapped in
``docs/RUNTIME_API.md`` / ``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core import warn_deprecated
from repro.core.hardware import HWSpec
from repro.core.hmsim import ServeTrace
from repro.core.profiler import TraceProfile
from repro.runtime.plan import (Candidate, PlacementPlan,  # noqa: F401
                                ServeCandidate, enumerate_candidates,
                                interval_stats, mi_to_periods,
                                serve_token_stats, slot_kv_weights)
from repro.runtime.plan import plan_serving as _plan_serving
from repro.runtime.plan import plan_training as _plan_training

# legacy result-type names (both were subsumed by the unified plan)
Plan = PlacementPlan
ServePlan = PlacementPlan


def _deprecated(old: str):
    warn_deprecated(f"core.planner.{old}", "runtime.plan(...)", stacklevel=4)


def plan(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
         max_mi: Optional[int] = None, sim_all: bool = False) -> PlacementPlan:
    """DEPRECATED: ``runtime.plan(profile, hw, fast_bytes, ...)``."""
    _deprecated("plan")
    return _plan_training(profile, hw, fast_bytes, max_mi=max_mi,
                          sim_all=sim_all)


def plan_serve(trace: ServeTrace, hw: HWSpec, fast_bytes: float,
               lookaheads: Sequence[int] = (2, 4, 8, 16, 32),
               policy: str = "sentinel") -> PlacementPlan:
    """DEPRECATED: ``runtime.plan(trace, hw, fast_bytes, ...)``."""
    _deprecated("plan_serve")
    return _plan_serving(trace, hw, fast_bytes, policy=policy,
                         lookaheads=lookaheads)
