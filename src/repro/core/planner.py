"""Migration-interval planner (paper §4.4).

Given one profiled training step, the planner:
  1. computes RS(MI), Data(MI), T(MI) for every candidate interval,
  2. prunes by the paper's two constraints,
       space (Eq. 1):  Data(MI) < S - RS(MI)
       time  (Eq. 2):  T(MI)    > (S - RS(MI)) / BW
  3. evaluates surviving candidates on the HM simulator (the runtime system
     would use one real training step per candidate — same procedure, measured
     instead of simulated), resolving Case 3 by test-and-trial,
  4. returns the sweet spot.

The same object drives the JAX offload engine: ``mi_periods`` is the layer-scan
block size used by core/offload.py, and ``offload_uids`` the long-lived objects
worth migrating.

The serving half of this module (``plan_serve`` / ``ServePlan``) restates
Eq. 1/2 per decode token; where each equation lands in the code is mapped in
``docs/ARCHITECTURE.md``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import HWSpec
from repro.core.hmsim import (ServeSimResult, ServeTrace, SimResult,
                              simulate_sentinel_tt, simulate_serve)
from repro.core.profiler import TraceProfile


@dataclass
class Candidate:
    mi: int
    rs: float
    data: float          # max prefetch bytes over intervals
    t: float             # min compute seconds over intervals
    space_ok: bool
    time_ok: bool
    sim: Optional[SimResult] = None


@dataclass
class Plan:
    mi: int
    stall_on_case3: bool
    fast_bytes: float
    candidates: List[Candidate] = field(default_factory=list)
    sim: Optional[SimResult] = None
    steps_used: int = 0          # "p, m & t" budget actually consumed (Table 3)

    @property
    def throughput(self) -> float:
        return self.sim.throughput if self.sim else 0.0


def interval_stats(profile: TraceProfile, mi: int, hw: HWSpec):
    """(Data(MI), T(MI)) per interval: prefetch bytes needed by each interval
    and compute time available in the preceding one."""
    steps = profile.num_steps
    acts = [o for o in profile.objects if o.accesses]
    data_per: Dict[int, float] = {}
    t_per: Dict[int, float] = {}
    n_int = (steps + mi - 1) // mi
    for i in range(n_int):
        lo, hi = i * mi, min((i + 1) * mi, steps)
        t_per[i] = sum(max(profile.step_flops(s) / hw.peak_flops,
                           profile.step_bytes(s) / hw.fast_bw)
                       for s in range(lo, hi))
        data_per[i] = 0.0
    # the final boundary step (embedding grad + optimizer) touches every
    # weight/moment, but elementwise: it streams tile-by-tile and never needs
    # them resident together (ZeRO-Offload-style), so it is exempt from the
    # Eq. 1 capacity constraint (it still costs migration *time*).
    opt_step = steps - 1
    for o in acts:
        if o.kind == "weight" or o.lifetime >= 2:
            touched = sorted({a // mi for a in o.accesses if a != opt_step})
            for i in touched:
                # fetched for interval i (unless it was just produced there)
                if o.kind == "weight" or o.birth // mi != i:
                    data_per[i] += o.size
    return data_per, t_per


def enumerate_candidates(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
                         max_mi: Optional[int] = None) -> List[Candidate]:
    out = []
    steps = profile.num_steps
    max_mi = max_mi or max(1, steps // 2)
    for mi in range(1, max_mi + 1):
        rs = profile.rs_bytes(mi)
        data_per, t_per = interval_stats(profile, mi, hw)
        data = max(data_per.values()) if data_per else 0.0
        t = min(t_per.values()) if t_per else 0.0
        space_ok = data < fast_bytes - rs
        time_ok = t > data / hw.mig_bw      # tight form of Eq. 2 (see note)
        out.append(Candidate(mi, rs, data, t, space_ok, time_ok))
    return out


def plan(profile: TraceProfile, hw: HWSpec, fast_bytes: float,
         max_mi: Optional[int] = None, sim_all: bool = False) -> Plan:
    """Pick the optimal migration interval.

    Note on Eq. 2: the paper states T(MI) > (S - RS)/BW — the worst case of a
    full fast-memory refill. We prune with the tighter per-interval form
    T(MI) > Data(MI)/BW (a superset of the paper's surviving candidates) and
    let the measured sweep decide, exactly as the paper's runtime does.
    """
    cands = enumerate_candidates(profile, hw, fast_bytes, max_mi)
    survivors = [c for c in cands if c.space_ok and c.time_ok]
    if not survivors:                        # fall back: least-bad candidates
        survivors = [c for c in cands if c.space_ok] or cands
    steps_used = 1                           # the profiling step
    best: Optional[Candidate] = None
    pool = survivors if not sim_all else cands
    for c in pool:
        c.sim = simulate_sentinel_tt(profile, hw, fast_bytes, c.mi)
        steps_used += 1 + c.sim.detail.get("tt_steps_used", 0)
        if best is None or c.sim.step_time < best.sim.step_time:
            best = c
    stall = best.sim.detail.get("tt_choice", "stall") != "slow-access"
    p = Plan(mi=best.mi, stall_on_case3=stall, fast_bytes=fast_bytes,
             candidates=cands, sim=best.sim, steps_used=steps_used)
    return p


def mi_to_periods(profile: TraceProfile, mi: int) -> int:
    """Convert a timeline-step MI to layer-scan block size (periods per block)
    for the offload engine. Timeline steps map 1:1 to periods inside the
    forward/backward regions."""
    return max(1, min(mi, profile.num_periods))


# ================================================================== serving ==
# Decode-phase planning: the paper's Eq. 1/2 restated per *token* instead of
# per migration interval.  During decode the timeline unit is one token step,
# the reserve pool RS is the set of open (still-filling) KV blocks, and the
# prefetchable data per step is bounded by one token's compute time times the
# migration bandwidth:
#
#   space (Eq. 1 per-token):  hot_bytes = B * W * kv_tok < S - RS_serve
#   time  (Eq. 2 per-token):  t_token   > prefetch_bytes(L) / BW_mig
#
# where W is the per-slot hot window (tokens kept in fast memory) and L the
# look-ahead (token steps of prefetch lead).  Like the training planner, the
# candidates surviving both constraints are measured on the serve simulator
# and the sweet spot wins.


@dataclass
class ServeCandidate:
    lookahead: int
    hot_window: int          # tokens of KV kept fast per slot
    prefetch_bytes: float    # per-step slow->fast demand at this look-ahead
    t_token: float           # all-fast decode step time
    space_ok: bool
    time_ok: bool
    sim: Optional[ServeSimResult] = None


@dataclass
class ServePlan:
    """Tiering decision for the serving runtime: ``hot_window`` tokens of each
    slot's KV stay in fast memory (HBM); everything older is the cold prefix
    in host memory.  ``lookahead`` drives the simulator policy's prefetch.

    ``slot_hot_windows`` refines the single global window per *slot*: each
    slot's window is sized from its own decode schedule (the byte-seconds its
    KV objects occupy in the trace), so a slot serving short requests never
    pins the same hot budget as one serving long ones.  ``page_tokens`` is
    the page granularity those per-slot boundaries are quantized to — the
    unit the paged decode kernel and the PageTable move."""
    policy: str
    hot_window: int
    lookahead: int
    fast_bytes: float
    rs: float
    candidates: List[ServeCandidate] = field(default_factory=list)
    sim: Optional[ServeSimResult] = None
    slot_hot_windows: Optional[List[int]] = None
    page_tokens: int = 0

    @property
    def decode_throughput(self) -> float:
        return self.sim.decode_throughput if self.sim else 0.0

    def cold_len(self, max_seq: int) -> int:
        """Cold-prefix length for a ``max_seq``-token cache buffer (global
        boundary — the concat path)."""
        return max(0, max_seq - self.hot_window)

    def slot_window(self, slot: int) -> int:
        """Hot-window tokens for ``slot`` (falls back to the global window)."""
        if not self.slot_hot_windows:
            return self.hot_window
        return self.slot_hot_windows[slot % len(self.slot_hot_windows)]

    def cold_len_slot(self, slot: int, seq_len: int,
                      page_tokens: Optional[int] = None) -> int:
        """Cold boundary for ``slot`` at its *current* sequence length,
        quantized down to page granularity: tokens older than the slot's own
        hot window, in whole pages.  Monotone in ``seq_len``, so within one
        residency a slot's boundary only ever advances.  ``page_tokens``
        overrides the plan's page size (the engine adjusts it to divide its
        cache buffer)."""
        cold = max(0, seq_len - self.slot_window(slot))
        page = max(1, page_tokens if page_tokens else self.page_tokens)
        return (cold // page) * page


def slot_kv_weights(trace: ServeTrace) -> List[float]:
    """Per-slot share of KV byte-seconds over the timeline: how much cache
    each slot's decode schedule actually keeps alive.  The per-slot analogue
    of the paper's per-object lifetime profile."""
    w = [0.0] * max(1, trace.num_slots)
    for o in trace.objects:
        w[o.slot % len(w)] += o.bytes * (o.death - o.birth + 1)
    total = sum(w) or 1.0
    return [x / total for x in w]


def serve_token_stats(trace: ServeTrace, hw: HWSpec) -> tuple:
    """(t_token, read_bytes): all-fast decode-step time and mean per-step KV
    read volume over the timeline — the serving analogue of interval_stats."""
    steps = max(1, trace.num_steps)
    read_bytes = sum(o.bytes * len(o.accesses) for o in trace.objects) / steps
    act = sum(trace.active.get(t, 0) for t in range(steps)) / steps
    flops = act * trace.flops_per_token
    bw_bytes = read_bytes + trace.weight_bytes + act * trace.num_layers \
        * trace.kv_token_bytes
    return max(flops / hw.peak_flops, bw_bytes / hw.fast_bw), read_bytes


def plan_serve(trace: ServeTrace, hw: HWSpec, fast_bytes: float,
               lookaheads: Sequence[int] = (2, 4, 8, 16, 32),
               policy: str = "sentinel") -> ServePlan:
    """Pick the hot window and prefetch look-ahead for serving-time tiering."""
    rs = trace.rs_bytes()
    budget = max(0.0, fast_bytes - rs)
    kv_tok_all = trace.num_layers * trace.kv_token_bytes
    slots = max(1, trace.num_slots)
    # floor: the open, still-filling block per slot is fast by construction
    # (it IS the reserve pool), so the hot window is never below one block
    hot_window = max(trace.block_tokens,
                     int(budget / (slots * kv_tok_all))) if kv_tok_all else 0
    t_token, _ = serve_token_stats(trace, hw)
    cold_bytes = max(0.0, trace.peak_kv_bytes() - budget)
    # Eq. 1 per-token: the hot windows plus the reserve pool must fit (the
    # floor above can violate this when fast memory is tiny)
    space_ok = rs + slots * hot_window * kv_tok_all <= fast_bytes

    cands: List[ServeCandidate] = []
    for la in sorted(set(lookaheads)):
        # history blocks re-read every history_period steps: within a
        # look-ahead of L steps, L/period of the cold set must be prefetched,
        # against L steps' worth of migration bandwidth (Eq. 2 per-token)
        prefetch = cold_bytes * min(1.0, la / max(1, trace.history_period))
        cands.append(ServeCandidate(la, hot_window, prefetch, t_token,
                                    space_ok=space_ok,
                                    time_ok=t_token * la * hw.mig_bw
                                    >= prefetch))
    # measure survivors on the simulator (fall back to everything when the
    # constraints kill all candidates, mirroring the training planner)
    pool = [c for c in cands if c.space_ok and c.time_ok] or cands
    best: Optional[ServeCandidate] = None
    for c in pool:
        c.sim = simulate_serve(trace, hw, fast_bytes, policy,
                               lookahead=c.lookahead)
        if best is None or c.sim.decode_throughput > best.sim.decode_throughput:
            best = c

    # Eq. 1 refined per slot: distribute the hot-token budget in proportion
    # to each slot's own decode schedule (KV byte-seconds), floor one block
    # (its open block is the reserve pool), quantized to block==page units.
    blk = max(1, trace.block_tokens)
    budget_tokens = budget / kv_tok_all if kv_tok_all else 0.0
    weights = slot_kv_weights(trace)
    slot_windows = [max(blk, (int(budget_tokens * w) // blk) * blk)
                    for w in weights]

    return ServePlan(policy=policy, hot_window=best.hot_window,
                     lookahead=best.lookahead, fast_bytes=fast_bytes, rs=rs,
                     candidates=cands, sim=best.sim,
                     slot_hot_windows=slot_windows, page_tokens=blk)
