"""DEPRECATED module: the policy registry moved to ``repro.runtime.policies``.

This shim re-exports the unified registry so existing imports keep working —
``POLICIES`` is the *same* dict object as the runtime's, so policies
registered through either path are visible to both.  The registry now also
carries the training-native policies (``sentinel_mi``, ``ial``, ``lru``,
``all_fast``, ``all_slow``) next to the serving trio (``prefer_fast``,
``lru_page``, ``sentinel``), and every one of them runs on every workload::

    from repro import runtime
    runtime.simulate(trace_or_profile, hw, fast_bytes, "sentinel")

Reference documentation — hook protocol, per-policy semantics, the incumbent
tie-breaking rule — lives in ``docs/POLICIES.md``; the migration guide in
``docs/RUNTIME_API.md``.
"""
from __future__ import annotations

from typing import List, Type

from repro.runtime.policies import (PAGE_BYTES, POLICIES,  # noqa: F401
                                    LRUPage, PlacementPolicy, PreferFast,
                                    SentinelLifetime, register_policy)
from repro.runtime.policies import get_policy as _get_policy
from repro.runtime.policies import list_policies as _list_policies

# legacy names
ServePolicy = PlacementPolicy
SentinelServe = SentinelLifetime


def get_policy(name: str) -> Type[PlacementPolicy]:
    """Thin wrapper over ``runtime.get_policy`` (legacy error message)."""
    try:
        return _get_policy(name)
    except KeyError:
        raise KeyError(f"unknown serve policy {name!r}; "
                       f"registered: {sorted(POLICIES)}") from None


def list_policies() -> List[str]:
    return _list_policies()
