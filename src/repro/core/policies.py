"""Pluggable placement/migration policies for serving-time KV-cache tiering.

Sentinel's training-time argument — object-granular placement wins because the
runtime knows object lifetimes from the workload's repeatable structure —
transfers to inference serving: per-slot, per-layer KV blocks are exactly the
"large amount of small data objects" of the paper, and the decode phase
repeats its access pattern every token. A policy decides, per KV block object
(or per page packing many objects), which tier it lives in and what migrates
between decode steps.

The policy families mirror the placement/migration strategy space of
Data_Placement_Optimization (PreferHBM / look-ahead batch migration) and the
page-grain reactive daemons (IAL/LRU) the paper compares against:

  prefer_fast  static object-grain PreferHBM: born fast while room remains,
               never migrated.  Weakness: once fast fills with old-but-alive
               history, fresh hot blocks are stuck slow.
  lru_page     page-grain reactive LRU: objects bump-packed into pages in
               birth order (mixing slots/layers — false sharing), periodic
               promotion of re-touched slow pages, LRU demotion.  Weakness:
               detection lag + dead bytes of refilled slots ride along in
               every promoted page.
  sentinel     lifetime-aware object policy: next accesses are *known* (the
               decode schedule is repeatable), so it prefetches the KV blocks
               needed in the next ``lookahead`` steps and evicts blocks whose
               next access is farthest — Belady with real lifetime knowledge,
               at object granularity.

Policies register themselves in ``POLICIES`` via the ``@register_policy``
decorator; the simulator (``hmsim.simulate_serve``), the decode-phase
planner (``planner.plan_serve``) and ``benchmarks/bench_serve.py`` all
dispatch by name, so a new policy is benchmarkable the moment it is
registered.  Reference documentation — hook protocol, per-policy semantics,
the incumbent tie-breaking rule in ``sentinel.migrate`` — lives in
``docs/POLICIES.md``.
"""
from __future__ import annotations

import bisect
import collections
from typing import Dict, Iterable, List, Optional, Type

PAGE_BYTES = 2 << 20          # huge-page granularity for page-grain baselines

POLICIES: Dict[str, Type["ServePolicy"]] = {}


def register_policy(name: str):
    """Class decorator: add a ServePolicy subclass to the registry."""
    def deco(cls):
        cls.name = name
        POLICIES[name] = cls
        return cls
    return deco


def get_policy(name: str) -> Type["ServePolicy"]:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown serve policy {name!r}; "
                       f"registered: {sorted(POLICIES)}") from None


def list_policies() -> List[str]:
    return sorted(POLICIES)


class ServePolicy:
    """Base: tracks placement (uid -> in fast?) and fast occupancy; charges
    migrations.  Subclasses override the hooks they care about.

    Hook order per decode step t (driven by hmsim.simulate_serve):
      on_free(t, objs)      blocks of completed requests disappear
      on_admit(t, objs)     prefill blocks of a refilled slot are born
      on_birth(t, objs)     decode blocks completed this step are born
      on_reads(t, objs)     -> (bytes_fast, bytes_slow) for this step's reads
      migrate(t, budget)    -> #migrations, off-critical-path volume capped
                               by budget (= step_time * mig_bw)
    """

    name = "base"
    granularity = "object"

    def __init__(self, trace, hw, fast_bytes: float, **knobs):
        self.trace, self.hw, self.fast_bytes = trace, hw, float(fast_bytes)
        self.knobs = knobs
        self.in_fast: Dict[int, bool] = {}
        self.live: Dict[int, object] = {}
        self.fast_used = 0.0
        self.migrations = 0
        self.bytes_s2f = 0.0
        self.bytes_f2s = 0.0
        self.slow_bytes_accessed = 0.0

    # ------------------------------------------------------------- helpers --
    def _place(self, o, fast: bool):
        self.live[o.uid] = o
        self.in_fast[o.uid] = fast
        if fast:
            self.fast_used += o.bytes

    def _demote(self, o):
        if self.in_fast.get(o.uid):
            self.in_fast[o.uid] = False
            self.fast_used -= o.bytes
            self.migrations += 1
            self.bytes_f2s += o.bytes

    def _promote(self, o):
        if not self.in_fast.get(o.uid):
            self.in_fast[o.uid] = True
            self.fast_used += o.bytes
            self.migrations += 1
            self.bytes_s2f += o.bytes

    # --------------------------------------------------------------- hooks --
    def on_free(self, t: int, objs: Iterable) -> None:
        for o in objs:
            if self.in_fast.pop(o.uid, False):
                self.fast_used -= o.bytes
            self.live.pop(o.uid, None)

    def on_admit(self, t: int, objs: Iterable) -> None:
        for o in objs:
            self._place(o, self.fast_used + o.bytes <= self.fast_bytes)

    def on_birth(self, t: int, objs: Iterable) -> None:
        # decode blocks were just written by compute (fast-resident RS pool);
        # they stay fast if room remains, else they spill at birth
        self.on_admit(t, objs)

    def on_reads(self, t: int, objs: Iterable):
        bf = bs = 0.0
        for o in objs:
            if self.in_fast.get(o.uid, False):
                bf += o.bytes
            else:
                bs += o.bytes
        self.slow_bytes_accessed += bs
        return bf, bs

    def migrate(self, t: int, budget_bytes: float) -> int:
        return 0


@register_policy("prefer_fast")
class PreferFast(ServePolicy):
    """Static PreferHBM: fast while room remains, no migration ever."""


@register_policy("lru_page")
class LRUPage(ServePolicy):
    """Page-grain reactive LRU with bump allocation (false sharing).

    Objects are packed into ``page_bytes`` pages in birth order, interleaving
    slots and layers exactly like a bump allocator does.  Placement and
    migration are per *page*: a promoted page carries every byte it packs,
    dead or alive; a page's fast space is only reclaimed when all members died
    or when the page is demoted.  Promotion is reactive: a slow page touched
    since the last step becomes a candidate; the least-recently-touched fast
    pages are demoted to make room.
    """

    granularity = "page"

    class _Page:
        __slots__ = ("pid", "members", "live_bytes", "in_fast", "last_touch")

        def __init__(self, pid):
            self.pid = pid
            self.members: list = []
            self.live_bytes = 0.0
            self.in_fast = False
            self.last_touch = -1

    def __init__(self, trace, hw, fast_bytes, *, page_bytes: int = PAGE_BYTES,
                 **knobs):
        super().__init__(trace, hw, fast_bytes, **knobs)
        self.page_bytes = float(page_bytes)
        self.pages: List[LRUPage._Page] = []
        self.page_of: Dict[int, LRUPage._Page] = {}
        self._open: Optional[LRUPage._Page] = None
        self._open_fill = 0.0
        self._touched_slow: "collections.OrderedDict" = collections.OrderedDict()

    def _alloc(self, o):
        if self._open is None or self._open_fill + o.bytes > self.page_bytes:
            pg = LRUPage._Page(len(self.pages))
            pg.in_fast = self.fast_used + self.page_bytes <= self.fast_bytes
            if pg.in_fast:
                self.fast_used += self.page_bytes
            self.pages.append(pg)
            self._open, self._open_fill = pg, 0.0
        pg = self._open
        pg.members.append(o)
        pg.live_bytes += o.bytes
        self._open_fill += o.bytes
        self.page_of[o.uid] = pg
        self.live[o.uid] = o
        self.in_fast[o.uid] = pg.in_fast

    def on_admit(self, t, objs):
        for o in objs:
            self._alloc(o)

    on_birth = on_admit

    def on_free(self, t, objs):
        for o in objs:
            pg = self.page_of.pop(o.uid, None)
            self.live.pop(o.uid, None)
            self.in_fast.pop(o.uid, None)
            if pg is None:
                continue
            pg.live_bytes -= o.bytes
            if pg.live_bytes <= 0 and pg is not self._open:
                # fully dead page: space reclaimed (only now — false sharing
                # kept the dead bytes resident until the last member died)
                if pg.in_fast:
                    self.fast_used -= self.page_bytes
                pg.in_fast = False

    def on_reads(self, t, objs):
        bf = bs = 0.0
        for o in objs:
            pg = self.page_of[o.uid]
            pg.last_touch = t
            if pg.in_fast:
                bf += o.bytes
            else:
                bs += o.bytes
                self._touched_slow[pg.pid] = pg
        self.slow_bytes_accessed += bs
        return bf, bs

    def migrate(self, t, budget_bytes):
        moved = 0
        # most recently touched slow pages first (reactive promotion)
        for pid in reversed(list(self._touched_slow)):
            pg = self._touched_slow.pop(pid)
            if pg.live_bytes <= 0 or budget_bytes < self.page_bytes:
                continue
            # demote LRU fast pages until the candidate fits
            while self.fast_used + self.page_bytes > self.fast_bytes and \
                    budget_bytes >= self.page_bytes:
                victims = [p for p in self.pages
                           if p.in_fast and p.live_bytes > 0]
                if not victims:
                    break
                v = min(victims, key=lambda p: p.last_touch)
                if v.last_touch >= pg.last_touch:
                    break                      # nothing colder than candidate
                v.in_fast = False
                self.fast_used -= self.page_bytes
                for m in v.members:
                    if m.uid in self.in_fast:
                        self.in_fast[m.uid] = False
                budget_bytes -= self.page_bytes
                self.migrations += 1
                self.bytes_f2s += self.page_bytes
                moved += 1
            if self.fast_used + self.page_bytes <= self.fast_bytes and \
                    budget_bytes >= self.page_bytes:
                pg.in_fast = True
                self.fast_used += self.page_bytes
                for m in pg.members:
                    if m.uid in self.in_fast:
                        self.in_fast[m.uid] = True
                budget_bytes -= self.page_bytes
                self.migrations += 1
                self.bytes_s2f += self.page_bytes
                moved += 1
        self._touched_slow.clear()
        return moved


@register_policy("sentinel")
class SentinelServe(ServePolicy):
    """Lifetime-aware object policy with look-ahead prefetch.

    The decode schedule is known (the serving analogue of the paper's
    repeatable training timeline), so each object's exact next access is
    available.  Every step the policy (a) prefetches objects whose next access
    falls within ``lookahead`` steps, (b) evicts the objects whose next access
    is farthest away (or never) to make room — per-token Belady at object
    granularity, bandwidth-capped like the paper's migration threads.
    """

    def __init__(self, trace, hw, fast_bytes, *, lookahead: int = 8, **knobs):
        super().__init__(trace, hw, fast_bytes, **knobs)
        self.lookahead = max(1, int(lookahead))

    @staticmethod
    def _next_access(o, t: int) -> Optional[int]:
        i = bisect.bisect_right(o.accesses, t)
        return o.accesses[i] if i < len(o.accesses) else None

    def _score(self, o, t: int) -> int:
        """Known accesses within the look-ahead horizon (per-token Eq. 2:
        this is the reuse the migration bandwidth can still buy back)."""
        lo = bisect.bisect_right(o.accesses, t)
        hi = bisect.bisect_right(o.accesses, t + self.lookahead)
        return hi - lo

    def _evict_for(self, need: float, t: int) -> None:
        """Make room by evicting farthest-next-access fast objects (Belady
        on the known schedule)."""
        if self.fast_used + need <= self.fast_bytes:
            return
        victims = [o for o in self.live.values() if self.in_fast.get(o.uid)]
        victims.sort(key=lambda o: -(self._next_access(o, t) or 10 ** 12))
        for v in victims:
            if self.fast_used + need <= self.fast_bytes:
                break
            self._demote(v)

    def on_admit(self, t, objs):
        # placement at birth is free (data is written to its tier directly):
        # hot-window blocks displace colder incumbents, cold prefix is born
        # slow — the serving analogue of "born in fast" vs residual offload
        for o in objs:
            if self._score(o, t - 1) == 0:
                self._place(o, False)
                continue
            self._evict_for(o.bytes, t)
            self._place(o, self.fast_used + o.bytes <= self.fast_bytes)

    on_birth = on_admit

    def migrate(self, t, budget_bytes):
        migs0 = self.migrations
        live = list(self.live.values())
        scored = [(self._score(o, t), o) for o in live]
        # desired fast set: greedy by score; incumbents win ties so
        # equal-rate history blocks never ping-pong between tiers
        scored.sort(key=lambda p: (-p[0], not self.in_fast.get(p[1].uid),
                                   p[1].uid))
        target = set()
        used = 0.0
        for sc, o in scored:
            if sc <= 0:
                break
            if used + o.bytes <= self.fast_bytes:
                target.add(o.uid)
                used += o.bytes
        promotes = [o for sc, o in scored
                    if o.uid in target and not self.in_fast.get(o.uid)]
        promotes.sort(key=lambda o: self._next_access(o, t) or 10 ** 12)
        for o in promotes:
            if o.bytes > budget_bytes:
                break
            while self.fast_used + o.bytes > self.fast_bytes:
                victims = [v for v in live if self.in_fast.get(v.uid)
                           and v.uid not in target]
                if not victims:
                    break
                v = min(victims, key=lambda v: self._score(v, t))
                if v.bytes > budget_bytes:
                    budget_bytes = -1.0
                    break
                self._demote(v)
                budget_bytes -= v.bytes
            if budget_bytes < 0 or self.fast_used + o.bytes > self.fast_bytes:
                break
            self._promote(o)
            budget_bytes -= o.bytes
        return self.migrations - migs0
