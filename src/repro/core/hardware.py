"""Hardware constants for the planner, simulator and roofline analysis."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops: float          # /s (bf16 where applicable)
    fast_bw: float             # fast-tier bandwidth, B/s
    slow_bw: float             # slow-tier bandwidth, B/s
    mig_bw: float              # migration bandwidth fast<->slow, B/s (per dir)
    fast_bytes: float          # fast-tier capacity (per device)
    link_bw: float = 0.0       # interconnect per link, B/s (roofline)
    mig_overhead: float = 0.0  # per-migration fixed critical-path cost, s
                               # (move_pages syscall / TLB shootdown on CPU;
                               #  DMA descriptor dispatch on TPU)


# TPU v5e chip: HBM is the fast tier; host DRAM over PCIe is the slow tier.
TPU_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    fast_bw=819e9,
    slow_bw=32e9,              # host DRAM as seen from device, PCIe-bound
    mig_bw=16e9,               # PCIe gen4 x16 per direction (effective)
    fast_bytes=16e9,
    link_bw=50e9,              # ICI per link
    mig_overhead=5e-6,
)

def default_cost_model():
    """The default machine as a time-domain ``CostModel``: TPU_V5E's
    constants plus the host-side split the byte-domain ``HWSpec`` cannot
    express.  One shared instance prices the planner (``runtime.plan``),
    the benchmarks, and the roofline table (``benchmarks/roofline.py``) —
    the single source of truth for the default machine's numbers.

    Imported lazily: ``repro.runtime.costmodel`` depends on this module
    for the raw constants."""
    from repro.runtime.costmodel import TPU_V5E_COST
    return TPU_V5E_COST


# The paper's evaluation platform (Table 2): 2-socket Xeon, local vs remote DDR4.
PAPER_HM = HWSpec(
    name="paper-xeon-hm",
    peak_flops=1.5e12,         # ~2x12-core AVX2 Xeon E5-2670v3 fp32
    fast_bw=34e9,
    slow_bw=19e9,
    mig_bw=19e9,               # cross-socket
    fast_bytes=6.4e9,          # Fig.10 uses 20% of peak model footprint
    mig_overhead=2e-6,         # per page, amortized over batched move_pages
                               # with 4-thread parallel copy (Yan et al. mech.)
)
