"""AdamW with global-norm clipping, warmup-cosine schedule, and an optional
error-feedback int8 gradient compressor for the cross-pod all-reduce
(distributed-optimization lever; see DESIGN.md §4)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False    # int8 + error feedback on the DP reduce


def schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    state = {"m": jax.tree.map(zeros, params),
             "v": jax.tree.map(zeros, params),
             "count": jnp.zeros((), jnp.int32)}
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(zeros, params)   # error-feedback residual
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def compress_decompress(g, ef):
    """int8 quantize + error feedback: g_q = q(g + ef); ef' = g + ef - g_q.
    Models the compressed cross-pod all-reduce payload (the reduce itself is
    inserted by GSPMD; quantizing before it shrinks DCN bytes 4x)."""
    t = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-8) / 127.0
    q = jnp.round(t / scale).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, t - deq


def update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-8))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.compress_grads:
        pairs = jax.tree.map(compress_decompress, grads, state["ef"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_ef = None

    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])

    new_state = {"m": new_m, "v": new_v, "count": count}
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
