"""The unified runtime API: one profile -> plan -> migrate surface for both
workload families.  Pins (a) the golden-plan JSON round trip, (b) the
cross-workload policy matrix (every registered policy runs on every
workload, and the lifetime-aware policy never loses to the page-grain
baseline at the paper's headline fraction), and (c) the deprecation shims
(old entry points warn but return results equal to the new API's)."""
import warnings

import pytest

from repro import runtime
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.runtime.synthetic import (synthetic_profile,
                                     synthetic_serve_trace,
                                     synthetic_shared_prefix_trace)


@pytest.fixture(scope="module")
def prof():
    return synthetic_profile()


@pytest.fixture(scope="module")
def trace():
    return synthetic_serve_trace()


@pytest.fixture(scope="module")
def shared_trace():
    return synthetic_shared_prefix_trace()


# ------------------------------------------------------------- workloads ----

def test_workload_adapters_dispatch(prof, trace):
    wt = runtime.as_workload(prof)
    ws = runtime.as_workload(trace)
    assert (wt.kind, ws.kind) == ("training", "serving")
    tl_t, tl_s = wt.timeline(), ws.timeline()
    assert tl_t.num_steps == prof.num_steps
    assert tl_s.num_steps == trace.num_steps
    # serving timeline preserves the trace's objects and event identity
    assert tl_s.objects is trace.objects
    assert tl_s.reads is trace.reads
    # training timeline: only migration candidates are objects; the
    # short-lived pool is carried as reserved bytes
    assert all(o.kind == "weight" or o.lifetime >= 2 for o in tl_t.objects)
    assert tl_t.reserved_bytes == prof.rs_bytes(1)
    assert tl_t.peak_bytes() > 0 and tl_s.peak_bytes() > 0
    with pytest.raises(TypeError, match="cannot adapt"):
        runtime.as_workload(object())


def test_plan_accepts_protocol_workloads(prof, trace):
    """runtime.plan works for anything implementing the Workload protocol —
    including a bare AccessTimeline — not just the two concrete adapters."""
    tl_t = runtime.as_workload(prof).timeline()
    tl_s = runtime.as_workload(trace).timeline()
    assert runtime.plan(tl_t, PAPER_HM, 0.3 * prof.peak_bytes()) == \
        runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    assert runtime.plan(tl_s, TPU_V5E, 0.2 * trace.peak_kv_bytes()) == \
        runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())


def test_memory_tiers(prof):
    pl = runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    assert pl.tiers is not None and [t.name for t in pl.tiers] == \
        ["fast", "slow"]
    assert pl.tiers[0].capacity == pytest.approx(0.3 * prof.peak_bytes())
    assert pl.tiers[1].capacity is None      # slow tier is unbounded


# ---------------------------------------------------------- golden plans ----

def test_plan_json_roundtrip_serving_golden(trace):
    """Plan on a fixed synthetic workload, round-trip, byte-identical
    re-serialization (guards against silent planner drift)."""
    pl = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    s = pl.to_json()
    back = runtime.PlacementPlan.from_json(s)
    assert back.to_json() == s                       # byte-identical
    # and the reconstructed plan is semantically the original
    assert back == pl
    assert back.cold_len_slot(1, 100) == pl.cold_len_slot(1, 100)
    assert back.sim.decode_throughput == pl.decode_throughput


def test_plan_json_roundtrip_training_golden(prof):
    pl = runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    s = pl.to_json()
    back = runtime.PlacementPlan.from_json(s)
    assert back.to_json() == s
    assert back == pl
    assert (back.kind, back.mi, back.stall_on_case3) == \
        ("training", pl.mi, pl.stall_on_case3)
    # candidate types survive the trip (tagged, not inferred)
    assert all(isinstance(c, runtime.Candidate) for c in back.candidates)


def test_plan_multi_tenant_golden_fixture():
    """Checked-in golden plan: the multi-tenant plan JSON on disk is (a) a
    byte-identical ``from_json``/``to_json`` round trip and (b) byte-equal
    to a freshly computed plan — any planner drift (candidate scoring,
    window sizing, tenant accounting, serialization) fails this test."""
    import pathlib

    from repro.runtime.synthetic import synthetic_multi_tenant_trace
    path = pathlib.Path(__file__).parent / "golden" / "multi_tenant_plan.json"
    text = path.read_text().rstrip("\n")
    back = runtime.PlacementPlan.from_json(text)
    assert back.to_json() == text                    # byte-identical reload
    wl = synthetic_multi_tenant_trace()
    fresh = runtime.plan(wl, TPU_V5E, 0.2 * wl.trace.peak_kv_bytes())
    assert fresh.to_json() == text                   # no silent drift
    assert fresh == back
    assert back.policy == "sentinel_slo"
    assert back.slot_tenants == wl.slot_tenants
    assert back.tenant_quotas == dict(sorted(wl.tenant_quotas.items()))
    assert not back.tenant_violations                # the SLO report card


def test_plan_latency_golden_fixture():
    """Checked-in golden latency plan: the time-domain objective's pick on
    the fixed serving workload, carrying its serialized ``CostModel`` and
    predicted step times — drift in the cost model's pricing, the latency
    selection loop, or the new fields' serialization fails this test."""
    import pathlib

    from repro.runtime import TPU_V5E_COST
    path = pathlib.Path(__file__).parent / "golden" / "latency_plan.json"
    text = path.read_text().rstrip("\n")
    back = runtime.PlacementPlan.from_json(text)
    assert back.to_json() == text                    # byte-identical reload
    trace = synthetic_serve_trace()
    fresh = runtime.plan(trace, TPU_V5E_COST, 0.2 * trace.peak_kv_bytes(),
                         objective="latency")
    assert fresh.to_json() == text                   # no silent drift
    assert fresh == back
    assert back.objective == "latency"
    assert back.cost_model == TPU_V5E_COST
    assert back.predicted_time == pytest.approx(
        sum(back.predicted_step_times))
    # the prediction is reproducible from the plan's own cost model
    assert back.cost_model.price_result(fresh.sim).time == \
        pytest.approx(back.predicted_time)


def test_online_replan_trace_golden_fixture():
    """Checked-in golden re-plan trace: the full online event sequence
    (trigger steps, drift reasons, delta contents, lend/reclaim schedule,
    per-segment regret differential) on the canonical tenant-flip drift
    workload — drift anywhere in the detector, the hysteresis, the delta
    serialization, or the replay pricing fails this test byte-for-byte."""
    import json
    import pathlib

    from repro.runtime import TPU_V5E_COST, replay_drift
    from repro.runtime.synthetic import synthetic_drift_tenant_flip
    path = pathlib.Path(__file__).parent / "golden" / \
        "online_replan_trace.json"
    text = path.read_text().rstrip("\n")
    wl = synthetic_drift_tenant_flip()
    rep = replay_drift(wl, TPU_V5E_COST, 0.2 * wl.peak_kv_bytes())
    assert rep.to_json() == text                     # no silent drift
    d = json.loads(text)
    assert d["regret"] <= 0.10
    assert d["online_s"] <= d["static_s"]
    assert d["tenant_violations"] == {}
    assert d["churn_bytes"] <= d["churn_budget_bytes"]
    # the pinned deltas replay onto the initial plan byte-identically
    p = rep.plan0
    for ev, pinned in zip((e for e in rep.events if e.applied),
                          (e for e in d["events"] if e["applied"])):
        # compare through JSON: in-memory changes keep int dict keys
        # (e.g. the simulator's per-interval case counts) that the wire
        # form stringifies
        assert json.loads(ev.delta.to_json()) == pinned["delta"]
        p = p.apply_delta(runtime.PlanDelta.from_dict(pinned["delta"]))
        assert p.to_json() == ev.plan.to_json()


def test_plan_feeds_offload_engine(prof):
    """The unified plan drives the training offload config end to end."""
    from repro.core import offload
    pl = runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    scfg = offload.from_plan(prof, pl)
    assert scfg.mode == "offload"
    assert 1 <= scfg.mi_periods <= prof.num_periods
    assert prof.num_periods % scfg.mi_periods == 0


# ---------------------------------------------------------- policy matrix ----

def test_policy_matrix_cross_workload(prof, trace):
    """Every registered policy runs on both a training TraceProfile workload
    and a ServeTrace workload without error; ``sentinel`` never loses to
    ``lru_page`` on either at 20% fast memory."""
    fast_t = 0.2 * prof.peak_bytes()
    fast_s = 0.2 * trace.peak_kv_bytes()
    res_t, res_s = {}, {}
    for name in runtime.list_policies():
        if name == "base":
            continue
        res_t[name] = runtime.simulate(prof, PAPER_HM, fast_t, name)
        res_s[name] = runtime.simulate(trace, TPU_V5E, fast_s, name)
        for r, tokens in ((res_t[name], 0), (res_s[name], sum(
                trace.active.values()))):
            assert r.policy == name
            assert r.time > 0 and r.compute_time > 0
            assert r.tokens == tokens
    assert {"prefer_fast", "lru_page", "sentinel", "sentinel_mi", "ial",
            "lru", "all_fast", "all_slow"} <= set(res_t)
    # the paper's claim on both workloads: lifetime knowledge >= reactive
    # page-grain, when fast memory is scarce
    assert res_t["sentinel"].time <= res_t["lru_page"].time
    assert res_s["sentinel"].time <= res_s["lru_page"].time
    assert res_s["sentinel"].decode_throughput >= \
        res_s["lru_page"].decode_throughput
    # static bounds bracket every policy on both workloads
    for res in (res_t, res_s):
        for name, r in res.items():
            assert r.time >= res["all_fast"].time * 0.999
            assert r.time <= res["all_slow"].time * 1.001


def test_policy_matrix_shared_prefix_workload(shared_trace):
    """Satellite: the N-tenants x one-system-prompt workload runs under
    every registered policy on the unified surface, and the sharing-aware
    accounting beats the matched unshared stream on the lifetime policy."""
    unshared = synthetic_shared_prefix_trace(shared=False)
    fast = 0.2 * unshared.peak_kv_bytes()
    tokens = sum(shared_trace.active.values())
    for name in runtime.list_policies():
        if name == "base":
            continue
        r = runtime.simulate(shared_trace, TPU_V5E, fast, name)
        assert r.policy == name and r.time > 0 and r.tokens == tokens
    rs = runtime.simulate(shared_trace, TPU_V5E, fast, "sentinel")
    ru = runtime.simulate(unshared, TPU_V5E, fast, "sentinel")
    # shared pages' bytes count once: less migration, smaller physical peak
    assert rs.bytes_s2f + rs.bytes_f2s < ru.bytes_s2f + ru.bytes_f2s
    assert shared_trace.peak_kv_bytes() < unshared.peak_kv_bytes()
    # and the plan's per-slot windows stay page-quantized on the shared trace
    pl = runtime.plan(shared_trace, TPU_V5E, fast)
    assert all(w % pl.page_tokens == 0 for w in pl.slot_hot_windows)


def test_training_native_policy_on_serving_and_vice_versa(prof, trace):
    """The headline unification: the MI-interval engine plans serving traces
    and the decode-native lifetime policy runs training profiles."""
    r_mi = runtime.simulate(trace, TPU_V5E, 0.3 * trace.peak_kv_bytes(),
                            "sentinel_mi", mi=8)
    assert r_mi.mi == 8 and r_mi.tokens > 0
    r_ev = runtime.simulate(prof, PAPER_HM, 0.3 * prof.peak_bytes(),
                            "sentinel", lookahead=4)
    assert r_ev.detail["lookahead"] == 4 and r_ev.time > 0


# ------------------------------------------------------ deprecation shims ----

def test_deprecated_plan_warns_and_matches(prof):
    from repro.core import planner
    with pytest.warns(DeprecationWarning, match="core.planner.plan"):
        old = planner.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    new = runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    assert isinstance(old, runtime.PlacementPlan)
    assert old == new


def test_deprecated_plan_serve_warns_and_matches(trace):
    from repro.core import planner
    with pytest.warns(DeprecationWarning, match="plan_serve"):
        old = planner.plan_serve(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    new = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    assert old == new


def test_deprecated_simulators_warn_and_match(prof, trace):
    from repro.core import hmsim
    fast = 0.3 * prof.peak_bytes()
    with pytest.warns(DeprecationWarning, match="simulate_sentinel"):
        old = hmsim.simulate_sentinel(prof, PAPER_HM, fast, mi=2)
    new = runtime.simulate(prof, PAPER_HM, fast, "sentinel_mi", mi=2,
                           test_and_trial=False)
    assert old == new
    with pytest.warns(DeprecationWarning, match="simulate_sentinel_tt"):
        old_tt = hmsim.simulate_sentinel_tt(prof, PAPER_HM, fast, 2)
    assert old_tt == runtime.simulate(prof, PAPER_HM, fast, "sentinel_mi",
                                      mi=2)
    fast_s = 0.2 * trace.peak_kv_bytes()
    with pytest.warns(DeprecationWarning, match="simulate_serve"):
        old_s = hmsim.simulate_serve(trace, TPU_V5E, fast_s, "sentinel")
    assert old_s == runtime.simulate(trace, TPU_V5E, fast_s, "sentinel")
    with pytest.warns(DeprecationWarning, match="simulate_caching"):
        old_c = hmsim.simulate_caching(prof, PAPER_HM, fast, "ial")
    assert old_c == runtime.simulate(prof, PAPER_HM, fast, "ial")
    with pytest.warns(DeprecationWarning, match="simulate_static"):
        old_f = hmsim.simulate_static(prof, PAPER_HM, "fast")
    assert old_f.time == runtime.simulate(prof, PAPER_HM, 0.0,
                                          "all_fast").time


def test_legacy_registry_is_the_unified_registry():
    """core.policies and runtime.policies share one registry object, and the
    legacy KeyError message survives."""
    from repro.core import policies as legacy
    assert legacy.POLICIES is runtime.POLICIES
    assert issubclass(legacy.get_policy("sentinel_mi"), legacy.ServePolicy)
    with pytest.raises(KeyError, match="unknown serve policy"):
        legacy.get_policy("nope")
    with pytest.raises(KeyError, match="unknown placement policy"):
        runtime.get_policy("nope")


def test_new_api_does_not_warn(prof, trace):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
        runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
        runtime.simulate(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes(),
                         "sentinel")
