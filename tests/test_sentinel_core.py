"""Sentinel core: profiler observations, planner constraints, simulator
behaviour — the paper's §3/§4 claims as assertions."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import allocator, hmsim, planner, profiler
from repro.core.hardware import PAPER_HM, TPU_V5E
from repro.models import model
from repro.models.layers import split_params


@pytest.fixture(scope="module")
def prof():
    cfg = dataclasses.replace(
        get_config("smollm-360m"), num_layers=8, d_model=128, num_heads=8,
        num_kv_heads=4, d_ff=512, head_dim=16, vocab_size=1024,
        dtype="float32")
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    pshapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           params)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    return profiler.trace_profile(
        jax.grad(lambda p, b: model.loss_fn(p, cfg, b, unroll_periods=True)),
        pshapes, batch, num_periods=cfg.num_periods)


def test_observation1_short_lived_dominance(prof):
    """Paper Obs. 1: the large majority of data objects are short-lived."""
    short = prof.short_lived(include_fused=True)
    acts = [o for o in prof.objects if o.kind == "activation"]
    assert len(short) / len(acts) > 0.75


def test_observation2_hot_cold_skew(prof):
    """Paper Obs. 2: few objects account for most accesses."""
    acts = sorted((o for o in prof.objects if o.kind == "activation"),
                  key=lambda o: -o.reads)
    hot = acts[:len(acts) // 10]
    # >2x the uniform 10% share
    assert sum(o.reads for o in hot) > 0.2 * sum(o.reads for o in acts)


def test_observation3_false_sharing(prof):
    """Paper Obs. 3: original (bump) allocation mixes short- and long-lived
    objects in the same pages."""
    stats = allocator.false_sharing_stats(prof)
    assert stats["false_shared_pages"] > 0


def test_profiling_footprint_overhead_small(prof):
    """Paper Table 5: one-object-per-page grows footprint only modestly
    (large objects dominate)."""
    o = allocator.profiling_overhead(prof)
    assert o["profiled_bytes"] > o["orig_bytes"]
    assert o["overhead_frac"] < 0.35
    # small objects blow up relatively (Table 1: 0.45MB -> 152MB at paper
    # scale; our traces have larger small objects, so the factor is smaller)
    assert o["small_obj_profiled_bytes"] > 2 * o["small_obj_bytes"]


def test_rs_stable_across_mi(prof):
    """Paper §4.4: RS is nearly constant in MI."""
    vals = [prof.rs_bytes(mi) for mi in (1, 2, 4, 8)]
    assert max(vals) <= min(vals) * 1.05 + 1


def test_timeline_layers_cover_fwd_and_bwd(prof):
    fwd = [s for s in prof.layers if 1 <= s <= prof.num_periods]
    bwd = [s for s in prof.layers
           if prof.num_periods + 2 <= s <= 2 * prof.num_periods + 1]
    assert len(fwd) == prof.num_periods
    assert len(bwd) == prof.num_periods


# ------------------------------------------------------------- simulator ----

def test_sentinel_never_beats_fast_only(prof):
    fast_only = hmsim.simulate_static(prof, PAPER_HM, "fast")
    for frac in (0.2, 0.4, 0.8):
        r = hmsim.simulate_sentinel_tt(prof, PAPER_HM,
                                       frac * prof.peak_bytes(), 2)
        assert r.step_time >= fast_only.step_time * 0.999


def test_sentinel_beats_slow_only_and_ial(prof):
    peak = prof.peak_bytes()
    slow = hmsim.simulate_static(prof, PAPER_HM, "slow")
    ial = hmsim.simulate_caching(prof, PAPER_HM, 0.3 * peak, "ial")
    pl = planner.plan(prof, PAPER_HM, 0.3 * peak)
    assert pl.sim.step_time < slow.step_time
    assert pl.sim.step_time < ial.step_time


def test_more_fast_memory_never_hurts(prof):
    times = []
    for frac in (0.2, 0.4, 0.6, 0.9):
        pl = planner.plan(prof, PAPER_HM, frac * prof.peak_bytes())
        times.append(pl.sim.step_time)
    for a, b in zip(times, times[1:]):
        assert b <= a * 1.02


def test_paper_headline_band(prof):
    """Sentinel with ~25% of peak as fast memory stays within ~15% of
    fast-memory-only (paper: <=8% at 20% on their five models)."""
    fast_only = hmsim.simulate_static(prof, PAPER_HM, "fast")
    pl = planner.plan(prof, PAPER_HM, 0.25 * prof.peak_bytes())
    assert pl.sim.step_time <= 1.15 * fast_only.step_time


def test_case_accounting(prof):
    """Fewer intervals -> fewer case events; each interval except the last
    reports exactly one case."""
    peak = prof.peak_bytes()
    for mi in (1, 3, 6):
        r = hmsim.simulate_sentinel_tt(prof, PAPER_HM, 0.3 * peak, mi)
        n_int = -(-prof.num_steps // mi)
        assert sum(r.cases.values()) == n_int - 1


def test_planner_constraints(prof):
    # space_ok set grows monotonically with fast size and is non-empty once
    # the budget clears the smallest per-interval prefetch set
    counts = []
    for frac in (0.5, 0.7, 1.0):
        cands = planner.enumerate_candidates(prof, PAPER_HM,
                                             frac * prof.peak_bytes())
        counts.append(sum(c.space_ok for c in cands))
    assert counts == sorted(counts)
    assert counts[-1] > 0
    # Data(MI) grows with MI (more prefetch per interval)
    datas = [c.data for c in cands]
    assert datas[-1] >= datas[0]


def test_planner_tpu_spec_runs(prof):
    pl = planner.plan(prof, TPU_V5E, 0.3 * prof.peak_bytes())
    assert pl.mi >= 1 and pl.sim is not None


def test_page_grain_worse_than_object_grain(prof):
    """The paper's core claim: object-granular Sentinel beats the same policy
    at page granularity with bump allocation (false sharing)."""
    peak = prof.peak_bytes()
    obj = hmsim.simulate_sentinel_tt(prof, PAPER_HM, 0.3 * peak, 2)
    page = hmsim.simulate_sentinel_tt(prof, PAPER_HM, 0.3 * peak, 2,
                                      granularity="page",
                                      page_mode="original")
    assert obj.step_time <= page.step_time * 1.001
