"""Multi-tenant serving on the unified runtime: the tenant/workload model,
the SLO-aware planner half, and the engine — per-tenant admission, quota-
respecting demotion, counters that match the simulator's prediction exactly,
and logits bit-identical to the all-HBM run."""
import dataclasses

import pytest

from repro import runtime
from repro.core.hardware import TPU_V5E
from repro.runtime.synthetic import synthetic_multi_tenant_trace


# ------------------------------------------------------------ tenant model ---

def _mini_traces(geoms=((4, 3), (6, 2))):
    from repro.core.hmsim import build_serve_trace
    return [build_serve_trace([(p, d), (p + 2, d)], num_slots=1,
                              num_layers=2, kv_token_bytes=64.0,
                              weight_bytes=1e3, flops_per_token=1e6)
            for p, d in geoms]


def test_merge_tenant_traces_disjoint_slots_and_tags():
    tenants = [runtime.Tenant("a", fast_quota_frac=0.5),
               runtime.Tenant("b", fast_quota_frac=0.5, arrival=3)]
    traces = _mini_traces()
    wl = runtime.MultiTenantWorkload(tenants, traces)
    tr = wl.trace
    assert tr.num_slots == 2 and wl.slot_tenants == ["a", "b"]
    assert {o.tenant for o in tr.objects} == {"a", "b"}
    # tenant b's whole schedule is shifted by its arrival offset
    b_objs = [o for o in tr.objects if o.tenant == "b"]
    src_b = traces[1].objects
    assert min(o.birth for o in b_objs) == min(o.birth for o in src_b) + 3
    assert tr.num_steps == max(traces[0].num_steps, traces[1].num_steps + 3)
    # per-step activity is the sum of the interleaved streams
    assert sum(tr.active.values()) == \
        sum(traces[0].active.values()) + sum(traces[1].active.values())
    # slots are disjoint: tenant a in slot 0, tenant b in slot 1
    assert {o.slot for o in tr.objects if o.tenant == "a"} == {0}
    assert {o.slot for o in tr.objects if o.tenant == "b"} == {1}
    # uids were re-issued without collision
    uids = [o.uid for o in tr.objects]
    assert len(uids) == len(set(uids))


def test_merge_rejects_mismatched_geometry_and_dup_ids():
    from repro.core.hmsim import build_serve_trace
    t0 = _mini_traces()[0]
    t1 = build_serve_trace([(4, 3)], num_slots=1, num_layers=3,
                           kv_token_bytes=64.0)
    with pytest.raises(ValueError, match="model geometry"):
        runtime.merge_tenant_traces([runtime.Tenant("a"),
                                     runtime.Tenant("b")], [t0, t1])
    with pytest.raises(ValueError, match="unique"):
        runtime.MultiTenantWorkload([runtime.Tenant("x"),
                                     runtime.Tenant("x")], _mini_traces())


def test_merge_namespaces_shared_keys_per_tenant():
    """Two tenants' independently-built traces both using prefix_id 0 hold
    physically distinct prompts: merged keys are namespaced by default, and
    only ids declared platform-wide via ``shared_prefix_ids`` coalesce."""
    from repro.core.hmsim import build_serve_trace

    def mk():
        return [build_serve_trace([(32, 4, 0), (32, 4, 0)], num_slots=1,
                                  num_layers=2, kv_token_bytes=64.0,
                                  shared_prefix_tokens=32)
                for _ in range(2)]

    tenants = [runtime.Tenant("a"), runtime.Tenant("b")]
    ns, _ = runtime.merge_tenant_traces(tenants, mk())
    keys_ns = {o.shared_key for o in ns.objects if o.shared_key}
    assert all(k[0] in ("a", "b") for k in keys_ns)   # tenant-namespaced
    plat, _ = runtime.merge_tenant_traces(tenants, mk(),
                                          shared_prefix_ids=(0,))
    keys_p = {o.shared_key for o in plat.objects if o.shared_key}
    assert all(k[0] == 0 for k in keys_p)             # verbatim, coalesced
    # platform-wide sharing dedups the prompt once more across tenants
    assert plat.peak_kv_bytes() < ns.peak_kv_bytes()


def test_normalized_quotas():
    ts = [runtime.Tenant("a", fast_quota_frac=0.5), runtime.Tenant("b"),
          runtime.Tenant("c")]
    q = runtime.normalized_quotas(ts)
    assert q["a"] == 0.5 and q["b"] == q["c"] == pytest.approx(0.25)
    # oversubscribed explicit quotas are rescaled to sum 1
    q2 = runtime.normalized_quotas([runtime.Tenant("a", fast_quota_frac=1.0),
                                    runtime.Tenant("b", fast_quota_frac=3.0)])
    assert q2 == {"a": pytest.approx(0.25), "b": pytest.approx(0.75)}
    assert sum(q.values()) <= 1.0 + 1e-9


# ---------------------------------------------------------------- planner ----

def test_plan_multi_tenant_fields_and_roundtrip():
    wl = synthetic_multi_tenant_trace()
    fast = 0.2 * wl.trace.peak_kv_bytes()
    pl = runtime.plan(wl, TPU_V5E, fast)
    assert pl.policy == "sentinel_slo"
    assert pl.slot_tenants == wl.slot_tenants
    assert pl.tenant_quotas == dict(sorted(wl.tenant_quotas.items()))
    # the winning sim's per-tenant accounting rides on the plan: peaks for
    # both tenants, zero violations for the SLO policy
    assert set(pl.tenant_fast_bytes) == {"chatty", "bursty"}
    assert pl.tenant_violations is None
    # windows are sized inside each tenant's share and page-quantized
    assert all(w % pl.page_tokens == 0 for w in pl.slot_hot_windows)
    assert len(pl.slot_hot_windows) == wl.trace.num_slots
    s = pl.to_json()
    back = runtime.PlacementPlan.from_json(s)
    assert back.to_json() == s and back == pl
    assert back.slot_tenants == pl.slot_tenants
    assert back.tenant_fast_bytes == pl.tenant_fast_bytes


def test_tenant_blind_policy_measured_against_same_quotas():
    """runtime.plan on a tenanted workload with a quota-blind policy still
    reports the violation accounting (measured, not enforced)."""
    wl = synthetic_multi_tenant_trace()
    fast = 0.2 * wl.trace.peak_kv_bytes()
    pl = runtime.plan(wl, TPU_V5E, fast, policy="sentinel")
    assert pl.policy == "sentinel"
    assert pl.tenant_violations and sum(pl.tenant_violations.values()) >= 1


# ----------------------------------------------------------------- engine ----

@pytest.fixture(scope="module")
def tenant_run():
    """One pools-layout multi-tenant run: the batcher, its plan, the request
    stream, and the all-HBM reference outputs."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine

    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, slots = 32, 4
    chatty = [(5, 5), (6, 4), (7, 5)]
    bursty = [(12, 6), (11, 5)]
    tenants = [runtime.Tenant("chatty", fast_quota_frac=0.5, slo_slack=1.05),
               runtime.Tenant("bursty", fast_quota_frac=0.5, slo_slack=2.0)]
    traces = [engine.serve_trace_for(get_config("smollm-360m"), rs, slots=2,
                                     layer_group=8)
              for rs in (chatty, bursty)]
    wl = runtime.MultiTenantWorkload(tenants, traces)
    plan = runtime.plan(wl, TPU_V5E, 0.2 * wl.trace.peak_kv_bytes())
    # shrink the planned windows to the reduced max_seq so demotions occur
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8, 4, 8], page_tokens=4)
    reqs = []
    key = jax.random.PRNGKey(3)
    for tn, stream in (("chatty", chatty), ("bursty", bursty)):
        for p, d in stream:
            key, sub = jax.random.split(key)
            reqs.append((jax.random.randint(sub, (p,), 0, cfg.vocab_size)
                         .astype(jnp.int32), d, tn))

    def drive(c, p, paged):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged,
                                     slot_tenants=plan.slot_tenants)
        for t, d, tn in reqs:
            b.submit(t, d, tenant=tn)
        return b.run(), b

    out_ref, _ = drive(cfg, None, False)
    out, b = drive(cfg_k, plan, True)
    return {"engine": engine, "plan": plan, "b": b, "out": out,
            "out_ref": out_ref, "reqs": reqs, "slots": slots,
            "max_seq": max_seq}


def test_engine_tenant_admission_respects_slots(tenant_run):
    """Requests only ever ran in their own tenant's slots: every slot's
    pages belong to one tenant, and both tenants got all their tokens."""
    b = tenant_run["b"]
    assert b.slot_tenants == ["chatty", "chatty", "bursty", "bursty"]
    want = sum(d for _, d, _ in tenant_run["reqs"])
    assert sum(len(o) for o in tenant_run["out"]) == want
    # an unknown tenant tag would queue forever — submit rejects it up front
    with pytest.raises(ValueError, match="owns no batch slot"):
        b.submit(tenant_run["reqs"][0][0], 2, tenant="Bursty")


def test_engine_matches_simulator_counters_exactly(tenant_run):
    """The agreement contract: predicted migration bytes, pool counters and
    per-tenant fast-byte peaks equal the real batcher's, integer for
    integer, on the deterministic trace."""
    b, engine = tenant_run["b"], tenant_run["engine"]
    pred = engine.predict_pool_counters(
        [(int(t.shape[0]), d, tn) for t, d, tn in tenant_run["reqs"]],
        tenant_run["plan"], slots=tenant_run["slots"],
        max_seq=tenant_run["max_seq"], page_tokens=b.page_tokens,
        row_bytes=b._row_bytes)
    assert pred["migration_bytes"] == b.sim_migration_bytes
    # the per-decode-step series the CostModel prices: integer-exact per
    # step, and its sum is the aggregate counter
    assert pred["step_migration_bytes"] == b.step_migration_bytes
    assert sum(pred["step_migration_bytes"]) == b.sim_migration_bytes
    assert pred["page_copies"] == b.pool.stats["page_copies"]
    assert pred["admit_page_writes"] == b.pool.stats["admit_page_writes"]
    assert pred["tenant_hot_peak"] == b.tenant_hot_peak
    assert set(b.tenant_hot_peak) == {"chatty", "bursty"}
    assert all(v > 0 for v in b.tenant_hot_peak.values())


def test_engine_tenant_logits_bit_identical_to_all_hbm(tenant_run):
    """Quota-respecting tiering never changes a logit: the tenant-tagged
    pools run reproduces the all-HBM reference tokens exactly."""
    assert tenant_run["out"] == tenant_run["out_ref"]
    assert tenant_run["b"].sim_migration_bytes > 0   # it really demoted
    tenant_run["b"].ptable.check()
