"""The online re-planner held to a clairvoyant-regret differential.

The offline planner's contract is "profile once, place forever"; the online
loop (runtime/online.py) breaks the repeatability assumption on purpose, so
its tests are differential: every piecewise-stationary drift workload is
replayed through the per-segment clairvoyant oracle (a fresh
``runtime.plan`` with full knowledge at each segment's first step) and the
online planner's predicted-time regret against that plan sequence is gated
at ≤ 10%, with hysteresis churn within budget, zero SLO violations across
re-plans, and every applied ``PlanDelta`` byte-identical to the fresh plan
it was diffed from.  The engine half pins ``apply_plan`` /
``predict_pool_counters(plan_schedule=)`` agreement integer-exactly across
a re-plan boundary, and hypothesis fuzzes the delta path end to end."""
import dataclasses

import pytest

from repro import runtime
from repro.runtime import (DriftSegment, DriftWorkload, OnlineReplanner,
                           TPU_V5E_COST, plan_churn_bytes, plan_delta,
                           replay_drift)
from repro.runtime.synthetic import drift_workloads

REGRET_BOUND = 0.10
MIG_FACTOR = 1.3


def _is_lend(ev):
    return ev.reason.startswith(("lend:", "reclaim:"))


@pytest.fixture(scope="module")
def reports():
    """One online replay per canonical drift workload, default knobs, 20%
    fast memory — the exact configuration ``bench_runtime --drift`` gates."""
    out = {}
    for name, wl in drift_workloads().items():
        fast = 0.2 * wl.peak_kv_bytes()
        out[name] = (wl, replay_drift(wl, TPU_V5E_COST, fast))
    return out


# ----------------------------------------------------- regret differential ---

def test_regret_within_bound_on_every_drift_workload(reports):
    """The headline gate: ≤10% predicted-time regret vs the per-segment
    clairvoyant plan sequence, having actually re-planned (not by luck)."""
    for name, (wl, rep) in reports.items():
        assert rep.regret <= REGRET_BOUND, (name, rep.regret)
        drift_evs = [e for e in rep.events if not _is_lend(e) and e.applied]
        assert drift_evs, f"{name}: the online loop never re-planned"
        # detection is prompt: a re-plan lands within two windows of at
        # least one segment boundary
        bounds, t = [], 0
        for seg in wl.segments[:-1]:
            t += seg.num_steps
            bounds.append(t)
        lag = 2 * int(rep.knobs["window"])
        assert any(0 <= e.step - b <= lag for e in drift_evs
                   for b in bounds), (name, [e.step for e in drift_evs])


def test_online_beats_static_stale_plan(reports):
    """The loop must pay for itself: never slower than serving the whole
    drift under segment-0's stale plan, in time and tokens/sec."""
    for name, (wl, rep) in reports.items():
        assert rep.online_s <= rep.static_s, name
        assert rep.online_tokens_per_s >= rep.static_tokens_per_s, name


def test_migration_bytes_within_clairvoyant_factor(reports):
    for name, (wl, rep) in reports.items():
        assert rep.online_mig_bytes <= \
            MIG_FACTOR * rep.clairvoyant_mig_bytes, name


def test_zero_slo_violations_across_replans(reports):
    """Re-planning never trades away a tenant's guarantee: every plan the
    online loop served under (stale, fresh, lent) ran violation-free."""
    for name, (wl, rep) in reports.items():
        assert rep.tenant_violations == {}, (name, rep.tenant_violations)


# ------------------------------------------------------------- delta chain ---

def test_delta_chain_reconstructs_every_applied_plan(reports):
    """Applying the emitted deltas in order to the initial plan reproduces
    every intermediate plan byte-for-byte — an applied delta IS the fresh
    plan, which is what makes deltas safe to ship to a live engine."""
    for name, (wl, rep) in reports.items():
        p = rep.plan0
        for ev in rep.events:
            if not ev.applied:
                continue
            assert ev.delta.base_digest == p.digest(), (name, ev.step)
            p = p.apply_delta(ev.delta)
            assert p.to_json() == ev.plan.to_json(), (name, ev.step)


def test_drift_replan_is_bit_identical_to_fresh_plan(reports):
    """At each detected shift the applied plan equals a from-scratch
    ``runtime.plan`` on that segment's workload, byte-for-byte."""
    for name, (wl, rep) in reports.items():
        seen = set()
        for ev in rep.events:
            if _is_lend(ev) or not ev.applied or ev.segment in seen:
                continue
            seen.add(ev.segment)           # first drift re-plan per segment
            fresh = runtime.plan(wl.segments[ev.segment].workload,
                                 TPU_V5E_COST,
                                 rep.knobs["fast_bytes"],
                                 objective="latency")
            assert ev.plan.to_json() == fresh.to_json(), (name, ev.step)
        assert seen, name


def test_delta_applies_only_in_emission_order():
    wl = drift_workloads()["prompt_shift"]
    rep = replay_drift(wl, TPU_V5E_COST, 0.2 * wl.peak_kv_bytes())
    ev = next(e for e in rep.events if not _is_lend(e) and e.applied)
    stale = ev.plan                        # delta was diffed from plan0
    with pytest.raises(ValueError, match="emission order"):
        stale.apply_delta(ev.delta)
    # and the delta's JSON round-trips byte-identically (the wire format)
    s = ev.delta.to_json()
    assert runtime.PlanDelta.from_json(s).to_json() == s


# -------------------------------------------------------------- hysteresis ---

def test_min_dwell_spaces_drift_replans(reports):
    for name, (wl, rep) in reports.items():
        steps = [e.step for e in rep.events if not _is_lend(e)]
        dwell = int(rep.knobs["min_dwell"])
        assert all(b - a >= dwell for a, b in zip(steps, steps[1:])), name


def test_churn_budget_is_respected_and_suppresses(reports):
    """Cumulative re-layout bytes stay inside the budget; with a zero
    budget every window-shrinking re-plan is suppressed (emitted with
    ``applied=False``) and nothing moves."""
    for name, (wl, rep) in reports.items():
        assert rep.churn_bytes <= rep.churn_budget_bytes, name
    wl = drift_workloads()["prompt_shift"]
    rep = replay_drift(wl, TPU_V5E_COST, 0.2 * wl.peak_kv_bytes(),
                       churn_budget_bytes=0.0)
    assert rep.churn_bytes == 0.0
    suppressed = [e for e in rep.events if not e.applied]
    assert suppressed and all(e.churn_bytes > 0 for e in suppressed)


def test_replanner_refuses_history_carrying_policies():
    tr = drift_workloads()["prompt_shift"].segments[0].workload
    fast = 0.2 * tr.peak_kv_bytes()
    pl = runtime.plan(tr, TPU_V5E_COST, fast, policy="lru_page",
                      objective="latency")
    rpl = OnlineReplanner(TPU_V5E_COST, fast)
    with pytest.raises(ValueError, match="supports_replan"):
        rpl.adopt(pl)


def test_plan_churn_bytes_counts_only_shrinks():
    tr = drift_workloads()["prompt_shift"].segments[0].workload
    pl = runtime.plan(tr, TPU_V5E_COST, 0.2 * tr.peak_kv_bytes(),
                      objective="latency")
    grown = dataclasses.replace(pl, slot_hot_windows=[
        w + pl.page_tokens for w in pl.slot_hot_windows])
    assert plan_churn_bytes(pl, grown, 64.0) == 0.0      # growth is free
    assert plan_churn_bytes(grown, pl, 64.0) == \
        len(pl.slot_hot_windows) * pl.page_tokens * 64.0


# --------------------------------------------------------- elastic lending ---

def test_flash_crowd_lends_and_reclaims_slots(reports):
    """While the crowd tenant sleeps its slots are lent to the steady
    tenant (pure slot_tenants deltas, zero churn); when the crowd wakes the
    owners reclaim them before the drift re-plan lands."""
    wl, rep = reports["flash_crowd"]
    lends = [e for e in rep.events if e.reason.startswith("lend:")]
    reclaims = [e for e in rep.events if e.reason.startswith("reclaim:")]
    assert lends and reclaims
    for e in lends + reclaims:
        assert e.applied and e.churn_bytes == 0.0
        assert set(e.delta.changes) == {"slot_tenants"}
    first = next(e for e in rep.events if e.reason == "lend:crowd->steady")
    assert first.plan.slot_tenants == ["steady"] * 4
    # reclaim restores the true ownership recorded on the initial plan
    assert reclaims[0].plan.slot_tenants == rep.plan0.slot_tenants
    # lending is rate-limited to once per window
    steps = [e.step for e in lends + reclaims]
    steps.sort()
    assert all(b - a >= int(rep.knobs["window"])
               for a, b in zip(steps, steps[1:]))


def test_surge_lends_steady_slots_to_the_crowd(reports):
    """Lending is symmetric: in the surge segment the steady tenant drains
    first and its slots go to the crowd."""
    wl, rep = reports["flash_crowd"]
    assert any(e.reason == "lend:steady->crowd" for e in rep.events)


# -------------------------------------------- engine: apply_plan agreement ---

@pytest.fixture(scope="module")
def replan_run():
    """A pools-layout run that adopts two re-plans mid-stream — one as a
    ``PlanDelta`` (window shrink), one as a full plan (shrink + tenancy
    swap) — plus the all-HBM reference for bit-exactness."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine

    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, slots = 32, 4
    chatty = [(5, 5), (6, 4), (7, 5), (5, 4)]
    bursty = [(12, 6), (11, 5), (10, 4)]
    tenants = [runtime.Tenant("chatty", fast_quota_frac=0.5, slo_slack=1.05),
               runtime.Tenant("bursty", fast_quota_frac=0.5, slo_slack=2.0)]
    traces = [engine.serve_trace_for(get_config("smollm-360m"), rs, slots=2,
                                     layer_group=8)
              for rs in (chatty, bursty)]
    wl = runtime.MultiTenantWorkload(tenants, traces)
    plan_a = runtime.plan(wl, TPU_V5E_COST, 0.2 * wl.trace.peak_kv_bytes())
    plan_a = dataclasses.replace(plan_a, hot_window=16,
                                 slot_hot_windows=[8, 8, 8, 8],
                                 page_tokens=4)
    plan_b = dataclasses.replace(plan_a, slot_hot_windows=[4, 8, 4, 8])
    delta_b = plan_delta(plan_a, plan_b, step=3, reason="test:shrink")
    plan_c = dataclasses.replace(plan_b, slot_hot_windows=[4, 4, 4, 4],
                                 slot_tenants=["bursty", "bursty",
                                               "chatty", "chatty"])
    reqs = []
    key = jax.random.PRNGKey(3)
    for tn, stream in (("chatty", chatty), ("bursty", bursty)):
        for p, d in stream:
            key, sub = jax.random.split(key)
            reqs.append((jax.random.randint(sub, (p,), 0, cfg.vocab_size)
                         .astype(jnp.int32), d, tn))

    def drive(c, p, paged, schedule=()):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged,
                                     slot_tenants=plan_a.slot_tenants)
        for t, d, tn in reqs:
            b.submit(t, d, tenant=tn)
        results, moved = [], []
        pending = sorted(schedule, key=lambda e: e[0])
        while b.queue or any(b.active):
            while pending and pending[0][0] <= len(b.step_migration_bytes):
                moved.append(b.apply_plan(pending.pop(0)[1]))
            if not b.step():
                break
            for i in range(b.B):
                if not b.active[i] and b.outputs[i]:
                    results.append(b.outputs[i])
                    b.outputs[i] = []
        return results, b, moved

    schedule = [(3, delta_b), (6, plan_c)]
    out_ref, _, _ = drive(cfg, None, False)
    out, b, moved = drive(cfg_k, plan_a, True, schedule)
    return {"engine": engine, "b": b, "out": out, "out_ref": out_ref,
            "moved": moved, "reqs": reqs, "slots": slots, "max_seq": max_seq,
            "plan_a": plan_a, "schedule": schedule}


def test_engine_counters_match_replay_across_replan_boundary(replan_run):
    """The satellite fix, pinned: with re-plans landing between decode
    steps, the engine's marker-based per-step series and the segment-aware
    replay (``plan_schedule=``) agree integer-for-integer, and the series
    sums to the total on both sides (bytes moved by ``apply_plan`` land in
    the next step's entry instead of vanishing)."""
    b, engine = replan_run["b"], replan_run["engine"]
    pred = engine.predict_pool_counters(
        [(int(t.shape[0]), d, tn) for t, d, tn in replan_run["reqs"]],
        replan_run["plan_a"], slots=replan_run["slots"],
        max_seq=replan_run["max_seq"], page_tokens=b.page_tokens,
        row_bytes=b._row_bytes, plan_schedule=replan_run["schedule"])
    assert pred["step_migration_bytes"] == b.step_migration_bytes
    assert pred["migration_bytes"] == b.sim_migration_bytes
    assert sum(pred["step_migration_bytes"]) == pred["migration_bytes"]
    assert sum(b.step_migration_bytes) == b.sim_migration_bytes
    assert pred["page_copies"] == b.pool.stats["page_copies"]
    assert pred["admit_page_writes"] == b.pool.stats["admit_page_writes"]
    assert pred["tenant_hot_peak"] == b.tenant_hot_peak
    # the live counter export bundles the same numbers
    c = b.counters()
    assert c["sim_migration_bytes"] == b.sim_migration_bytes
    assert c["step_migration_bytes"] == b.step_migration_bytes
    assert c["page_copies"] == pred["page_copies"]


def test_engine_apply_plan_moves_bytes_and_stays_consistent(replan_run):
    """Both adoptions really demoted pages (shrunken windows), the tenancy
    swap took effect for later admissions, and the page table is green."""
    b, moved = replan_run["b"], replan_run["moved"]
    assert len(moved) == 2 and moved[0] > 0        # the shrink delta copied
    assert b.slot_tenants == ["bursty", "bursty", "chatty", "chatty"]
    b.ptable.check()


def test_engine_replans_never_change_a_logit(replan_run):
    """Re-planning only moves KV between tiers: every request's decoded
    tokens are identical to the all-HBM reference run (as multisets — the
    tenancy swap may reorder completions across slots)."""
    got = sorted(tuple(o) for o in replan_run["out"])
    ref = sorted(tuple(o) for o in replan_run["out_ref"])
    assert got == ref


def test_engine_apply_plan_validates_geometry(replan_run):
    b = replan_run["b"]
    bad = dataclasses.replace(replan_run["plan_a"],
                              slot_tenants=["chatty"] * 3)
    with pytest.raises(ValueError, match="geometry mismatch"):
        b.apply_plan(bad)
    with pytest.raises(ValueError, match="re-paged in place"):
        b.apply_plan(dataclasses.replace(replan_run["plan_a"],
                                         page_tokens=3))


# ----------------------------------------------------------- hypothesis ------
# Guarded import (NOT importorskip at module level — that would skip the
# differential suite above with it); CI installs hypothesis under the
# deterministic HYPOTHESIS_PROFILE=ci registered in conftest.py.

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.hmsim import build_serve_trace

    def _mk_trace(reqs, slots):
        return build_serve_trace(reqs, num_slots=slots, num_layers=2,
                                 kv_token_bytes=256.0, weight_bytes=1e4,
                                 flops_per_token=1e6)

    @st.composite
    def plan_pairs(draw):
        slots = draw(st.integers(1, 3))
        frac = draw(st.sampled_from([0.15, 0.25, 0.4]))

        def plan_one():
            n = draw(st.integers(2, 5))
            reqs = [(draw(st.integers(4, 40)), draw(st.integers(2, 8)))
                    for _ in range(n)]
            tr = _mk_trace(reqs, slots)
            return runtime.plan(tr, TPU_V5E_COST,
                                max(1.0, frac * tr.peak_kv_bytes()),
                                policy="sentinel", objective="latency",
                                lookaheads=(2, 4))
        return plan_one(), plan_one()

    @given(plan_pairs())
    @settings(max_examples=20, deadline=None)
    def test_property_delta_apply_equals_fresh_plan(pair):
        """For ANY two plans: the diff applies back to the fresh plan
        byte-identically, the delta survives a JSON round trip unchanged,
        and a no-change diff is None."""
        old, new = pair
        d = plan_delta(old, new, step=1, reason="fuzz")
        if old.to_json() == new.to_json():
            assert d is None
            return
        assert d is not None
        assert old.apply_delta(d).to_json() == new.to_json()
        # the wire format: disk and memory deltas apply identically
        wire = runtime.PlanDelta.from_json(d.to_json())
        assert wire.to_json() == d.to_json()
        assert old.apply_delta(wire).to_json() == new.to_json()
        assert plan_delta(old, old) is None
        if new.digest() != old.digest():
            with pytest.raises(ValueError, match="emission order"):
                new.apply_delta(d)

    @st.composite
    def table_programs(draw):
        slots = draw(st.integers(1, 3))
        pg = draw(st.sampled_from([2, 4]))
        pages = draw(st.integers(2, 6))
        lens = [draw(st.integers(0, pages * pg)) for _ in range(slots)]
        rounds = draw(st.lists(
            st.tuples(*[st.floats(0.0, 1.0) for _ in range(slots)]),
            min_size=1, max_size=4))
        return slots, pg, pages, lens, rounds

    @given(table_programs())
    @settings(max_examples=25, deadline=None)
    def test_property_replan_demotions_keep_page_table_green(prog):
        """The delta-application path on the layout machinery: any sequence
        of re-plan cold-boundary targets leaves ``PageTable.check()`` green,
        never promotes (boundaries are monotone), bumps ``version`` on every
        page moved, and conserves bytes (pages demoted == cold pages)."""
        from repro.models.kvcache import PageTable
        slots, pg, pages, lens, rounds = prog
        pt = PageTable(slots, pages, pg)
        for s, ln in enumerate(lens):
            for _ in range(-(-ln // pg)):
                pt.alloc(s, 0)
        pt.check()
        demoted = [0] * slots
        for targets in rounds:
            for s, f in enumerate(targets):
                # a re-plan target: page-quantized, never past the length
                target = int(f * lens[s]) // pg * pg
                before = pt.cold_tokens(s)
                while pt.cold_tokens(s) < target:
                    v0 = pt.version
                    pt.demote(s, pt.cold_pages(s))
                    demoted[s] += 1
                    assert pt.version > v0
                    pt.check()
                assert pt.cold_tokens(s) >= before   # monotone, no promote
        for s in range(slots):
            assert pt.cold_pages(s) == demoted[s]
        pt.check()

    @st.composite
    def drift_cases(draw):
        slots = 2

        def seg(i):
            base = draw(st.sampled_from([12, 80]))
            n = draw(st.integers(2, 4))
            reqs = [(base + draw(st.integers(0, 6)),
                     draw(st.integers(6, 12))) for _ in range(n)]
            return DriftSegment(f"s{i}", _mk_trace(reqs, slots))
        nseg = draw(st.integers(2, 3))
        wl = DriftWorkload("fuzz", tuple(seg(i) for i in range(nseg)))
        frac = draw(st.sampled_from([0.2, 0.35, 0.5]))
        budget = draw(st.sampled_from([0.0, None]))
        return wl, frac, budget

    @given(drift_cases())
    @settings(max_examples=10, deadline=None)
    def test_property_random_drift_schedules(case):
        """Random drift schedules through the whole loop: the delta chain
        reconstructs every applied plan, churn stays within budget, the
        report serializes, and suppression really suppresses."""
        wl, frac, budget = case
        rep = replay_drift(wl, TPU_V5E_COST, frac * wl.peak_kv_bytes(),
                           window=4, min_dwell=4, lookaheads=(2, 4),
                           policy="sentinel", churn_budget_bytes=budget)
        assert rep.churn_bytes <= rep.churn_budget_bytes
        assert rep.online_s > 0 and rep.clairvoyant_s > 0
        p = rep.plan0
        for ev in rep.events:
            if ev.applied:
                p = p.apply_delta(ev.delta)
                assert p.to_json() == ev.plan.to_json()
        if budget == 0.0:
            assert all(e.churn_bytes == 0.0 for e in rep.events
                       if e.applied)
        import json
        json.loads(rep.to_json())          # the report is wire-clean
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI installs it; the "
                             "differential suite above still ran)")
    def test_property_suites_need_hypothesis():
        pass
