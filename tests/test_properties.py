"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.allocator import PAGE, pack_pages
from repro.core.hardware import HWSpec
from repro.core.hmsim import build_units, simulate_sentinel, simulate_static
from repro.core.profiler import DataObject, TraceProfile
from repro.optim.adamw import OptConfig, compress_decompress, schedule
from repro.sharding import AxisRules


# ----------------------------------------------------------- strategies ----

@st.composite
def data_objects(draw, max_steps=16):
    n = draw(st.integers(2, 40))
    out = []
    for uid in range(n):
        birth = draw(st.integers(0, max_steps - 1))
        death = draw(st.integers(birth, max_steps - 1))
        size = draw(st.integers(1, 64 * 1024))
        reads = draw(st.integers(0, 5))
        accesses = sorted({birth, death} |
                          set(draw(st.lists(st.integers(birth, death),
                                            max_size=3))))
        out.append(DataObject(uid, size, birth, death, reads, "activation",
                              (size,), "int8", accesses, prim="dot_general"))
    return out


def make_profile(objs, steps=16):
    p = TraceProfile(num_periods=steps // 2, num_steps=steps, objects=objs)
    for s in range(steps):
        from repro.core.profiler import LayerStats
        p.layers[s] = LayerStats(s, flops=1e9, bytes_accessed=1e6)
    return p


HW = HWSpec("t", peak_flops=1e12, fast_bw=100e9, slow_bw=20e9, mig_bw=20e9,
            fast_bytes=1e9)


# ---------------------------------------------------------------- tests ----

@given(data_objects())
@settings(max_examples=30, deadline=None)
def test_pack_pages_invariants(objs):
    for mode in ("original", "profiled", "sentinel"):
        pages, omap = pack_pages(objs, mode)
        # every object mapped, no page over capacity for shared pages
        assert set(omap) == {o.uid for o in objs}
        for p in pages:
            small = [o for o in p.objects if o.size < PAGE]
            if len(p.objects) > 1:
                assert sum(o.size for o in small) <= PAGE
        # footprint >= raw bytes
        assert len(pages) * PAGE >= sum(o.size for o in objs) - PAGE


@given(data_objects())
@settings(max_examples=30, deadline=None)
def test_sentinel_packing_no_false_sharing(objs):
    """Sentinel groups by (birth, death): no page mixes different lifetimes."""
    pages, _ = pack_pages(objs, "sentinel")
    for p in pages:
        if len(p.objects) > 1:
            sigs = {(o.birth, o.death) for o in p.objects}
            assert len(sigs) == 1


@given(data_objects(), st.integers(1, 8),
       st.floats(0.05, 1.0))
@settings(max_examples=25, deadline=None)
def test_sim_step_time_at_least_compute(objs, mi, frac):
    prof = make_profile(objs)
    total = sum(o.size for o in objs)
    r = simulate_sentinel(prof, HW, frac * max(total, 1), mi)
    assert r.step_time >= r.compute_time * 0.999
    fast = simulate_static(prof, HW, "fast")
    slow = simulate_static(prof, HW, "slow")
    assert fast.step_time <= slow.step_time
    # bounded by all-slow plus migration overheads
    assert r.step_time <= slow.step_time * 2 + r.stall_time + 1.0


@given(data_objects(), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_sim_infinite_fast_is_free(objs, mi):
    prof = make_profile(objs)
    r = simulate_sentinel(prof, HW, 1e18, mi)
    fast = simulate_static(prof, HW, "fast")
    assert abs(r.step_time - fast.step_time) <= \
        fast.step_time * 0.01 + r.migrations * HW.mig_overhead + 1e-9


@given(st.lists(st.floats(-1e3, 1e3), min_size=4, max_size=64),
       st.lists(st.floats(-10, 10), min_size=4, max_size=64))
@settings(max_examples=50, deadline=None)
def test_error_feedback_conservation(g, ef):
    """Quantize+error-feedback must conserve mass: deq + ef' == g + ef."""
    n = min(len(g), len(ef))
    g = jnp.asarray(g[:n], jnp.float32)
    ef = jnp.asarray(ef[:n], jnp.float32)
    deq, ef2 = compress_decompress(g, ef)
    np.testing.assert_allclose(np.asarray(deq + ef2), np.asarray(g + ef),
                               rtol=1e-5, atol=1e-4)


@given(st.integers(0, 20_000))
@settings(max_examples=50, deadline=None)
def test_schedule_bounded(step):
    cfg = OptConfig(lr=1e-3, warmup_steps=100, total_steps=10_000)
    lr = float(schedule(cfg, step))
    assert 0.0 <= lr <= cfg.lr * 1.0001


@st.composite
def page_table_ops(draw, slots=4, pages_per_slot=4, max_ops=40):
    """A random but always-legal op sequence over a PageTable: refill with a
    fresh request, share a prefix from a donor, write (CoW when shared),
    demote the boundary page, free."""
    ops = []
    for _ in range(draw(st.integers(1, max_ops))):
        ops.append((draw(st.sampled_from(
            ["refill", "share", "write", "demote", "free"])),
            draw(st.integers(0, slots - 1)),
            draw(st.integers(0, pages_per_slot - 1))))
    return ops


@given(page_table_ops())
@settings(max_examples=40, deadline=None)
def test_page_table_sharing_invariants(ops):
    """Random alloc/share/write/demote/free: refcounts never negative, the
    cold-prefix invariant and ``check()`` hold after every op, and every
    slot's *logical* content (who originally wrote each page) survives
    CoW, twin-deduped demotion, and refcounted frees."""
    from repro.models.kvcache import PageTable
    SLOTS, NP, PG = 4, 4, 8
    pt = PageTable(SLOTS, NP, PG)
    hot_data, cold_data = {}, {}        # phys -> content token
    expect = [[None] * NP for _ in range(SLOTS)]   # logical content
    stamp = 0

    def store(s, i):
        return cold_data if pt.tier[s][i] == 1 else hot_data

    for op, s, i in ops:
        stamp += 1
        if op == "refill":
            pt.free_slot(s)
            expect[s] = [None] * NP
            n = i + 1                            # 1..NP fresh pages
            for j in range(n):
                if not pt.hot_free:
                    break
                pt.alloc(s, 0)
                hot_data[pt.table[s][j]] = ("w", s, stamp, j)
                expect[s][j] = ("w", s, stamp, j)
        elif op == "share":
            donor = (s + 1) % SLOTS
            if pt.n_pages[s] == 0 and pt.n_pages[donor] > 0:
                n = min(i + 1, pt.n_pages[donor])
                pt.share(s, donor, n)
                expect[s] = list(expect[donor][:n]) + [None] * (NP - n)
        elif op == "write" and i < pt.n_pages[s]:
            r = pt.cow(s, i)
            if r is not None:                    # engine copies page data
                src, new, tier = r
                d = cold_data if tier == 1 else hot_data
                d[new] = d[src]
            store(s, i)[pt.table[s][i]] = ("w", s, stamp, i)
            expect[s][i] = ("w", s, stamp, i)
        elif op == "demote":
            b = pt.cold_pages(s)
            if b < pt.n_pages[s] and pt.cold_free:
                cold_phys, src, copied = pt.demote(s, b)
                if copied:
                    cold_data[cold_phys] = hot_data[src]
        elif op == "free":
            pt.free_slot(s)
            expect[s] = [None] * NP
        pt.check()                               # invariants after EVERY op
        assert all(r >= 0 for r in pt.hot_ref + pt.cold_ref)
        for sl in range(SLOTS):
            assert pt.cold_pages(sl) * PG == pt.cold_tokens(sl)
            for j in range(pt.n_pages[sl]):
                if expect[sl][j] is not None:
                    assert store(sl, j)[pt.table[sl][j]] == expect[sl][j], \
                        (sl, j, "content lost through share/CoW/demote")


@given(st.integers(1, 4), st.integers(1, 4),
       st.lists(st.sampled_from(["batch", "mlp", "vocab", None, "embed"]),
                min_size=1, max_size=4))
@settings(max_examples=50, deadline=None)
def test_axis_rules_spec_valid(d, m, logical):
    """spec() never repeats a mesh axis and respects divisibility."""
    mesh = jax.sharding.AbstractMesh((d, m), ("data", "model"))
    rules = AxisRules(mesh, {"batch": "data", "mlp": "model",
                             "vocab": "model", "embed": None})
    shape = tuple(np.random.default_rng(0).integers(1, 64, len(logical)))
    spec = rules.spec(tuple(logical), shape)
    flat = [a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat))
    for dim, entry in zip(shape, spec):
        if entry is not None:
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0
