"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2 import ssd

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,Sq,Skv,H,KVH,D", [
    (2, 128, 128, 4, 2, 64),
    (1, 64, 256, 8, 8, 32),
    (2, 100, 100, 6, 2, 64),      # non-block-multiple seq
    (1, 1, 160, 4, 1, 64),        # single query
    (1, 32, 32, 2, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, Sq, Skv, H, KVH, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KVH, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KVH, D), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert jnp.max(jnp.abs(out.astype(jnp.float32) -
                           want.astype(jnp.float32))) < tol


@pytest.mark.parametrize("window,cap", [(0, 0.0), (16, 0.0), (0, 50.0),
                                        (8, 30.0)])
def test_flash_attention_window_softcap(window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out = flash_attention(q, k, v, window=window, softcap_val=cap,
                          block_q=16, block_k=16, interpret=True)
    want = ref.attention_ref(q, k, v, window=window, softcap_val=cap)
    assert jnp.max(jnp.abs(out - want)) < 1e-4


@pytest.mark.parametrize("B,S,H,KVH,D,w", [
    (2, 256, 8, 2, 64, 0), (1, 100, 4, 4, 32, 0), (3, 512, 8, 1, 64, 64),
    (2, 64, 16, 8, 128, 16),
])
def test_decode_attention_matches_oracle(B, S, H, KVH, D, w):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, KVH, D))
    vc = jax.random.normal(ks[2], (B, S, KVH, D))
    lengths = jnp.array([S // 2 + 3 * i + 1 for i in range(B)], jnp.int32)
    out = decode_attention(q, kc, vc, lengths, window=w, block_k=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths, window=w)
    assert jnp.max(jnp.abs(out - want)) < 1e-4


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 4, 16, 16, 16), (1, 128, 8, 32, 64, 32), (2, 96, 2, 8, 32, 32),
])
def test_ssd_kernel_matches_sequential_oracle(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y0, h0 = ref.ssd_ref(x, dt, A, Bm, Cm)
    y1, h1 = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk)
    y2, h2 = ssd(x, dt, A, Bm, Cm, chunk=chunk, block_heads=min(2, H),
                 interpret=True)
    assert jnp.max(jnp.abs(y0 - y1)) < 1e-3
    assert jnp.max(jnp.abs(y0 - y2)) < 1e-3
    assert jnp.max(jnp.abs(h0 - h1)) < 1e-3
    assert jnp.max(jnp.abs(h0 - h2)) < 1e-3


def test_ssd_decode_continues_scan():
    """prefill state -> decode steps == one long scan."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 1, 32, 2, 8, 16
    x = jax.random.normal(ks[0], (B, S + 4, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 4, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S + 4, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S + 4, N)) * 0.5
    y_all, _ = ref.ssd_ref(x, dt, A, Bm, Cm)
    _, h = ref.ssd_ref(x[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S])
    for t in range(S, S + 4):
        y_t, h = ref.ssd_decode_ref(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        assert jnp.max(jnp.abs(y_t - y_all[:, t])) < 1e-4


def test_mlstm_stability_long_sequence():
    """Stabilized gates: no overflow even with extreme input-gate logits."""
    ks = jax.random.split(KEY, 5)
    B, S, H, Dk, Dv = 1, 64, 2, 8, 8
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    log_i = jax.random.normal(ks[3], (B, S, H)) * 10.0   # extreme
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)))
    h, (C, n, m) = ref.mlstm_ref(q, k, v, log_i, log_f)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(C).all())


@pytest.mark.parametrize("B,S,H,Dk,Dv,chunk", [
    (2, 64, 2, 8, 16, 16), (1, 128, 4, 16, 16, 32), (2, 96, 3, 8, 8, 8),
])
def test_mlstm_chunked_matches_sequential(B, S, H, Dk, Dv, chunk):
    """Chunkwise-parallel mLSTM (the xlstm §Perf lever) == sequential scan."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    li = jax.random.normal(ks[3], (B, S, H)) * 2
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 1)
    h0, s0 = ref.mlstm_ref(q, k, v, li, lf)
    h1, s1 = ref.mlstm_chunked_ref(q, k, v, li, lf, chunk=chunk)
    assert jnp.max(jnp.abs(h0 - h1)) < 2e-4
    # states are stabilizer-scaled; compare through a continuation run
    h0c, _ = ref.mlstm_ref(q, k, v, li, lf, state=s0)
    h1c, _ = ref.mlstm_ref(q, k, v, li, lf, state=s1)
    assert jnp.max(jnp.abs(h0c - h1c)) < 2e-4


def test_slstm_finite_and_recurrent():
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (2, 32, 2, 4, 8))
    r = jax.random.normal(ks[1], (2, 4, 8, 8)) * 0.1
    h, state = ref.slstm_ref(x, r_ifzo=r)
    assert h.shape == (2, 32, 2, 8)
    assert bool(jnp.isfinite(h).all())
    # recurrence matters: zeroing r changes the output
    h2, _ = ref.slstm_ref(x, r_ifzo=jnp.zeros_like(r))
    assert not jnp.allclose(h, h2)
