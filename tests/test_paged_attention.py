"""ops.paged_decode_attention wired into the model attention layer behind
``cfg.use_paged_decode``: decode reads KV through the tiered page pools
(hot/cold + per-slot page table) instead of the dense masked-merge view,
and the results are parity with the masked-merge path."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import runtime
from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.models import kvcache, model
from repro.models.layers import split_params
from repro.serve import engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_paged_decode_logits_parity(setup):
    """One decode step: logits through the page pools match the dense
    masked-merge path (same values, different read layout/reduction)."""
    cfg, params = setup
    B, S, page = 2, 16, 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, 7), 0,
                                 cfg.vocab_size).astype(jnp.int32)
    _, caches = model.prefill(params, cfg, {"tokens": prompts}, max_seq=S)
    lengths = jnp.array([7, 7], jnp.int32)
    tok = jnp.array([[3], [5]], jnp.int32)

    dense_logits, _, _ = model.forward(
        params, cfg, {"tokens": tok}, caches=caches, cache_index=lengths,
        decode=True)
    cfg_paged = dataclasses.replace(cfg, use_paged_decode=True)
    paged_logits, _, _ = model.forward(
        params, cfg_paged, {"tokens": tok}, caches=caches,
        cache_index=lengths, decode=True,
        paged_view={"boundaries": [4, 0], "page_tokens": page})
    assert jnp.allclose(dense_logits, paged_logits, atol=1e-4, rtol=1e-4)
    # the flag alone (no page view provided) must not change the path
    flag_only, _, _ = model.forward(
        params, cfg_paged, {"tokens": tok}, caches=caches,
        cache_index=lengths, decode=True)
    assert jnp.array_equal(dense_logits, flag_only)


def test_paged_decode_cold_rows_are_read_from_pools(setup):
    """The kernel path really reads through the page table: scribbling over
    the dense rows of a *hot* page changes the output, while the packed
    pools pin which physical page each logical page resolves to."""
    cfg, params = setup
    B, S, page = 2, 16, 4
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, 9), 0,
                                 cfg.vocab_size).astype(jnp.int32)
    _, caches = model.prefill(params, cfg, {"tokens": prompts}, max_seq=S)
    lengths = jnp.array([9, 9], jnp.int32)
    tok = jnp.array([[3], [5]], jnp.int32)
    cfg_paged = dataclasses.replace(cfg, use_paged_decode=True)
    pv = {"boundaries": [8, 4], "page_tokens": page}
    a, _, _ = model.forward(params, cfg_paged, {"tokens": tok}, caches=caches,
                            cache_index=lengths, decode=True, paged_view=pv)
    # zero the K rows the slots actually attend to -> output must change
    wiped = jax.tree.map(
        lambda l: l.at[..., :, :9, :].set(0.0)
        if l.ndim >= 3 and l.shape[-2] == S else l, caches)
    b, _, _ = model.forward(params, cfg_paged, {"tokens": tok}, caches=wiped,
                            cache_index=lengths, decode=True, paged_view=pv)
    assert not jnp.allclose(a, b, atol=1e-4)


def test_paged_kernel_batcher_matches_reference(setup):
    """End to end: ContinuousBatcher(paged=True) with use_paged_decode
    produces exactly the tokens of the all-HBM reference run."""
    cfg, params = setup
    max_seq, slots = 32, 2
    requests = [(7, 6), (9, 5), (6, 7)]
    trace = engine.serve_trace_for(get_config("smollm-360m"), requests,
                                   slots=slots, layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def run(c, p, paged=False):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged)
        key = jax.random.PRNGKey(3)
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(sub, (plen,), 0,
                                        cfg.vocab_size).astype(jnp.int32), d)
        return b.run(), b

    base, _ = run(cfg, None)
    cfg_kernel = dataclasses.replace(cfg, use_paged_decode=True)
    paged, b = run(cfg_kernel, plan, paged=True)
    assert base == paged
    assert len(base) == len(requests)
    # the persistent pools really are the cache: boundaries advanced in the
    # page table, pool data moved, and nothing was ever dense-re-packed
    assert b.pool is not None and b.paged is None
    assert any(b.ptable.cold_tokens(s) > 0 for s in range(slots))
    assert b.pool.stats["repacks"] == 0
    assert b.pool.stats["page_copies"] > 0
    b.ptable.check()


def test_paged_view_respects_page_table_tiering(setup):
    """pack_kv_pools splits at the per-slot boundaries the engine derives:
    cold pages land in the cold pool, and the table covers the buffer."""
    from repro.kernels.paged_decode import pack_kv_pools
    cfg, _ = setup
    B, S, page = 2, 16, 4
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    kh, vh, kc, vc, table, tier = pack_kv_pools(k, v, [8, 4], page)
    assert int(tier.sum()) == (8 + 4) // page       # cold pages counted
    assert kc.shape[0] == (8 + 4) // page
    assert table.shape == (B, S // page)
    # every logical page resolves inside its pool
    for b in range(B):
        for i in range(S // page):
            pool = kc if int(tier[b, i]) else kh
            assert 0 <= int(table[b, i]) < pool.shape[0]
