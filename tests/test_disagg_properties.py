"""Property-based suite (hypothesis) for ``MeshPageTable``.

Random but always-legal op programs — alloc/share/CoW/demote/free plus
cross-device ``migrate_slot`` — fuzz the three invariants the mesh page
table exists for: (1) namespace uniqueness — every global slot names
exactly one ``(device, local_slot)`` and round-trips; (2) per-device
refcount/cold-prefix structure (each table's own ``check()``) after every
op; (3) byte conservation — the mesh's edge ledgers always equal an
independently-kept account of what the program itself moved, hot pages on
the device↔device edge, cold pages inside host memory, never both.
"""
import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.models.kvcache import MeshPageTable, PageTable

DEVS, SLOTS, NP, PG = 3, 2, 4, 8
PAGE_BYTES = float(PG * 64)


@st.composite
def mesh_ops(draw, max_ops=40):
    ops = []
    for _ in range(draw(st.integers(1, max_ops))):
        ops.append((draw(st.sampled_from(
            ["refill", "share", "write", "demote", "free", "migrate"])),
            draw(st.integers(0, DEVS * SLOTS - 1)),
            draw(st.integers(0, DEVS * SLOTS - 1)),
            draw(st.integers(0, NP - 1))))
    return ops


@given(mesh_ops())
@settings(max_examples=40, deadline=None)
def test_mesh_page_table_invariants(ops):
    """Random alloc/share/CoW/demote/free/cross-device-migrate programs:
    per-device structure and the mesh byte ledgers hold after every op, and
    the ledgers equal an independent account of what the program moved."""
    m = MeshPageTable([PageTable(SLOTS, NP, PG) for _ in range(DEVS)],
                      page_bytes=PAGE_BYTES)
    my_edges, my_host = {}, 0.0              # the test's own books

    for op, a, b, i in ops:
        if op == "refill":
            m.free_slot(a)
            for _ in range(i + 1):
                t, _, _ = m._at(a)
                if not t.hot_free:
                    break
                m.alloc(a, 0)
        elif op == "share":
            da, _ = m.owner(a)
            db, _ = m.owner(b)
            if da == db and a != b and m.n_pages(a) == 0 \
                    and m.n_pages(b) > 0:
                m.share(a, b, min(i + 1, m.n_pages(b)))
        elif op == "write":
            if i < m.n_pages(a):
                m.cow(a, i)
        elif op == "demote":
            t, _, s = m._at(a)
            bnd = t.cold_pages(s)
            if bnd < t.n_pages[s] and t.cold_free:
                t.demote(s, bnd)
        elif op == "free":
            m.free_slot(a)
        elif op == "migrate":
            da, _ = m.owner(a)
            db, _ = m.owner(b)
            n, n_cold = m.n_pages(a), m.cold_pages(a)
            n_hot = n - n_cold
            dt, _, ds = m._at(b)
            fits = (da != db and n > 0
                    and dt.n_pages[ds] + n <= dt.pages_per_slot
                    and not (n_cold and dt.n_pages[ds] > dt.cold_pages(ds))
                    and len(dt.hot_free) >= n_hot
                    and len(dt.cold_free) >= n_cold)
            if fits:
                out = m.migrate_slot(a, b)
                assert out["pages"] == n
                if n_hot:                    # cold-only moves touch no edge
                    key = (m.names[da], m.names[db])
                    my_edges[key] = my_edges.get(key, 0.0) \
                        + n_hot * PAGE_BYTES
                my_host += n_cold * PAGE_BYTES
                assert out["hot_bytes"] == n_hot * PAGE_BYTES
                assert out["cold_bytes"] == n_cold * PAGE_BYTES
                assert m.n_pages(a) == 0

        m.check()                            # ledgers + per-table structure
        assert m.edge_bytes == my_edges, "edge ledger drifted from the " \
            "test's own account"
        assert m.host_internal_bytes == my_host
        for g in range(m.slots):             # namespace stays a bijection
            d, s = m.owner(g)
            assert m.gslot(d, s) == g
        total = sum(t.pages_in_use() for t in m.tables)
        assert total == m.pages_in_use()
