"""Multi-shard disaggregation on a forced 4-device host mesh.

``XLA_FLAGS=--xla_force_host_platform_device_count=4`` must be set before
jax imports, so the engine scenario runs in ONE subprocess (2 prefill + 2
decode devices) that prints a JSON record; the tests here assert its keys.
The scenario covers the acceptance gates:

  * 2-shard decode emits tokens bit-identical to the colocated all-HBM
    engine, with zero dense re-packs;
  * every (src, dst) edge of the ``MeshPageTable`` ledger matches
    ``predict_pool_counters`` integer-exactly — shared-prefix admits
    (private tail only) and ``apply_plan`` slot re-homings included —
    and the mesh's byte-conservation ``check()`` holds;
  * tensor-parallel prefill (opt-in) produces numerically-equivalent
    prefill logits (allclose; NOT bit-identical — fp32 psum reduction
    order differs across the group) and the same greedy tokens.

Everything that doesn't need live devices — the slot->device packing
fuzz over the pure-python replay, plan/engine geometry validation, and
the ``price_disagg`` channel-recovery regressions — runs in-process.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCENARIO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import runtime
    from repro.configs.base import get_config
    from repro.core.hardware import TPU_V5E
    from repro.launch.mesh import disagg_groups
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine
    from repro.serve.disagg import DisaggregatedEngine
    from repro.serve.engine import predict_pool_counters, serve_trace_for

    rec = {}
    devs = jax.devices()
    pre, dec = disagg_groups(devs)
    rec["groups"] = [len(pre), len(dec)]

    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              use_paged_decode=True)
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 4
    key = jax.random.PRNGKey(3)
    key, kp = jax.random.split(key)
    pref = [int(t) for t in jax.device_get(
        jax.random.randint(kp, (9,), 0, cfg.vocab_size))]
    reqs = []
    for plen, gen in [(12, 6), (13, 5), (11, 6), (12, 5), (14, 4), (12, 6)]:
        key, k = jax.random.split(key)
        tail = [int(t) for t in jax.device_get(
            jax.random.randint(k, (plen - 9,), 0, cfg.vocab_size))]
        reqs.append((tuple(pref + tail), gen, None, "sys"))
    trace = serve_trace_for(get_config("smollm-360m"),
                            [(len(r[0]), r[1]) for r in reqs],
                            slots=slots, layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=16, page_tokens=4,
                               slot_hot_windows=[8, 8, 8, 8],
                               slot_devices=[0, 0, 1, 1])
    plan2 = dataclasses.replace(plan, slot_hot_windows=[4, 8, 4, 8],
                                slot_devices=[1, 0, 1, 0])

    def drive(b, replan_at=None):
        for toks, gen, tn, pk in reqs:
            b.submit(jnp.asarray(toks, jnp.int32), gen,
                     prefix_key=pk, tenant=tn)
        outs, step = [], 0
        while b.queue or b._jobs or any(b.active):
            if step == replan_at:
                b.apply_plan(plan2)
            if not b.step():
                break
            step += 1
            for i in range(b.B):
                if not b.active[i] and b.outputs[i]:
                    outs.append(b.outputs[i])
                    b.outputs[i] = []
        return outs

    # colocated all-HBM reference: same admission schedule, no tiering
    ref = engine.ContinuousBatcher(
        params, cfg, slots, max_seq, paged=True,
        plan=dataclasses.replace(plan, hot_window=max_seq,
                                 slot_hot_windows=None, slot_devices=None))
    out_ref = drive(ref)

    b2 = DisaggregatedEngine(params, cfg, slots, max_seq, plan=plan,
                             devices=devs)
    rec["n_shards"] = b2.n_shards
    out_2 = drive(b2, replan_at=3)
    rec["bit_identical"] = out_ref == out_2
    rec["repacks"] = b2.counters()["repacks"]
    b2.mesh_table.check()
    rec["ledger_balanced"] = True
    c = b2.counters()
    pred = predict_pool_counters(
        reqs, plan, slots=slots, max_seq=max_seq,
        page_tokens=b2.page_tokens, row_bytes=b2._row_bytes,
        dense_admit=True, plan_schedule=[(3, plan2)])
    edges_eng = {f"{s}->{d}": v
                 for (s, d), v in c["edge_migration_bytes"].items()}
    edges_pred = {f"{s}->{d}": v
                  for (s, d), v in pred["edge_migration_bytes"].items()}
    rec["edges_eng"], rec["edges_pred"] = edges_eng, edges_pred
    rec["xdev_eng"] = b2.xdev_migration_bytes
    rec["xdev_pred"] = pred["xdev_migration_bytes"]
    rec["dev_peak_eng"] = c["device_hot_peak"]
    rec["dev_peak_pred"] = pred["device_hot_peak"]
    rec["mig_eng"] = b2.sim_migration_bytes
    rec["mig_pred"] = pred["migration_bytes"]
    rec["series_match"] = (c["step_migration_bytes"]
                           == pred["step_migration_bytes"])

    # tensor-parallel prefill: numerically equivalent, same greedy tokens
    b_tp = DisaggregatedEngine(params, cfg, slots, max_seq, plan=plan,
                               devices=devs, tp_prefill=True)
    rec["tp_on"] = bool(b_tp.tp_prefill)
    toks = jnp.asarray(reqs[0][0], jnp.int32)
    last_1, _ = b2._prefill(None, {"tokens": toks[None]})
    last_tp, _ = b_tp._prefill(None, {"tokens": toks[None]})
    a, b = jax.device_get(last_1), jax.device_get(last_tp)
    rec["tp_allclose"] = bool(np.allclose(a, b, atol=1e-4, rtol=1e-4))
    rec["tp_bit_identical"] = bool((a == b).all())
    out_tp = drive(DisaggregatedEngine(params, cfg, slots, max_seq,
                                       plan=plan, devices=devs,
                                       tp_prefill=True))
    rec["tp_tokens_equal"] = out_ref == out_tp
    print(json.dumps(rec))
""")


@pytest.fixture(scope="module")
def scenario():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCENARIO],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_groups_split_two_two(scenario):
    assert scenario["groups"] == [2, 2] and scenario["n_shards"] == 2


def test_two_shards_bit_identical_zero_repacks(scenario):
    assert scenario["bit_identical"]
    assert scenario["repacks"] == 0


def test_edge_ledger_matches_replay_exactly(scenario):
    """Every (src, dst) edge — shared-prefix admit streams AND the
    apply_plan re-homings — integer-exact vs predict_pool_counters."""
    assert scenario["ledger_balanced"]
    assert scenario["edges_eng"] == scenario["edges_pred"]
    assert scenario["xdev_eng"] == scenario["xdev_pred"]
    assert any("dev0->dev1" in k or "dev1->dev0" in k
               for k in scenario["edges_eng"]), "no re-homing exercised"


def test_replay_parity_across_shards(scenario):
    assert scenario["dev_peak_eng"] == scenario["dev_peak_pred"]
    assert scenario["mig_eng"] == scenario["mig_pred"]
    assert scenario["series_match"]


def test_tp_prefill_equivalent_not_bitexact(scenario):
    """TP prefill over the prefill group: allclose logits and the same
    greedy tokens.  Bit-identity is NOT promised (measured: ~1e-6 drift
    from the row-parallel psum reduction order), which is why tp_prefill
    is opt-in and the bit-identity gates above run with it off."""
    assert scenario["tp_on"]
    assert scenario["tp_allclose"]
    assert scenario["tp_tokens_equal"]


# --------------------------------------------------- in-process (no jax) ----

def test_validate_slot_devices_geometry():
    from repro.runtime.plan import validate_slot_devices
    assert validate_slot_devices([0, 1, 0], 3, 2) == [0, 1, 0]
    with pytest.raises(ValueError):
        validate_slot_devices([0, 1], 3, 2)        # wrong length
    with pytest.raises(ValueError):
        validate_slot_devices([0, 2, 0], 3, 2)     # shard out of range
    with pytest.raises(ValueError):
        validate_slot_devices([0, True, 0], 3, 2)  # bool is not a shard id


def test_plan_serving_disagg_rejects_chunked():
    from repro.core.hmsim import build_serve_trace
    from repro.runtime import TPU_V5E_COST, plan_serving
    trace = build_serve_trace([(16, 8), (20, 6)], num_slots=2,
                              num_layers=4, kv_token_bytes=64)
    with pytest.raises(ValueError, match="chunked"):
        plan_serving(trace, TPU_V5E_COST, 0.5 * trace.peak_kv_bytes(),
                     disagg=True, prefill_chunk_tokens=8)


def test_plan_serving_places_slots_on_shards():
    from repro.core.hmsim import build_serve_trace
    from repro.runtime import TPU_V5E_COST, plan_serving
    trace = build_serve_trace([(48, 12), (64, 8), (40, 16), (56, 10)],
                              num_slots=4, num_layers=4, kv_token_bytes=64)
    plan = plan_serving(trace, TPU_V5E_COST, 0.5 * trace.peak_kv_bytes(),
                        decode_devices=2)
    assert plan.slot_devices is not None
    assert len(plan.slot_devices) == 4
    assert set(plan.slot_devices) <= {0, 1}
    # both shards get work on a 4-slot stream
    assert len(set(plan.slot_devices)) == 2


def test_price_disagg_recovers_tokens_without_flops():
    """Regression: a flops-less trace used to price the KV stream as zero.
    The admit byte channel (extra_fast = computed prefill tokens x KV row)
    recovers the same edge bytes as the flops channel."""
    from repro.core.hmsim import build_serve_trace
    from repro.runtime import TPU_V5E_COST
    from repro.serve.disagg import price_disagg
    reqs = [(480, 24), (512, 16), (448, 32), (500, 20)]
    trace = build_serve_trace(reqs, num_slots=4, num_layers=8,
                              kv_token_bytes=256)
    fast = 0.25 * trace.peak_kv_bytes()
    attributed = price_disagg(trace, TPU_V5E_COST, fast)
    flopless = price_disagg(
        dataclasses.replace(trace, flops_per_token=0.0), TPU_V5E_COST, fast)
    assert attributed["edge_bytes"] > 0
    assert flopless["edge_bytes"] == attributed["edge_bytes"]


def test_price_disagg_raises_on_unattributable_stream():
    from repro.core.hmsim import build_serve_trace
    from repro.runtime import TPU_V5E_COST
    from repro.serve.disagg import price_disagg
    trace = build_serve_trace([(64, 8)], num_slots=1, num_layers=4,
                              kv_token_bytes=64)
    dead = dataclasses.replace(trace, flops_per_token=0.0, kv_token_bytes=0)
    with pytest.raises(ValueError, match="cannot attribute"):
        price_disagg(dead, TPU_V5E_COST, 1e6)


def test_price_disagg_multi_shard_mesh():
    from repro.core.hmsim import build_serve_trace
    from repro.runtime import TPU_V5E_COST
    from repro.serve.disagg import price_disagg
    reqs = [(480, 24), (512, 16), (448, 32), (500, 20)]
    trace = build_serve_trace(reqs, num_slots=4, num_layers=8,
                              kv_token_bytes=256)
    fast = 0.25 * trace.peak_kv_bytes()
    r = price_disagg(trace, TPU_V5E_COST, fast, decode_devices=2)
    names = {n.name for n in r["graph"].nodes}
    assert names == {"dev0", "dev1", "dev2", "host"}
    assert r["disagg"].tokens_per_s > 0
    with pytest.raises(ValueError):
        price_disagg(trace, TPU_V5E_COST, fast, decode_devices=0)


# ------------------------------------------ packing fuzz (pure replay) ------
# (the hypothesis-driven variants live in test_disagg_packing_properties.py,
# gated on the optional dep; this seeded sweep keeps the property exercised
# everywhere)

def _replay_packing_invariants(slots, n_dev, packing, reqs):
    """For ANY legal packing: every admit stream lands on the slot's owning
    shard, the prefill-edge total equals xdev_migration_bytes, and the
    per-device hot peaks only name devices the packing uses."""
    from repro import runtime
    from repro.core.hardware import TPU_V5E
    from repro.core.hmsim import build_serve_trace
    from repro.serve.engine import predict_pool_counters
    trace = build_serve_trace(reqs, num_slots=slots, num_layers=4,
                              kv_token_bytes=64)
    plan = runtime.plan(trace, TPU_V5E, 0.3 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, page_tokens=4, hot_window=8,
                               slot_hot_windows=None)
    pred = predict_pool_counters(reqs, plan, slots=slots, max_seq=32,
                                 page_tokens=4, row_bytes=64.0,
                                 dense_admit=True, slot_devices=packing)
    edges = pred["edge_migration_bytes"]
    # an explicit packing names shards dev{d} even when there is one
    used = {f"dev{d}" for d in packing}
    for (src, dst), v in edges.items():
        assert src == "prefill" and dst in used
        assert v >= 0 and v == int(v)
    assert sum(edges.values()) == pred["xdev_migration_bytes"]
    assert set(pred["device_hot_peak"]) <= used


def test_replay_edge_ledger_under_random_packings():
    import random
    rng = random.Random(7)
    for _ in range(25):
        slots = rng.randint(2, 4)
        n_dev = rng.randint(1, 3)
        packing = [rng.randrange(n_dev) for _ in range(slots)]
        reqs = [(rng.randint(5, 14), rng.randint(3, 7))
                for _ in range(rng.randint(slots, slots + 3))]
        _replay_packing_invariants(slots, n_dev, packing, reqs)


def test_pack_slots_legal_and_balanced():
    import random
    from repro.runtime.plan import pack_slots, validate_slot_devices
    rng = random.Random(11)
    for _ in range(60):
        slots = rng.randint(1, 8)
        n_dev = rng.randint(1, 4)
        weights = [rng.uniform(0.0, 1e6) for _ in range(slots)]
        out = pack_slots(weights, n_dev)
        assert validate_slot_devices(out, slots, n_dev) == out
        counts = [out.count(d) for d in range(n_dev)]
        if slots >= n_dev:
            # LPT never leaves a device idle while another stacks up
            assert min(counts) >= 1 or max(counts) <= 1
