"""Per-slot cold boundaries end-to-end: PageTable alloc/free/splice
invariants, planner slot windows, boundary monotonicity under slot refill,
and the paged ContinuousBatcher matching the all-HBM reference while moving
fewer simulated migration bytes than the global-boundary concat path."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import planner
from repro.core.hardware import TPU_V5E
from repro.models import kvcache, model
from repro.models.layers import split_params
from repro.serve import engine


# ------------------------------------------------------------ page table ----

def test_page_table_alloc_free_splice_invariants():
    pt = kvcache.PageTable(slots=3, pages_per_slot=4, page_tokens=8)
    n_cold = pt.splice_slot(0, tokens=30, cold_tokens=16)
    pt.check()
    assert (n_cold, pt.n_pages[0], pt.cold_pages(0)) == (2, 4, 2)
    pt.splice_slot(1, tokens=9, cold_tokens=0)
    pt.check()
    assert pt.cold_tokens(1) == 0 and pt.n_pages[1] == 2
    # demotion advances the cold boundary one page at a time
    pt.demote(1, 0)
    pt.check()
    assert pt.cold_tokens(1) == 8
    # refill releases every page back to its pool
    before_hot, before_cold = len(pt.hot_free), len(pt.cold_free)
    released = pt.free_slot(0)
    pt.check()
    assert released == 4
    assert len(pt.hot_free) == before_hot + 2
    assert len(pt.cold_free) == before_cold + 2
    # splice after free reuses pages without leaking
    pt.splice_slot(0, tokens=32, cold_tokens=32)
    pt.check()
    assert pt.cold_pages(0) == 4


def test_page_table_guards():
    pt = kvcache.PageTable(slots=1, pages_per_slot=2, page_tokens=4,
                           hot_pages=2, cold_pages=1)
    pt.alloc(0, 0)
    with pytest.raises(ValueError, match="cold-prefix"):
        pt.alloc(0, 1)                    # cold after hot breaks the prefix
    pt.alloc(0, 0)
    with pytest.raises(ValueError, match="exhausted"):
        pt.alloc(0, 0)                    # pages_per_slot exhausted
    with pytest.raises(ValueError, match="not the cold boundary"):
        pt.demote(0, 1)
    pt.demote(0, 0)
    with pytest.raises(ValueError, match="cold pool exhausted"):
        pt.demote(0, 1)                   # cold pool only had one page


def test_paged_cache_merge_is_bit_identical():
    """Scribbling over hot rows below a slot's boundary must not leak into
    the merged view — cold rows are the copy of record."""
    cfg = get_config("smollm-360m").reduced()
    B, S, page = 2, 32, 8
    pc = kvcache.init_paged_cache(cfg, B, S, page, jnp.float32)
    dense = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(a.size % 89),
                                    a.shape).astype(a.dtype),
        kvcache.init_cache(cfg, B, S, jnp.float32))
    pc.hot = dense
    assert pc.demote_rows(0, 16) == 16
    assert pc.demote_rows(0, 16) == 0            # idempotent at the boundary
    pc.hot = kvcache.copy_slot_rows(
        jax.tree.map(lambda a: a, pc.hot),
        jax.tree.map(lambda a: None if a is None else jnp.full_like(a, -9.0),
                     pc.hot, is_leaf=lambda x: x is None),
        0, 0, 16, S)
    merged = pc.merged()
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(merged)):
        if a.ndim >= 3 and a.shape[-2] == S:
            assert jnp.array_equal(a, b)


# --------------------------------------------------------------- planner ----

def test_plan_serve_slot_windows():
    from repro.core import hmsim
    reqs = hmsim.synthetic_requests(12)
    trace = hmsim.build_serve_trace(reqs, num_slots=4, num_layers=8,
                                    kv_token_bytes=4096, weight_bytes=50e6,
                                    flops_per_token=2e9)
    pl = planner.plan_serve(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    assert pl.page_tokens == trace.block_tokens
    assert pl.slot_hot_windows and len(pl.slot_hot_windows) == trace.num_slots
    for w in pl.slot_hot_windows:
        assert w >= trace.block_tokens               # reserve-pool floor
        assert w % trace.block_tokens == 0           # page-quantized
    # per-slot cold boundaries: page-aligned and monotone in sequence length
    prev = -1
    for seq_len in range(0, 200, 7):
        c = pl.cold_len_slot(1, seq_len)
        assert c % pl.page_tokens == 0
        assert c >= prev
        prev = c
    # a slot serving more KV byte-seconds never gets a smaller window
    w = planner.slot_kv_weights(trace)
    order = sorted(range(len(w)), key=lambda s: w[s])
    windows = [pl.slot_hot_windows[s] for s in order]
    assert windows == sorted(windows)


# ------------------------------------------------------------------- e2e ----

@pytest.fixture(scope="module")
def served():
    """Run the same request stream through all three batcher layouts."""
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    requests = [(7, 6), (9, 5), (6, 7)]

    trace = engine.serve_trace_for(get_config("smollm-360m"), requests,
                                   slots=slots, layer_group=8)
    plan = planner.plan_serve(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    # small per-slot windows so decode actually crosses page boundaries
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def run(p, paged=False):
        b = engine.ContinuousBatcher(params, cfg, slots, max_seq, plan=p,
                                     paged=paged)
        key = jax.random.PRNGKey(3)
        boundary_log = []
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(sub, (plen,), 0,
                                        cfg.vocab_size).astype(jnp.int32), d)
        results = []
        while b.queue or any(b.active):
            if not b.step():
                break
            if paged:
                boundary_log.append((
                    [int(x) for x in b.lengths],
                    [int(x) for x in jnp.asarray(b.paged.boundaries)]))
            for i in range(b.B):
                if not b.active[i] and b.outputs[i]:
                    results.append(b.outputs[i])
                    b.outputs[i] = []
        return results, b, boundary_log

    base, _, _ = run(None)
    concat, b_concat, _ = run(plan)
    paged, b_paged, log = run(plan, paged=True)
    return base, concat, paged, b_concat, b_paged, log


def test_paged_batcher_matches_all_hbm(served):
    base, concat, paged, *_ = served
    assert base == concat == paged
    assert len(base) == 3


def test_paged_moves_fewer_bytes_than_concat(served):
    *_, b_concat, b_paged, _ = served
    assert b_paged.sim_migration_bytes > 0       # boundaries actually moved
    assert b_paged.sim_migration_bytes < b_concat.sim_migration_bytes


def test_per_slot_boundary_monotone_under_refill(served):
    """Within one residency a slot's cold boundary only advances (and stays
    page-aligned, at or below the slot's length); it resets only when the
    slot is refilled with a new request."""
    *_, b_paged, log = served
    page = b_paged.page_tokens
    assert any(any(bd > 0 for bd in bounds) for _, bounds in log)
    for (len_prev, bd_prev), (len_now, bd_now) in zip(log, log[1:]):
        for s in range(len(bd_now)):
            assert bd_now[s] % page == 0
            assert bd_now[s] <= len_now[s]
            if len_now[s] == len_prev[s] + 1:    # same residency, one decode
                assert bd_now[s] >= bd_prev[s]
    b_paged.ptable.check()


def test_paged_table_consistent_with_boundaries(served):
    """The PageTable's per-slot cold pages agree with the storage-side
    boundary vector at the end of the run."""
    *_, b_paged, _ = served
    bounds = [int(x) for x in jnp.asarray(b_paged.paged.boundaries)]
    for s in range(b_paged.B):
        assert b_paged.ptable.cold_tokens(s) == bounds[s]
