"""End-to-end behaviour tests for the whole system (paper pipeline:
profile -> plan -> execute with the planned config), plus a small-mesh
dry-run in a subprocess (the 512-device production dry-run lives in
launch/dryrun.py; this proves the same path on 8 forced host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import hmsim, planner, profiler
from repro.core.hardware import PAPER_HM
from repro.core.offload import SentinelConfig, from_plan, loss_kwargs
from repro.models import model
from repro.models.layers import split_params


def test_profile_plan_execute_pipeline(rng):
    """The full Sentinel workflow on one model: dynamic profile (1 traced
    step), MI planning, then the planned config actually executes."""
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    pshapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           params)
    batch_s = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
               "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    prof = profiler.trace_profile(
        jax.grad(lambda p, b: model.loss_fn(p, cfg, b, unroll_periods=True)),
        pshapes, batch_s, num_periods=cfg.num_periods)
    plan = planner.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    scfg = from_plan(prof, plan)
    assert cfg.num_periods % scfg.mi_periods == 0

    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss = jax.jit(lambda p, b: model.loss_fn(p, cfg, b,
                                              **loss_kwargs(scfg)))(params, batch)
    assert bool(jnp.isfinite(loss))


def test_sentinel_vs_ial_full_comparison(rng):
    """Paper Fig. 10 shape: fast-only <= sentinel < {IAL-or-slow} ceiling."""
    cfg = get_config("lstm-ptb").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    pshapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           params)
    batch_s = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    prof = profiler.trace_profile(
        jax.grad(lambda p, b: model.loss_fn(p, cfg, b, unroll_periods=True)),
        pshapes, batch_s, num_periods=cfg.num_periods)
    peak = prof.peak_bytes()
    fast = hmsim.simulate_static(prof, PAPER_HM, "fast").step_time
    slow = hmsim.simulate_static(prof, PAPER_HM, "slow").step_time
    sent = planner.plan(prof, PAPER_HM, 0.3 * peak).sim.step_time
    ial = hmsim.simulate_caching(prof, PAPER_HM, 0.3 * peak, "ial").step_time
    assert fast <= sent <= slow * 1.5
    assert sent <= ial


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """lower+compile a sharded train step on an 8-device forced-host mesh —
    the production dry-run path, scaled down to run in CI."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro import sharding as shd
        from repro.configs.base import get_config, SHAPES, ShapeConfig
        from repro.core.offload import SentinelConfig
        from repro.launch import specs

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rules = shd.tp_dp_rules(mesh)
        cfg = get_config("smollm-360m").reduced()
        shape = ShapeConfig("tiny", 64, 8, "train")
        scfg = SentinelConfig(mode="offload", mi_periods=1)
        with mesh, shd.axis_rules(rules):
            fn, args, in_sh = specs.build_train_cell(cfg, shape, rules, scfg)
            compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
            ma = compiled.memory_analysis()
            print(json.dumps({"ok": True,
                              "temp": ma.temp_size_in_bytes,
                              "flops": compiled.cost_analysis()["flops"]}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
