import os

import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS device forcing here — smoke tests and benches must see
# exactly 1 device (the dry-run sets 512 in its own process).

jax.config.update("jax_enable_x64", False)

# Deterministic hypothesis runs in CI: a registered profile with a fixed
# (derandomized) seed and no deadline, selected via HYPOTHESIS_PROFILE=ci in
# .github/workflows/ci.yml.  Guarded import: hypothesis is a dev extra, and
# environments without it must still collect the suite.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=25, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(cfg, key, B=2, S=16):
    """Batch matching an arch's modality (codebooks / vlm prefix)."""
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
        labels = toks
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        labels = toks
    batch = {"tokens": toks.astype(jnp.int32)}
    if cfg.num_codebooks:
        batch["labels"] = labels.astype(jnp.int32)
    else:
        batch["labels"] = labels.astype(jnp.int32)
    if cfg.num_prefix_tokens:
        batch["prefix_embed"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.pad(batch["labels"],
                                  ((0, 0), (cfg.num_prefix_tokens, 0)))
    return batch
