"""Physical page pools as the source of truth: PageTable refcount/CoW/share
invariants, the persistent-pool batcher doing zero dense re-packs and zero
boundary host-syncs in steady state, and copy-on-write prefix sharing
producing logits bit-identical to independent (unshared) decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import runtime
from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.models import kvcache, model
from repro.models.layers import split_params
from repro.serve import engine


# ------------------------------------------------------- table invariants ----

def test_share_maps_same_physical_pages():
    pt = kvcache.PageTable(slots=3, pages_per_slot=4, page_tokens=8)
    pt.splice_slot(0, tokens=30, cold_tokens=16)
    assert pt.share(1, 0, 3) == 3
    pt.check()
    # same physical pages, same tiers, refcount 2 everywhere shared
    for i in range(3):
        assert pt.table[1][i] == pt.table[0][i]
        assert pt.tier[1][i] == pt.tier[0][i]
        assert pt.refcount(0, i) == 2 and pt.is_shared(1, i)
    # the shared prefix inherits a valid cold-prefix pattern
    assert pt.cold_pages(1) == 2
    # a fourth, private page continues the slot normally
    pt.alloc(1, 0)
    pt.check()
    assert pt.refcount(1, 3) == 1


def test_share_guards():
    pt = kvcache.PageTable(slots=2, pages_per_slot=4, page_tokens=8)
    pt.splice_slot(0, tokens=16, cold_tokens=0)
    pt.alloc(1, 0)
    with pytest.raises(ValueError, match="empty slot"):
        pt.share(1, 0, 1)                 # dst must be empty
    pt.free_slot(1)
    with pytest.raises(ValueError, match="cannot share"):
        pt.share(1, 0, 3)                 # src only has 2 pages


def test_cow_gives_private_page_and_preserves_invariants():
    pt = kvcache.PageTable(slots=2, pages_per_slot=4, page_tokens=8)
    pt.splice_slot(0, tokens=32, cold_tokens=8)
    pt.share(1, 0, 4)
    src, new, tier = pt.cow(1, 2)
    pt.check()
    assert tier == 0 and new != src
    assert pt.table[1][2] == new and pt.table[0][2] == src
    assert pt.refcount(1, 2) == 1 and pt.refcount(0, 2) == 1
    # CoW of a *cold* shared page stays cold (cold-prefix invariant holds)
    src_c, new_c, tier_c = pt.cow(1, 0)
    pt.check()
    assert tier_c == 1 and pt.cold_pages(1) == 1
    # exclusive pages are a no-op
    assert pt.cow(1, 2) is None


def test_refcounted_free_keeps_pages_alive():
    """Freeing the donor slot must not release pages the sharer still
    references — the page returns to the free list only at refcount zero."""
    pt = kvcache.PageTable(slots=2, pages_per_slot=2, page_tokens=4)
    pt.splice_slot(0, tokens=8, cold_tokens=0)
    pt.share(1, 0, 2)
    free_before = len(pt.hot_free)
    pt.free_slot(0)
    pt.check()
    assert len(pt.hot_free) == free_before       # still referenced by slot 1
    assert all(pt.refcount(1, i) == 1 for i in range(2))
    pt.free_slot(1)
    pt.check()
    assert len(pt.hot_free) == free_before + 2   # now truly free


def test_shared_demote_moves_bytes_once():
    """N sharers demoting the same logical page produce ONE cold copy: the
    first demotion copies, later ones reuse the twin with a refcount bump."""
    pt = kvcache.PageTable(slots=3, pages_per_slot=2, page_tokens=4)
    pt.splice_slot(0, tokens=8, cold_tokens=0)
    pt.share(1, 0, 2)
    pt.share(2, 0, 2)
    c0, src0, copied0 = pt.demote(0, 0)
    c1, src1, copied1 = pt.demote(1, 0)
    c2, src2, copied2 = pt.demote(2, 0)
    pt.check()
    assert copied0 and not copied1 and not copied2
    assert c0 == c1 == c2 and src0 == src1 == src2
    assert pt.cold_ref[c0] == 3
    # all three boundaries advanced without further data movement
    assert all(pt.cold_pages(s) == 1 for s in range(3))


# ------------------------------------------------ pools: steady-state cost ----

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _plan(max_seq, windows, page):
    trace = engine.serve_trace_for(get_config("smollm-360m"),
                                   [(7, 6), (9, 5)], slots=2, layer_group=8)
    pl = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    return dataclasses.replace(pl, hot_window=max_seq // 2,
                               slot_hot_windows=windows, page_tokens=page)


def test_pool_steady_state_zero_repacks_zero_syncs(setup, monkeypatch):
    """The acceptance gate: with the persistent pools, steady-state step()
    never re-packs the dense cache into pools (gather_pools/pool_layout are
    poisoned), and a step with no layout event uploads no table and copies
    no page."""
    import repro.kernels.paged_decode as pd

    def poisoned(*a, **k):
        raise AssertionError("dense->pool re-pack on the persistent-pool path")

    monkeypatch.setattr(pd, "gather_pools", poisoned)
    monkeypatch.setattr(pd, "pool_layout", poisoned)

    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, page = 32, 4
    plan = _plan(max_seq, [16, 16], page)     # huge windows: no demotions
    b = engine.ContinuousBatcher(params, cfg_k, 2, max_seq, plan=plan,
                                 paged=True)
    b.submit(jnp.arange(5, dtype=jnp.int32), 8)
    b.submit(jnp.arange(6, dtype=jnp.int32), 8)
    assert b.step()                            # admits + first decode
    steady_steps = 0
    while any(b.active):
        before = dict(b.pool.stats)
        version = b.ptable.version
        if not b.step():
            break
        if b.ptable.version == version:        # no admit/alloc/demote event
            steady_steps += 1
            assert b.pool.stats["table_uploads"] == before["table_uploads"]
            assert b.pool.stats["page_copies"] == before["page_copies"]
            assert b.pool.stats["admit_page_writes"] == \
                before["admit_page_writes"]
    assert steady_steps > 0                    # the loop really went steady
    assert b.pool.stats["repacks"] == 0
    # layout uploads are event-driven, bounded by table mutations
    assert b.pool.stats["table_uploads"] <= b.ptable.version + 1


def test_pool_decode_writes_land_in_physical_pages(setup):
    """Decode really writes through the page table: after a run, the hot
    pool pages of a slot hold the KV the dense path would hold (the pools
    are the only storage — scribbling the pool changes the next logits)."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, page = 32, 4
    plan = _plan(max_seq, [16, 16], page)
    b = engine.ContinuousBatcher(params, cfg_k, 2, max_seq, plan=plan,
                                 paged=True)
    b.submit(jnp.arange(5, dtype=jnp.int32), 4)
    for _ in range(3):
        b.step()
    logits_ref, _, _ = model.forward(
        params, cfg_k, {"tokens": b.last_tok[:, None]}, caches=b.pool.tree,
        cache_index=b.lengths, decode=True,
        paged_view=b.pool.paged_view(b._active_mask))
    # zero slot 0's first physical hot page -> attention must change
    entry = b.pool.tree["slots"][0]
    phys = b.ptable.table[0][0]
    wiped = {**entry, "k_hot": entry["k_hot"].at[:, phys].set(0.0)}
    tree = {"prologue": list(b.pool.tree["prologue"]),
            "slots": [wiped] + list(b.pool.tree["slots"][1:])}
    logits_wiped, _, _ = model.forward(
        params, cfg_k, {"tokens": b.last_tok[:, None]}, caches=tree,
        cache_index=b.lengths, decode=True,
        paged_view=b.pool.paged_view(b._active_mask))
    assert not jnp.allclose(logits_ref[0], logits_wiped[0], atol=1e-4)


# ------------------------------------------------- sharing: bit-identical ----

def test_shared_prefix_slots_bit_identical_and_cheaper(setup):
    """Two slots decoding from one shared system prompt: tokens equal the
    all-HBM reference, logits bit-identical to the unshared pool run, and
    strictly fewer physical pages + migration bytes."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, slots, page = 32, 2, 4
    plan = _plan(max_seq, [4, 8], page)       # small windows: demotions occur
    sys_p = jax.random.randint(jax.random.PRNGKey(7), (9,), 0,
                               cfg.vocab_size).astype(jnp.int32)
    users = [jax.random.randint(jax.random.PRNGKey(8 + i), (2 + i,), 0,
                                cfg.vocab_size).astype(jnp.int32)
             for i in range(2)]
    reqs = [(jnp.concatenate([sys_p, u]), 6) for u in users]

    def drive(c, p, paged, shared):
        b = engine.ContinuousBatcher(params, c, slots, max_seq, plan=p,
                                     paged=paged)
        for t, d in reqs:
            b.submit(t, d, prefix_key="sys" if shared else None)
        logit_log = []
        while b.queue or any(b.active):
            b._admit()
            if not any(b.active):
                break
            b.step()
            logit_log.append(b.last_tok)
        return b.outputs, b, logit_log

    out_base, _, _ = drive(cfg, None, False, False)
    out_s, b_s, log_s = drive(cfg_k, plan, True, True)
    out_u, b_u, log_u = drive(cfg_k, plan, True, False)
    assert out_s == out_u == out_base
    # bit-identical decode trajectories shared vs unshared
    for a, b in zip(log_s, log_u):
        assert jnp.array_equal(a, b)
    # the system prompt's full pages existed once, not twice
    assert b_s.pool.peak_pages < b_u.pool.peak_pages
    assert b_s.sim_migration_bytes < b_u.sim_migration_bytes
    assert b_s.pool.stats["admit_page_writes"] < \
        b_u.pool.stats["admit_page_writes"]
    b_s.ptable.check()
    b_u.ptable.check()


def test_shared_prefix_logits_bit_identical_one_step(setup):
    """One decode step, logits only: slot 1 sharing slot 0's prefix pages
    produces exactly the logits of a private-pages run (same values read
    through a different physical mapping)."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    max_seq, page = 32, 4
    plan = _plan(max_seq, [16, 16], page)
    prompt = jax.random.randint(jax.random.PRNGKey(5), (8,), 0,
                                cfg.vocab_size).astype(jnp.int32)

    def one(shared):
        b = engine.ContinuousBatcher(params, cfg_k, 2, max_seq, plan=plan,
                                     paged=True)
        b.submit(prompt, 3, prefix_key="p" if shared else None)
        b.submit(prompt, 3, prefix_key="p" if shared else None)
        b._admit()
        pv = b.pool.paged_view(b._active_mask)
        logits, _, _ = model.forward(
            params, cfg_k, {"tokens": b.last_tok[:, None]},
            caches=b.pool.tree, cache_index=b.lengths, decode=True,
            paged_view=pv)
        return logits, b

    l_shared, b_shared = one(True)
    l_priv, _ = one(False)
    assert jnp.array_equal(l_shared, l_priv)
    # and the shared run really aliased the prompt's full pages
    assert b_shared.ptable.is_shared(1, 0)
    assert b_shared.ptable.table[1][0] == b_shared.ptable.table[0][0]


# ----------------------------------------------------- runtime surface -------

def test_shared_trace_counts_bytes_once():
    from repro.runtime.synthetic import synthetic_shared_prefix_trace
    ts = synthetic_shared_prefix_trace(shared=True)
    tu = synthetic_shared_prefix_trace(shared=False)
    # identical byte geometry per request, smaller physical peak when shared
    assert ts.num_steps == tu.num_steps
    assert sum(o.bytes for o in ts.objects) == sum(o.bytes for o in tu.objects)
    assert ts.peak_kv_bytes() < tu.peak_kv_bytes()
    fast = 0.2 * tu.peak_kv_bytes()
    rs = runtime.simulate(ts, TPU_V5E, fast, "sentinel")
    ru = runtime.simulate(tu, TPU_V5E, fast, "sentinel")
    assert rs.bytes_s2f + rs.bytes_f2s < ru.bytes_s2f + ru.bytes_f2s
    assert rs.detail["peak_kv"] < ru.detail["peak_kv"]
    # plan sizing consumes the deduped peak
    pl = runtime.plan(ts, TPU_V5E, fast)
    assert pl.slot_hot_windows and pl.page_tokens == ts.block_tokens
    assert runtime.PlacementPlan.from_json(pl.to_json()).to_json() == \
        pl.to_json()
