"""Sentinel-Serve: serving-phase trace model, policy registry, decode-phase
planner, and the tiered continuous-batching runtime (cold KV prefix on host
matching the all-HBM reference bit-for-bit)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import hmsim, planner
from repro.core.hardware import TPU_V5E
from repro.core.policies import (POLICIES, ServePolicy, get_policy,
                                 list_policies, register_policy)
from repro.models import kvcache, model
from repro.models.layers import split_params
from repro.serve import engine


@pytest.fixture(scope="module")
def trace():
    """Synthetic serving trace with realistic byte geometry (4KB KV/token
    per layer-group, 8 groups, 4 slots, mixed prompt/decode lengths)."""
    reqs = hmsim.synthetic_requests(12)
    return hmsim.build_serve_trace(reqs, num_slots=4, num_layers=8,
                                   kv_token_bytes=4096, weight_bytes=50e6,
                                   flops_per_token=2e9)


# ------------------------------------------------------------- registry ----

def test_policy_registry_dispatch():
    assert {"prefer_fast", "lru_page", "sentinel"} <= set(list_policies())
    for name in list_policies():
        cls = get_policy(name)
        assert issubclass(cls, ServePolicy) and cls.name == name
    with pytest.raises(KeyError, match="unknown serve policy"):
        get_policy("nope")


def test_policy_registration_roundtrip():
    @register_policy("_test_noop")
    class Noop(ServePolicy):
        pass
    try:
        assert get_policy("_test_noop") is Noop
        assert "_test_noop" in list_policies()
    finally:
        POLICIES.pop("_test_noop")


# ---------------------------------------------------------------- trace ----

def test_decode_trace_access_invariants(trace):
    """Every KV object's accesses are monotone in token index, start at
    birth, and stay within the owning request's lifetime."""
    assert trace.objects and trace.num_steps > 0
    for o in trace.objects:
        assert o.accesses, f"object {o.uid} never accessed"
        assert o.accesses == sorted(set(o.accesses))          # monotone
        assert o.accesses[0] == o.birth
        assert o.birth <= o.accesses[-1] <= o.death
        assert 0 <= o.token_start < o.token_end


def test_trace_blocks_partition_token_stream(trace):
    """Per (request, layer), the KV blocks tile [0, prompt+decode) without
    gaps or overlap."""
    by_req_layer = {}
    for o in trace.objects:
        by_req_layer.setdefault((o.req, o.layer), []).append(o)
    for (req, layer), objs in by_req_layer.items():
        objs.sort(key=lambda o: o.token_start)
        assert objs[0].token_start == 0
        for a, b in zip(objs, objs[1:]):
            assert a.token_end == b.token_start
        assert all(o.death == objs[0].death for o in objs)


def test_trace_accounting(trace):
    """Reads/admits/births/frees index exactly the object set."""
    from_reads = {o.uid for objs in trace.reads.values() for o in objs}
    born = {o.uid for objs in trace.admits.values() for o in objs} | \
           {o.uid for objs in trace.births.values() for o in objs}
    freed = {o.uid for objs in trace.frees.values() for o in objs}
    uids = {o.uid for o in trace.objects}
    assert from_reads == born == freed == uids
    assert trace.peak_kv_bytes() > 0
    assert trace.rs_bytes() > 0


# ------------------------------------------------------------- policies ----

def test_sentinel_beats_page_grain_at_20pct(trace):
    """The serving restatement of the paper's core claim: lifetime-aware
    object-granular placement beats page-grain reactive LRU (and static
    prefer-fast) when fast memory is scarce."""
    fast = 0.2 * trace.peak_kv_bytes()
    sent = hmsim.simulate_serve(trace, TPU_V5E, fast, "sentinel")
    lru = hmsim.simulate_serve(trace, TPU_V5E, fast, "lru_page")
    pf = hmsim.simulate_serve(trace, TPU_V5E, fast, "prefer_fast")
    assert sent.decode_throughput >= lru.decode_throughput
    assert sent.decode_throughput >= pf.decode_throughput
    assert sent.slow_bytes_accessed < lru.slow_bytes_accessed


def test_policies_agree_at_full_fast(trace):
    """With fast memory >= peak KV, object-grain policies hit the compute
    bound exactly; page-grain keeps a small padding/false-sharing residue."""
    fast = 1.1 * trace.peak_kv_bytes()
    sent = hmsim.simulate_serve(trace, TPU_V5E, fast, "sentinel").time
    pf = hmsim.simulate_serve(trace, TPU_V5E, fast, "prefer_fast").time
    lru = hmsim.simulate_serve(trace, TPU_V5E, fast, "lru_page").time
    assert sent <= pf * 1.001
    assert sent <= lru and lru <= sent * 1.10


def test_more_fast_memory_never_hurts_serving(trace):
    tputs = []
    for frac in (0.1, 0.3, 0.6, 1.0):
        r = hmsim.simulate_serve(trace, TPU_V5E,
                                 frac * trace.peak_kv_bytes(), "sentinel")
        tputs.append(r.decode_throughput)
    for a, b in zip(tputs, tputs[1:]):
        assert b >= a * 0.98


# -------------------------------------------------------------- planner ----

def test_plan_serve_constraints(trace):
    pl = planner.plan_serve(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    assert pl.policy == "sentinel"
    assert pl.hot_window >= trace.block_tokens        # reserve-pool floor
    assert pl.lookahead >= 1
    assert pl.sim is not None and pl.decode_throughput > 0
    assert pl.candidates and any(c.space_ok for c in pl.candidates)
    # cold prefix shrinks to zero once the buffer fits the hot window
    assert pl.cold_len(pl.hot_window) == 0
    assert pl.cold_len(pl.hot_window + 7) == 7


# -------------------------------------------------- tiered cache pytrees ----

def test_split_merge_roundtrip():
    cfg = get_config("smollm-360m").reduced()
    max_seq, cold = 40, 24
    full = kvcache.init_cache(cfg, 2, max_seq, jnp.float32)
    full = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(a.size % 97), a.shape)
        .astype(a.dtype), full)
    c, h = kvcache.split_seq_cache(full, max_seq, cold)
    merged = kvcache.merge_seq_cache(kvcache.to_host(c), h)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(merged)):
        assert a.shape == b.shape
        assert jnp.array_equal(a, b)


def test_splice_slot_matches_direct_write():
    cfg = get_config("smollm-360m").reduced()
    max_seq, B = 32, 3
    big = kvcache.init_cache(cfg, B, max_seq, jnp.float32)
    one = jax.tree.map(
        lambda a: jnp.ones_like(a[:, :1] if a.ndim >= 2 and a.shape[1] == B
                                else a[:1]),
        kvcache.init_cache(cfg, B, max_seq, jnp.float32))
    out = kvcache.splice_slot(big, one, 1, B)
    for leaf in jax.tree.leaves(out):
        total = float(jnp.sum(leaf))
        per_slot = leaf.size / B
        assert total == pytest.approx(per_slot)


# ------------------------------------------------------------------ e2e ----

def test_tiered_batcher_matches_all_hbm():
    """ContinuousBatcher with a host-offloaded cold prefix produces exactly
    the tokens of the all-HBM reference run."""
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    requests = [(7, 4), (9, 4), (8, 4)]

    def run(plan):
        b = engine.ContinuousBatcher(params, cfg, slots, max_seq, plan=plan)
        key = jax.random.PRNGKey(3)
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(sub, (plen,), 0,
                                        cfg.vocab_size).astype(jnp.int32), d)
        return b.run()

    trace = engine.serve_trace_for(get_config("smollm-360m"), requests,
                                   slots=slots, layer_group=8)
    plan = planner.plan_serve(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2)
    assert plan.cold_len(max_seq) == max_seq // 2      # real cold prefix

    base = run(None)
    tiered = run(plan)
    assert base == tiered
    assert len(base) == len(requests)
