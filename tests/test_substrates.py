"""Substrate tests: data pipeline, optimizer, checkpointing, train loop,
serving (prefill/decode parity)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.checkpoint import ckpt
from repro.configs.base import get_config
from repro.core.offload import SentinelConfig
from repro.data.pipeline import DataConfig, Prefetcher, make_batch
from repro.models import model
from repro.models.layers import split_params
from repro.optim import adamw
from repro.serve import engine
from repro.train import loop


def test_data_determinism():
    cfg = DataConfig(seed=3, vocab_size=100, seq_len=16, global_batch=4)
    a = make_batch(cfg, 7)
    b = make_batch(cfg, 7)
    c = make_batch(cfg, 8)
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert not jnp.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 100


def test_prefetcher_orders_batches():
    cfg = DataConfig(seed=0, vocab_size=50, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_adamw_optimizes_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_grad_clip():
    cfg = adamw.OptConfig(clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    _, _, m = adamw.update({"w": jnp.full(3, 1e6)}, state, params, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_compressed_grads_still_train():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, compress_grads=True)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params, cfg)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.6


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.ones(4), {"c": jnp.zeros((), jnp.int32)}]}
    ckpt.save(tree, str(tmp_path), 3)
    ckpt.save(tree, str(tmp_path), 7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore(tree, str(tmp_path), 3)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_gc(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in range(6):
        ckpt.save(tree, str(tmp_path), s, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_train_loop_resume_exact(tmp_path, rng):
    """Crash recovery is bit-exact: run 10 steps straight vs 5+resume+5."""
    cfg = get_config("smollm-360m").reduced()
    scfg = SentinelConfig(mode="remat", mi_periods=1)
    ocfg = adamw.OptConfig(total_steps=20, warmup_steps=2)
    dcfg = DataConfig(seed=1, vocab_size=cfg.vocab_size, seq_len=16,
                      global_batch=2)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    t1 = loop.TrainConfig(steps=10, ckpt_every=10, ckpt_dir=d1, log_every=100)
    r1 = loop.run(cfg, t1, scfg, ocfg, dcfg, log=lambda *a: None)

    t2a = loop.TrainConfig(steps=5, ckpt_every=5, ckpt_dir=d2, log_every=100)
    loop.run(cfg, t2a, scfg, ocfg, dcfg, log=lambda *a: None)
    t2b = loop.TrainConfig(steps=10, ckpt_every=10, ckpt_dir=d2, log_every=100)
    r2 = loop.run(cfg, t2b, scfg, ocfg, dcfg, log=lambda *a: None)

    for a, b in zip(jax.tree.leaves(r1["state"]["params"]),
                    jax.tree.leaves(r2["state"]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma2-2b", "zamba2-7b",
                                  "xlstm-1.3b", "deepseek-v2-lite-16b"])
def test_prefill_decode_matches_full_forward(arch, rng):
    """Decode-step logits at position t == full-forward logits at t."""
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size).astype(jnp.int32)

    full_logits, _, _ = model.forward(params, cfg, {"tokens": toks})

    last, caches = model.prefill(params, cfg, {"tokens": toks[:, :S - 2]},
                                 max_seq=S)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, S - 3]),
                               rtol=2e-3, atol=2e-3)
    lg, caches = model.decode_step(params, cfg, toks[:, S - 2:S - 1], caches,
                                   jnp.asarray(S - 2, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-3, atol=2e-3)
    lg, _ = model.decode_step(params, cfg, toks[:, S - 1:], caches,
                              jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_generate_greedy_deterministic(rng):
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    prompts = {"tokens": jnp.ones((2, 6), jnp.int32)}
    a = engine.generate(params, cfg, prompts, 4)
    b = engine.generate(params, cfg, prompts, 4)
    assert jnp.array_equal(a, b)
    assert a.shape == (2, 4)


def test_continuous_batching_matches_single_request(rng):
    """Ragged prompts through the slot-based batcher == per-request greedy."""
    from repro.serve.engine import ContinuousBatcher
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    prompts = [jnp.array([3, 5, 7, 2], jnp.int32),
               jnp.array([9, 1, 4, 4, 8, 2], jnp.int32),
               jnp.array([2, 2, 6], jnp.int32)]
    cb = ContinuousBatcher(params, cfg, batch_slots=2, max_seq=32)
    for p in prompts:
        cb.submit(p, 6)
    results = cb.run()
    assert len(results) == 3
    for p in prompts:
        ref = list(map(int, engine.generate(params, cfg,
                                            {"tokens": p[None]}, 6)[0]))
        assert any(r[:6] == ref[:6] for r in results), (p, ref, results)
