"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs. (Full configs are exercised only
via the dry-run — ShapeDtypeStructs, no allocation.)"""
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_batch
from repro.configs.base import SHAPES, cells, get_config, list_archs
from repro.core.offload import SentinelConfig
from repro.models import model
from repro.models.layers import split_params
from repro.optim import adamw

ARCHS = list_archs()


def test_all_archs_registered():
    assert len(ARCHS) == 11  # 10 assigned + lstm-ptb (paper's own)
    assert set(ARCHS) >= {
        "smollm-360m", "gemma3-12b", "internlm2-1.8b", "gemma2-2b",
        "granite-moe-3b-a800m", "deepseek-v2-lite-16b", "zamba2-7b",
        "xlstm-1.3b", "musicgen-medium", "paligemma-3b", "lstm-ptb"}


def test_cell_count():
    all_cells = cells(include_skips=True)
    assert len(all_cells) == 40
    skips = [c for c in all_cells if c[2]]
    assert len(skips) == 6       # pure-full-attention archs skip long_500k
    assert all(c[1] == "long_500k" for c in skips)


def test_full_configs_match_assignment():
    c = get_config("gemma3-12b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (48, 3840, 16, 8, 15360, 262144)
    assert c.period.count("attn") == 1 and c.period.count("local") == 5
    d = get_config("deepseek-v2-lite-16b")
    assert d.kv_lora_rank == 512 and d.moe.num_experts == 64 \
        and d.moe.experts_per_token == 6 and d.moe.num_shared_experts == 2
    g = get_config("granite-moe-3b-a800m")
    assert g.moe.num_experts == 40 and g.moe.experts_per_token == 8
    z = get_config("zamba2-7b")
    assert z.num_layers == 81 and z.ssm.state_dim == 64
    x = get_config("xlstm-1.3b")
    assert x.d_ff == 0 and x.period.count("mlstm") == 7


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng)

    logits, _, aux = jax.jit(
        lambda p, b: model.forward(p, cfg, b))(params, batch)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1] + (cfg.num_prefix_tokens or 0)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    # one full train step (grad + adamw update): finite loss, finite params
    ocfg = adamw.OptConfig(total_steps=10, warmup_steps=1)
    opt = adamw.init(params, ocfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch)))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    new_params, _, m = adamw.update(grads, opt, params, ocfg)
    assert bool(jnp.isfinite(m["grad_norm"]))
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite params"


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "lstm-ptb"])
def test_smoke_decode(arch, rng):
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng, B=2, S=8)
    batch.pop("labels")
    last, caches = model.prefill(params, cfg, batch, max_seq=12)
    tok = (jnp.zeros((2, 1, cfg.num_codebooks), jnp.int32)
           if cfg.num_codebooks else jnp.zeros((2, 1), jnp.int32))
    idx = jnp.asarray(8 + (cfg.num_prefix_tokens or 0), jnp.int32)
    logits, caches2 = model.decode_step(params, cfg, tok, caches, idx)
    assert bool(jnp.isfinite(logits).all())


def test_sentinel_modes_agree(rng):
    """offload / save_hbm / remat / full must be numerically identical —
    the reserved-pool recompute changes memory, never math."""
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng)
    vals = {}
    for mode in ["full", "remat", "save_hbm", "offload"]:
        scfg = SentinelConfig(mode=mode, mi_periods=2)
        from repro.core.offload import loss_kwargs
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, **loss_kwargs(scfg))))(params)
        vals[mode] = (loss, grads)
    for mode in ["remat", "save_hbm", "offload"]:
        assert jnp.allclose(vals["full"][0], vals[mode][0], rtol=1e-5), mode
        for a, b in zip(jax.tree.leaves(vals["full"][1]),
                        jax.tree.leaves(vals[mode][1])):
            assert jnp.allclose(a, b, rtol=1e-4, atol=1e-5), mode
