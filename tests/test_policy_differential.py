"""Differential policy-test harness: every registered policy against a
brute-force oracle.

Three layers, from strongest to loosest, matched to what each policy family
exposes:

  audited replay   event-driven policies (``simulate`` not overridden) are
                   driven hook by hook with instrumented ``_promote`` /
                   ``_demote`` and, after EVERY step, a from-scratch
                   recomputation of the fast tier's occupancy — capacity
                   feasibility, no dead object tracked (let alone resident)
                   in fast memory, and migration-byte conservation (every
                   byte charged to a channel equals bytes that actually
                   changed tier) are asserted against that brute force.
  static oracle    on <= 12-object workloads, exhaustive enumeration of all
                   2^n capacity-feasible static placements; the lifetime-
                   aware policy must not lose to the best static placement
                   (it sees the schedule the oracle sees, and can migrate).
  result oracle    interval/daemon/static policies expose their peak fast
                   occupancy through ``detail['peak_fast_used']``; plus the
                   bracket/positivity invariants every result must satisfy.

A hypothesis suite fuzzes the same oracles over random workloads, tenant
counts, and fast-memory fractions (profile registered in conftest.py keeps
CI deterministic).
"""
import pytest

from repro import runtime
from repro.core.hardware import HWSpec
from repro.runtime.synthetic import (synthetic_multi_tenant_trace,
                                     synthetic_profile,
                                     synthetic_serve_trace,
                                     synthetic_shared_prefix_trace)

HW = HWSpec("diff", peak_flops=1e12, fast_bw=100e9, slow_bw=20e9,
            mig_bw=20e9, fast_bytes=1e9)

# knobs that make each policy deterministic and cheap on tiny workloads
KNOBS = {"sentinel": {"lookahead": 6}, "sentinel_slo": {"lookahead": 6},
         "alpha_migration": {"lookahead": 6},
         "lru_page": {"page_bytes": 4096}, "sentinel_mi": {"mi": 3},
         "ial": {"repeats": 2}, "lru": {"repeats": 2}}


def policies():
    return [p for p in runtime.list_policies() if p != "base"]


def is_event_driven(name: str) -> bool:
    cls = runtime.get_policy(name)
    return cls.simulate.__func__ is runtime.PlacementPolicy.simulate.__func__


# ------------------------------------------------------ workload builders ----

def make_timeline(objs, num_steps: int, fixed: float = 0.0,
                  flops: float = 1e6) -> runtime.AccessTimeline:
    """A tiny serving-kind timeline straight from DataObjects (the unit the
    oracle enumerates over)."""
    admits, births, frees, reads = {}, {}, {}, {}
    for o in objs:
        (admits if o.birth == 0 else births).setdefault(
            o.birth, []).append(o)
        frees.setdefault(o.death + 1, []).append(o)
        for s in o.accesses:
            if 0 <= s < num_steps:
                reads.setdefault(s, []).append(o)
    total = [fixed + sum(o.bytes for o in reads.get(s, ()))
             for s in range(num_steps)]
    return runtime.AccessTimeline(
        kind="serving", num_steps=num_steps, objects=list(objs),
        flops=[flops] * num_steps, total_bytes=total,
        fixed_fast_bytes=[fixed] * num_steps, tokens=[1] * num_steps,
        extra_flops=[0.0] * num_steps, extra_fast_bytes=[0.0] * num_steps,
        admits=admits, births=births, frees=frees, reads=reads)


def _obj(uid, bytes_, birth, death, accesses, tenant=None, shared=None):
    return runtime.DataObject(uid, bytes_, birth, death,
                              sorted(set(accesses)), "kv",
                              shared_key=shared, tenant=tenant)


def small_workloads():
    """Deterministic <= 12-object workloads covering the shapes the policies
    disagree on: overlap pressure, strided history, tenants, shared groups."""
    KB = 1024
    pyramid = [_obj(i, (8 + 4 * i) * KB, i, 9 - i, [i, 9 - i])
               for i in range(5)]
    strided = [_obj(i, 16 * KB, i, 11, list(range(i, 12, 3)))
               for i in range(6)]
    tenants = [_obj(i, 12 * KB, 0, 11, list(range(0, 12, 2)), tenant="a")
               for i in range(3)] + \
              [_obj(10 + i, 48 * KB, 1, 11, list(range(1, 12, 1)),
                    tenant="b") for i in range(3)]
    shared = [_obj(i, 32 * KB, i, 10, list(range(i, 11, 2)),
                   shared=("sys", 0)) for i in range(3)] + \
             [_obj(5 + i, 16 * KB, i, 10, [i, 10]) for i in range(3)]
    return {"pyramid": (pyramid, 11), "strided": (strided, 13),
            "tenants": (tenants, 13), "shared": (shared, 12)}


# ------------------------------------------------------- the audited oracle --

def audited(cls):
    """Subclass with conservation checks on the tier-move primitives: a
    promotion charges s2f exactly the bytes that became resident, a demotion
    charges f2s exactly the bytes that left, never both."""

    class Audited(cls):
        def _promote(self, o):
            fu, s0, f0 = self.fast_used, self.bytes_s2f, self.bytes_f2s
            super()._promote(o)
            assert self.fast_used - fu >= -1e-9
            assert self.bytes_s2f - s0 == pytest.approx(self.fast_used - fu)
            assert self.bytes_f2s == f0
        def _demote(self, o):
            fu, s0, f0 = self.fast_used, self.bytes_s2f, self.bytes_f2s
            super()._demote(o)
            assert fu - self.fast_used >= -1e-9
            assert self.bytes_f2s - f0 == pytest.approx(fu - self.fast_used)
            assert self.bytes_s2f == s0

    Audited.__name__ = f"Audited{cls.__name__}"
    return Audited


def brute_force_occupancy(pol) -> float:
    """Recompute the fast tier's occupancy from scratch (shared groups count
    once), independently of the policy's incremental counter."""
    seen, total = set(), 0.0
    for uid, o in pol.live.items():
        if not pol.in_fast.get(uid):
            continue
        k = getattr(o, "shared_key", None)
        if k is None:
            total += o.bytes
        elif k not in seen:
            seen.add(k)
            total += o.bytes
    return total


def check_step(pol) -> None:
    # no dead object is tracked — a fortiori none is fast-resident
    for uid in pol.in_fast:
        assert uid in pol.live, f"dead object {uid} still placed"
    # capacity feasibility
    assert pol.fast_used <= pol.fast_bytes + 1e-6, \
        f"fast tier over capacity: {pol.fast_used} > {pol.fast_bytes}"
    # occupancy conservation against the brute force
    if pol.granularity == "object":
        assert pol.fast_used == pytest.approx(brute_force_occupancy(pol)), \
            "fast_used drifted from the resident set"
    else:                                  # page-grain: whole resident pages
        resident = sum(1 for p in pol.pages if p.in_fast)
        assert pol.fast_used == pytest.approx(resident * pol.page_bytes)
    # per-tenant occupancy never exceeds the total
    tenanted = sum(v for v in pol.tenant_fast.values() if v > 0)
    assert tenanted <= pol.fast_used + 1e-6


def replay_checked(name: str, tl, hw, fast_bytes: float, **knobs):
    """Drive an event-driven policy through the shared event loop with the
    oracle checks after every step; returns the policy instance."""
    cls = audited(runtime.get_policy(name))
    pol = cls(tl, hw, max(0.0, fast_bytes - tl.reserved_bytes), **knobs)
    for t in range(tl.num_steps):
        pol.on_free(t, tl.frees.get(t, ()))
        pol.on_admit(t, tl.admits.get(t, ()))
        pol.on_birth(t, tl.births.get(t, ()))
        bf, bs = pol.on_reads(t, tl.reads.get(t, ()))
        t_step = max(tl.flops[t] / hw.peak_flops,
                     (bf + tl.fixed_fast_bytes[t]) / hw.fast_bw
                     + bs / hw.slow_bw) + tl.extra_time(t, hw)
        pol.migrate(t, t_step * hw.mig_bw)
        check_step(pol)
    return pol


def oracle_best_static(tl, hw, fast_bytes: float) -> float:
    """Exhaustive best *static* placement: minimum timeline time over every
    subset of objects that fits in fast memory at every step."""
    objs = tl.objects
    assert len(objs) <= 12, "oracle is exponential in the object count"
    best = None
    for mask in range(1 << len(objs)):
        fast = [o for i, o in enumerate(objs) if mask >> i & 1]
        if any(sum(o.bytes for o in fast if o.birth <= t <= o.death)
               > fast_bytes + 1e-9 for t in range(tl.num_steps)):
            continue
        uids = {o.uid for o in fast}
        time = 0.0
        for t in range(tl.num_steps):
            bf = bs = 0.0
            for o in tl.reads.get(t, ()):
                if o.uid in uids:
                    bf += o.bytes
                else:
                    bs += o.bytes
            time += max(tl.flops[t] / hw.peak_flops,
                        (bf + tl.fixed_fast_bytes[t]) / hw.fast_bw
                        + bs / hw.slow_bw)
        if best is None or time < best:
            best = time
    return best


# ------------------------------------------------------------ deterministic --

@pytest.mark.parametrize("wname", sorted(small_workloads()))
@pytest.mark.parametrize("frac", [0.15, 0.35, 0.7])
def test_event_driven_policies_pass_oracle(wname, frac):
    objs, steps = small_workloads()[wname]
    tl = make_timeline(objs, steps)
    fast = frac * runtime.peak_object_bytes(objs)
    for name in policies():
        if is_event_driven(name):
            replay_checked(name, tl, HW, fast, **KNOBS.get(name, {}))


@pytest.mark.parametrize("wname", sorted(small_workloads()))
def test_all_policies_result_invariants(wname):
    objs, steps = small_workloads()[wname]
    tl = make_timeline(objs, steps)
    fast = 0.3 * runtime.peak_object_bytes(objs)
    for name in policies():
        r = runtime.simulate(tl, HW, fast, name, **KNOBS.get(name, {}))
        assert r.policy == name
        assert r.time >= r.compute_time * 0.999
        assert r.tokens == steps
        assert r.migrations >= 0 and r.bytes_s2f >= 0 and r.bytes_f2s >= 0
        assert r.slow_bytes_accessed >= 0 and r.stall_time >= 0
        # capacity feasibility for every policy that reports its peak
        # (all_fast/all_slow are the definitional bounds, no occupancy)
        peak = r.detail.get("peak_fast_used")
        if peak is not None and name not in ("all_fast", "all_slow"):
            budget = r.detail.get("fast_budget", fast)
            assert peak <= budget + 1e-6, (name, peak, budget)


@pytest.mark.parametrize("wname", sorted(small_workloads()))
def test_lifetime_policy_not_worse_than_best_static(wname):
    """The differential claim: with the schedule known, the lifetime-aware
    policy never loses to the best static placement an exhaustive oracle can
    find (it can always mimic it, and may migrate on top)."""
    objs, steps = small_workloads()[wname]
    tl = make_timeline(objs, steps)
    fast = 0.3 * runtime.peak_object_bytes(objs)
    best = oracle_best_static(tl, HW, fast)
    r = runtime.simulate(tl, HW, fast, "sentinel", lookahead=steps)
    assert r.time <= best * 1.001 + r.migrations * HW.mig_overhead + 1e-12


def test_oracle_brackets_static_policies():
    objs, steps = small_workloads()["pyramid"]
    tl = make_timeline(objs, steps)
    fast = 0.3 * runtime.peak_object_bytes(objs)
    best = oracle_best_static(tl, HW, fast)
    all_fast = runtime.simulate(tl, HW, fast, "all_fast")
    # the oracle can at best reach the all-fast roofline, and the empty
    # placement (a feasible subset) bounds it above
    assert best >= all_fast.time * 0.999
    assert best <= oracle_best_static(tl, HW, 0.0) + 1e-12


def test_harness_exercises_real_workload_traces():
    """The harness also runs every policy over the real synthetic sources —
    training profile, serving trace, shared-prefix and multi-tenant mixes —
    not just the hand-built timelines."""
    from repro.core.hardware import PAPER_HM, TPU_V5E
    prof = synthetic_profile(num_periods=2)
    trace = synthetic_serve_trace(num_requests=4, num_slots=2)
    shared = synthetic_shared_prefix_trace(num_tenants=4, num_slots=2)
    mt = synthetic_multi_tenant_trace(chatty_requests=3, bursty_requests=2)
    for wl, hw, peak in ((prof, PAPER_HM, prof.peak_bytes()),
                         (trace, TPU_V5E, trace.peak_kv_bytes()),
                         (shared, TPU_V5E, shared.peak_kv_bytes()),
                         (mt, TPU_V5E, mt.trace.peak_kv_bytes())):
        fast = 0.25 * peak
        for name in policies():
            r = runtime.simulate(wl, hw, fast, name, **KNOBS.get(name, {}))
            assert r.time > 0 and r.time >= r.compute_time * 0.999
        tl = runtime.as_workload(wl).timeline()
        for name in policies():
            if is_event_driven(name):
                replay_checked(name, tl, hw, fast, **KNOBS.get(name, {}))


def test_sentinel_slo_zero_violations_everywhere_blind_violates():
    """The tenant gate, as a test: on the adversarial mix the blind policy
    violates at least one tenant's guarantee at 20% fast memory; the SLO
    policy violates none at ANY fraction, within 1.2x the migration bytes."""
    from repro.core.hardware import TPU_V5E
    wl = synthetic_multi_tenant_trace()
    peak = wl.trace.peak_kv_bytes()
    blind = runtime.simulate(wl, TPU_V5E, 0.2 * peak, "sentinel",
                             tenant_quotas=wl.tenant_quotas)
    assert sum(blind.tenant_violations.values()) >= 1
    for frac in (0.1, 0.2, 0.4):
        slo = runtime.simulate(wl, TPU_V5E, frac * peak, "sentinel_slo",
                               tenant_quotas=wl.tenant_quotas,
                               tenant_slack=wl.tenant_slack)
        assert slo.tenant_violations == {}
    slo20 = runtime.simulate(wl, TPU_V5E, 0.2 * peak, "sentinel_slo",
                             tenant_quotas=wl.tenant_quotas,
                             tenant_slack=wl.tenant_slack)
    assert slo20.bytes_s2f + slo20.bytes_f2s <= \
        1.2 * (blind.bytes_s2f + blind.bytes_f2s)


# ----------------------------------------------------------- hypothesis ------
# Guarded import (NOT importorskip at module level — that would skip the
# deterministic oracle above with it); CI installs hypothesis, so the
# property suites below always run there.

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def random_workloads(draw):
        steps = draw(st.integers(4, 14))
        n = draw(st.integers(2, 12))
        n_tenants = draw(st.integers(0, 3))
        objs = []
        for uid in range(n):
            birth = draw(st.integers(0, steps - 1))
            death = draw(st.integers(birth, steps - 1))
            extra = draw(st.lists(st.integers(birth, death), max_size=4))
            tenant = None if n_tenants == 0 else \
                f"t{draw(st.integers(0, n_tenants - 1))}"
            objs.append(_obj(uid, draw(st.integers(1, 64)) * 1024, birth,
                             death, [birth] + extra, tenant=tenant))
        frac = draw(st.floats(0.05, 1.0))
        return objs, steps, frac

    @given(random_workloads())
    @settings(max_examples=25, deadline=None)
    def test_property_event_driven_oracle(case):
        objs, steps, frac = case
        tl = make_timeline(objs, steps)
        fast = frac * runtime.peak_object_bytes(objs)
        for name in policies():
            if is_event_driven(name):
                replay_checked(name, tl, HW, fast, **KNOBS.get(name, {}))

    @given(random_workloads())
    @settings(max_examples=25, deadline=None)
    def test_property_interval_policies_capacity(case):
        objs, steps, frac = case
        tl = make_timeline(objs, steps)
        fast = frac * runtime.peak_object_bytes(objs)
        for name in ("sentinel_mi", "ial", "lru"):
            r = runtime.simulate(tl, HW, fast, name, **KNOBS.get(name, {}))
            assert r.time >= r.compute_time * 0.999
            peak = r.detail.get("peak_fast_used", 0.0)
            assert peak <= r.detail.get("fast_budget", fast) + 1e-6

    @given(random_workloads())
    @settings(max_examples=15, deadline=None)
    def test_property_slo_never_violates(case):
        """Whatever the workload, tenant mix, or budget: equal-share
        guarantees under ``sentinel_slo`` produce zero violation events."""
        objs, steps, frac = case
        tl = make_timeline(objs, steps)
        fast = frac * runtime.peak_object_bytes(objs)
        pol = replay_checked("sentinel_slo", tl, HW, fast, lookahead=6)
        assert pol.tenant_violations == {}
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI installs it; the "
                             "deterministic oracle above still ran)")
    def test_property_suites_need_hypothesis():
        pass
