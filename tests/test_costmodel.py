"""The time-domain cost model (``runtime/costmodel.py``): the pricing
identities that make it placement-consistent, the ``objective="latency"``
planner path, the bandwidth-optimal ``alpha_migration`` policy, and the
CostModel API surface (serialization, ``from_hw`` upgrade, deprecation of
the ``hw=`` keyword)."""
import dataclasses
import json
import math
import warnings

import pytest

from repro import runtime
from repro.core.hardware import PAPER_HM, TPU_V5E, default_cost_model
from repro.runtime import CostModel, StepTraffic, TPU_V5E_COST
from repro.runtime.synthetic import synthetic_profile, synthetic_serve_trace

CM = TPU_V5E_COST


@pytest.fixture(scope="module")
def trace():
    return synthetic_serve_trace()


@pytest.fixture(scope="module")
def prof():
    return synthetic_profile()


# ------------------------------------------------------ pricing identities ----

def test_all_fast_priced_reproduces_roofline_clock(trace):
    """A zero-migration all-fast placement prices to exactly the legacy
    simulator's clock, which is exactly the roofline memory/compute term."""
    r = runtime.simulate(trace, CM, 0.2 * trace.peak_kv_bytes(), "all_fast")
    rep = CM.price_result(r)
    assert r.migrations == 0 and r.bytes_s2f == 0
    assert rep.time == pytest.approx(r.time, rel=1e-12)
    assert rep.time == pytest.approx(rep.compute_time, rel=1e-12)
    assert rep.slowdown == pytest.approx(1.0)
    assert len(rep.step_times) == trace.num_steps
    assert sum(rep.step_times) == pytest.approx(rep.time)


def test_all_fast_lower_bounds_every_policy(trace):
    fast = 0.2 * trace.peak_kv_bytes()
    lb = CM.price_result(runtime.simulate(trace, CM, fast, "all_fast")).time
    for name in runtime.list_policies():
        if name == "base":
            continue
        rep = CM.price_result(runtime.simulate(trace, CM, fast, name))
        assert rep.time >= lb * (1 - 1e-9), name


def test_step_time_monotone_in_fast_fraction():
    """Moving any read byte from the slow tier to fast never makes the
    predicted step slower (the roofline floor keeps the model consistent)."""
    reads = 1e9
    times = [CM.step_time(StepTraffic(flops=1e9, fast_read=f * reads,
                                      slow_read=(1 - f) * reads))
             for f in (0.0, 0.2, 0.4, 0.6, 0.8, 0.9624, 1.0)]
    assert all(a >= b - 1e-15 for a, b in zip(times, times[1:]))
    # and the all-fast split is exactly the all-fast floor
    assert times[-1] == pytest.approx(
        CM.step_time_all_fast(StepTraffic(flops=1e9, fast_read=reads)))


def test_demand_reads_never_cheaper_than_planned():
    """The same slow bytes priced as reactive demand misses (serialized)
    cost at least as much as planned/streamed reads (overlapped)."""
    planned = StepTraffic(flops=1e9, fast_read=8e8, slow_read=2e8)
    demand = dataclasses.replace(planned, demand_read=planned.slow_read)
    assert CM.step_time(demand) >= CM.step_time(planned)
    # the serialized misses pay the full interface cost on top of the
    # all-fast floor — they cannot hide behind any pipe
    assert CM.step_time(demand) >= CM.step_time_all_fast(planned) \
        + planned.slow_read / CM.ext_read_bw() - 1e-15


def test_reactive_policies_record_demand_reads(trace):
    fast = 0.2 * trace.peak_kv_bytes()
    lru = runtime.simulate(trace, CM, fast, "lru_page")
    sent = runtime.simulate(trace, CM, fast, "sentinel")
    assert sum(t.demand_read for t in lru.step_traffic) == \
        pytest.approx(sum(t.slow_read for t in lru.step_traffic))
    assert sum(t.demand_read for t in sent.step_traffic) == 0.0


def test_optimal_alpha():
    """alpha* = B_fast / (B_fast + B_ext): the fast:total read split that
    equalizes the two pipes' times."""
    a = CM.optimal_alpha()
    assert a == pytest.approx(819e9 / (819e9 + 32e9))
    assert a / CM.fast_read_bw == pytest.approx((1 - a) / CM.ext_read_bw())
    assert CostModel.from_hw(PAPER_HM).optimal_alpha() == \
        pytest.approx(34e9 / (34e9 + 19e9))


# ------------------------------------------------------------- API surface ----

def test_cost_model_json_roundtrip():
    d = CM.to_dict()
    json.dumps(d)                                    # JSON-safe
    assert CostModel.from_dict(d) == CM
    # inf host bandwidth (the legacy interface-bound model) survives as None
    legacy = CostModel.from_hw(TPU_V5E)
    d2 = legacy.to_dict()
    assert d2["host_internal_bw"] is None
    back = CostModel.from_dict(json.loads(json.dumps(d2)))
    assert back == legacy and math.isinf(back.host_internal_bw)


def test_cost_model_duck_types_hwspec():
    assert (CM.fast_bw, CM.slow_bw, CM.mig_bw) == \
        (CM.fast_read_bw, CM.slow_read_bw, CM.mig_read_bw)
    assert runtime.as_cost_model(CM) is CM
    assert runtime.as_cost_model(TPU_V5E) == CostModel.from_hw(TPU_V5E)


def test_from_hw_simulates_identically(trace):
    """A CostModel upgraded from an HWSpec drops into every policy and
    produces the identical PlacementResult."""
    fast = 0.2 * trace.peak_kv_bytes()
    cm = CostModel.from_hw(TPU_V5E)
    for pol in ("sentinel", "lru_page", "prefer_fast", "alpha_migration"):
        assert runtime.simulate(trace, TPU_V5E, fast, pol) == \
            runtime.simulate(trace, cm, fast, pol)


def test_default_cost_model_extends_tpu_constants():
    cm = default_cost_model()
    assert cm is TPU_V5E_COST
    assert (cm.peak_flops, cm.fast_bw, cm.slow_bw, cm.mig_bw, cm.link_bw,
            cm.fast_bytes, cm.mig_overhead) == \
        (TPU_V5E.peak_flops, TPU_V5E.fast_bw, TPU_V5E.slow_bw, TPU_V5E.mig_bw,
         TPU_V5E.link_bw, TPU_V5E.fast_bytes, TPU_V5E.mig_overhead)


def test_price_result_requires_recorded_traffic():
    bare = runtime.PlacementResult(policy="x", time=1.0, compute_time=1.0)
    with pytest.raises(ValueError, match="step_traffic"):
        CM.price_result(bare)


# ------------------------------------------------------- deprecation shims ----

def test_hw_keyword_warns_and_matches(prof, trace):
    fast_s = 0.2 * trace.peak_kv_bytes()
    with pytest.warns(DeprecationWarning, match="runtime.plan"):
        old = runtime.plan(trace, fast_bytes=fast_s, hw=TPU_V5E)
    assert old == runtime.plan(trace, TPU_V5E, fast_s)
    fast_t = 0.3 * prof.peak_bytes()
    with pytest.warns(DeprecationWarning, match="runtime.plan"):
        old_t = runtime.plan(prof, fast_bytes=fast_t, hw=PAPER_HM)
    assert old_t == runtime.plan(prof, PAPER_HM, fast_t)


def test_offload_from_plan_hw_keyword_warns(prof):
    from repro.core import offload
    pl = runtime.plan(prof, PAPER_HM, 0.3 * prof.peak_bytes())
    with pytest.warns(DeprecationWarning, match="from_plan"):
        old = offload.from_plan(prof, pl, hw=PAPER_HM)
    assert old == offload.from_plan(prof, pl)


def test_both_cost_model_and_hw_is_an_error(trace):
    with pytest.raises(TypeError, match="both"):
        runtime.plan(trace, TPU_V5E_COST, 1e9, hw=TPU_V5E)


def test_new_surface_does_not_warn(trace):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        runtime.plan(trace, TPU_V5E_COST, 0.2 * trace.peak_kv_bytes(),
                     objective="latency")
        runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())


# -------------------------------------------------------- latency objective ----

def test_invalid_objective_raises(trace):
    with pytest.raises(ValueError, match="objective"):
        runtime.plan(trace, TPU_V5E, 1e9, objective="zebra")


def test_latency_plan_never_slower_than_bytes_plan(trace):
    peak = trace.peak_kv_bytes()
    for frac in (0.1, 0.2, 0.4, 0.8):
        pb = runtime.plan(trace, CM, frac * peak)
        pl = runtime.plan(trace, CM, frac * peak, objective="latency")
        assert pl.objective == "latency" and pl.cost_model == CM
        assert pl.predicted_time <= \
            CM.price_result(pb.sim).time * (1 + 1e-12)
        assert sum(pl.predicted_step_times) == \
            pytest.approx(pl.predicted_time)
        assert pl.predicted_decode_throughput > 0


def test_latency_plan_training(prof):
    cm = CostModel.from_hw(PAPER_HM)
    fast = 0.3 * prof.peak_bytes()
    pb = runtime.plan(prof, PAPER_HM, fast)
    pl = runtime.plan(prof, cm, fast, objective="latency")
    assert pl.kind == "training" and pl.objective == "latency"
    assert pl.predicted_time <= cm.price_result(pb.sim).time * (1 + 1e-12)


def test_bytes_objective_serialization_is_unchanged(trace):
    """The default objective leaves plan JSON byte-compatible with every
    pre-CostModel golden: no objective/cost_model/predicted keys at all."""
    pl = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    d = pl.to_dict()
    assert "objective" not in d and "cost_model" not in d
    assert "predicted_step_times" not in d
    # while the latency plan carries all three, byte-stably
    pl2 = runtime.plan(trace, CM, 0.2 * trace.peak_kv_bytes(),
                       objective="latency")
    d2 = pl2.to_dict()
    assert d2["objective"] == "latency"
    assert CostModel.from_dict(d2["cost_model"]) == CM
    s = pl2.to_json()
    back = runtime.PlacementPlan.from_json(s)
    assert back.to_json() == s and back == pl2
    assert back.cost_model == CM


# ---------------------------------------------------------- alpha_migration ----

def test_alpha_migration_registered_and_bracketed(trace):
    assert "alpha_migration" in runtime.list_policies()
    peak = trace.peak_kv_bytes()
    af = CM.price_result(
        runtime.simulate(trace, CM, 0.4 * peak, "all_fast")).time
    sl = CM.price_result(
        runtime.simulate(trace, CM, 0.4 * peak, "all_slow")).time
    r = runtime.simulate(trace, CM, 0.4 * peak, "alpha_migration")
    t = CM.price_result(r).time
    assert af * (1 - 1e-9) <= t <= sl * (1 + 1e-9)


def test_alpha_migration_defaults_to_optimal_alpha_and_clamps(trace):
    cls = runtime.get_policy("alpha_migration")
    tl = runtime.as_workload(trace).timeline()
    assert cls(tl, CM, 1e9).alpha == pytest.approx(CM.optimal_alpha())
    # a legacy HWSpec machine gets the interface-bound alpha
    assert cls(tl, PAPER_HM, 1e9).alpha == pytest.approx(34e9 / 53e9)
    assert cls(tl, CM, 1e9, alpha=7.0).alpha == 1.0
    assert cls(tl, CM, 1e9, alpha=-1.0).alpha == 0.0


# ------------------------------------------------------------- hypothesis ----

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.floats(0.05, 1.0), st.integers(2, 4), st.integers(3, 8))
    @settings(max_examples=10, deadline=None)
    def test_property_latency_never_slower_than_bytes(frac, slots, reqs):
        """Whatever the trace shape or budget, the latency objective never
        returns a plan the cost model prices slower than the bytes
        objective's pick (the bytes winner is in the latency pool)."""
        tr = synthetic_serve_trace(num_requests=reqs, num_slots=slots)
        fast = frac * tr.peak_kv_bytes()
        pb = runtime.plan(tr, CM, fast)
        pl = runtime.plan(tr, CM, fast, objective="latency")
        assert pl.predicted_time <= \
            CM.price_result(pb.sim).time * (1 + 1e-12)

    @given(st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.floats(0.0, 0.9))
    @settings(max_examples=25, deadline=None)
    def test_property_step_time_bracketed(split, mig, overlap):
        """step_time is always >= the all-fast floor of the same reads and
        monotone in the demand fraction, for any read split / migration
        volume / overlap factor."""
        cm = dataclasses.replace(CM, dma_overlap=overlap)
        reads = 1e9
        tr = StepTraffic(flops=1e9, fast_read=split * reads,
                         slow_read=(1 - split) * reads,
                         mig_in=mig * 1e8, mig_out=(1 - mig) * 1e8)
        t = cm.step_time(tr)
        assert t >= cm.step_time_all_fast(tr) - 1e-15
        assert cm.step_time(dataclasses.replace(
            tr, demand_read=tr.slow_read)) >= t - 1e-15
else:
    @pytest.mark.skip(reason="hypothesis not installed (CI installs it; the "
                             "deterministic identities above still ran)")
    def test_property_suites_need_hypothesis():
        pass
