"""Cache-aware prefill scheduling: the shared-prefix compute skip and
chunked prefill are exact program transformations — every path must be
bit-identical to one-shot, all-HBM, full-prompt prefill.

Covers (1) ``model.prefill_suffix`` against full prefill, forking exactly
at a page boundary and mid-page; (2) the pool engine's donor-page skip
(``prefill_compute_tokens`` strictly below unshared at identical tokens);
(3) chunked admission across chunk sizes, interleaving with a decoding
anchor slot; (4) a re-plan landing mid-prefill (jobs resume under the new
plan); (5) ``predict_pool_counters(prefill_chunk_tokens=...)`` replaying a
chunked engine's books integer-exactly; (6) a hypothesis fuzz over chunk
size x shared-prefix length."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import runtime
from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.models import model
from repro.models.layers import split_params
from repro.serve import engine

MAX_SEQ, SLOTS, PAGE = 32, 2, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _plan(windows=(16, 16)):
    trace = engine.serve_trace_for(get_config("smollm-360m"),
                                   [(7, 6), (9, 5)], slots=SLOTS,
                                   layer_group=8)
    pl = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    return dataclasses.replace(pl, hot_window=MAX_SEQ // 2,
                               slot_hot_windows=list(windows),
                               page_tokens=PAGE)


def _toks(key, n, cfg):
    return jax.random.randint(jax.random.PRNGKey(key), (n,), 0,
                              cfg.vocab_size).astype(jnp.int32)


def _drive(params, cfg, plan, reqs, *, paged, keys=None, chunk=0,
           replan_at=None, new_plan=None):
    """Run a batcher to completion; returns (sorted output tuples, engine,
    whether a re-plan landed while a prefill job was in flight)."""
    b = engine.ContinuousBatcher(params, cfg, SLOTS, MAX_SEQ, plan=plan,
                                 paged=paged, prefill_chunk_tokens=chunk)
    for i, (t, d) in enumerate(reqs):
        b.submit(t, d, prefix_key=keys[i] if keys else None)
    results, steps, mid_prefill = [], 0, False
    while b.queue or b._jobs or any(b.active):
        if not b.step():
            break
        steps += 1
        if replan_at is not None and steps == replan_at:
            mid_prefill = bool(b._jobs)
            b.apply_plan(new_plan)
        for i in range(b.B):
            if not b.active[i] and b.outputs[i]:
                results.append(tuple(b.outputs[i]))
                b.outputs[i] = []
        assert steps < 500
    return sorted(results), b, mid_prefill


# ------------------------------------------------ model-level bit-identity ---

@pytest.mark.parametrize("fork", [PAGE, 2 * PAGE, PAGE + 2, 2 * PAGE + 3])
def test_prefill_suffix_matches_full_prefill(setup, fork):
    """Chunk boundary exactly on a page edge and mid-page: running the
    prompt as prefix-then-suffix against the prefix's dense cache produces
    the full prefill's last logits bit-for-bit."""
    cfg, params = setup
    S = 3 * PAGE + 1
    tokens = _toks(3, S, cfg)[None]
    full, _ = model.prefill(params, cfg, {"tokens": tokens})
    _, caches = model.prefill(params, cfg, {"tokens": tokens[:, :fork]},
                              max_seq=S)
    last, _ = model.prefill_suffix(params, cfg,
                                   {"tokens": tokens[:, fork:]},
                                   caches=caches, start=fork)
    assert jnp.array_equal(full, last)


# ------------------------------------------------- shared-prefix skip --------

@pytest.mark.parametrize("prefix_len", [2 * PAGE, 2 * PAGE + 1])
def test_shared_admit_skips_donor_pages(setup, prefix_len):
    """Sharing forks exactly at a page boundary and mid-page: the follower
    admits compute only over its suffix (strictly fewer prefill tokens than
    the unshared run), tokens identical to the dense all-HBM reference."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    plan = _plan()
    sys_p = _toks(7, prefix_len, cfg)
    reqs = [(jnp.concatenate([sys_p, _toks(11 + i, 2 + i, cfg)]), 5)
            for i in range(3)]
    base, _, _ = _drive(params, cfg, None, reqs, paged=False)
    out_s, b_s, _ = _drive(params, cfg_k, plan, reqs, paged=True,
                           keys=["sys"] * len(reqs))
    out_u, b_u, _ = _drive(params, cfg_k, plan, reqs, paged=True)
    assert base == out_s == out_u
    c_s, c_u = b_s.counters(), b_u.counters()
    assert c_s["prefill_compute_tokens"] < c_u["prefill_compute_tokens"]
    assert c_u["prefill_skipped_tokens"] == 0
    # every follower skips the donor's *full* pages (mid-page rows recompute)
    skip_each = (prefix_len // PAGE) * PAGE
    assert c_s["prefill_skipped_tokens"] == (len(reqs) - 1) * skip_each
    assert c_s["prefill_compute_tokens"] + c_s["prefill_skipped_tokens"] \
        == c_u["prefill_compute_tokens"]
    b_s.ptable.check()


# ------------------------------------------------- chunked admission ---------

@pytest.mark.parametrize("chunk", [PAGE, 2 * PAGE, 3 * PAGE])
def test_chunked_prefill_bit_identical_across_chunk_sizes(setup, chunk):
    """Long prompts admitted in page-aligned chunks while an anchor slot
    keeps decoding: same token set as one-shot admission and as the dense
    all-HBM engine, for every chunk size."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    plan = _plan()
    reqs = [(_toks(3, 5, cfg), 14), (_toks(4, 18, cfg), 4),
            (_toks(5, 15, cfg), 4)]
    base, _, _ = _drive(params, cfg, None, reqs, paged=False)
    one, b1, _ = _drive(params, cfg_k, plan, reqs, paged=True, chunk=0)
    chk, bc, _ = _drive(params, cfg_k, plan, reqs, paged=True, chunk=chunk)
    assert base == one == chk
    cc = bc.counters()
    assert cc["prefill_compute_tokens"] == \
        b1.counters()["prefill_compute_tokens"]
    # the chunker really split the admissions: some step ran a partial prompt
    sp = cc["step_prefill_tokens"]
    assert max(sp) <= max(chunk, PAGE) * SLOTS
    assert sum(sp) == cc["prefill_compute_tokens"]
    bc.ptable.check()


def test_chunk_requires_pool_layout(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="pool"):
        engine.ContinuousBatcher(params, cfg, SLOTS, MAX_SEQ, plan=_plan(),
                                 paged=True, prefill_chunk_tokens=PAGE)


def test_replan_lands_mid_prefill(setup):
    """A plan delta applied while a job is mid-prefill: the job resumes
    under the new plan and the run stays bit-identical to the dense
    reference."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    plan = _plan()
    shrunk = dataclasses.replace(plan, hot_window=8,
                                 slot_hot_windows=[4, 8])
    reqs = [(_toks(3, 5, cfg), 14), (_toks(4, 20, cfg), 4),
            (_toks(5, 16, cfg), 4)]
    base, _, _ = _drive(params, cfg, None, reqs, paged=False)
    out, b, mid = _drive(params, cfg_k, plan, reqs, paged=True, chunk=PAGE,
                         replan_at=2, new_plan=shrunk)
    assert mid                      # the re-plan really hit an in-flight job
    assert base == out
    assert b.plan.hot_window == 8
    b.ptable.check()


# ------------------------------------------------- replay exactness ----------

def test_predict_pool_counters_chunked_integer_exact(setup):
    """The pure-Python replay with ``prefill_chunk_tokens`` mirrors a
    chunked engine's books integer-for-integer: migration total and series,
    page copies, admit writes."""
    cfg, params = setup
    cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
    plan = _plan(windows=(4, 8))       # small windows: demotions occur
    requests = [(5, 9), (17, 4), (14, 5), (9, 6)]
    reqs = [(_toks(20 + i, p, cfg), d) for i, (p, d) in enumerate(requests)]
    for chunk in (0, PAGE, 2 * PAGE):
        _, b, _ = _drive(params, cfg_k, plan, reqs, paged=True, chunk=chunk)
        pred = engine.predict_pool_counters(
            requests, plan, slots=SLOTS, max_seq=MAX_SEQ,
            page_tokens=b.page_tokens, row_bytes=b._row_bytes,
            prefill_chunk_tokens=chunk)
        cnt = b.counters()
        assert pred["migration_bytes"] == cnt["sim_migration_bytes"]
        assert pred["step_migration_bytes"] == cnt["step_migration_bytes"]
        assert pred["page_copies"] == cnt["page_copies"]
        assert pred["admit_page_writes"] == cnt["admit_page_writes"]


# ------------------------------------------------- hypothesis fuzz -----------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                    # optional dev dep: skip, don't error
    HAVE_HYP = False


if HAVE_HYP:
    @given(chunk=st.sampled_from([0, PAGE, 2 * PAGE, 3 * PAGE]),
           prefix_len=st.integers(1, 3 * PAGE),
           seed=st.integers(0, 3))
    @settings(max_examples=8, deadline=None)
    def test_fuzz_chunk_x_prefix_bit_identical(setup, chunk, prefix_len,
                                               seed):
        """Random chunk size x shared-prefix length x request mix: the
        shared, chunked pool engine always reproduces the dense all-HBM
        token set."""
        cfg, params = setup
        cfg_k = dataclasses.replace(cfg, use_paged_decode=True)
        plan = _plan()
        sys_p = _toks(40 + seed, prefix_len, cfg)
        reqs = [(jnp.concatenate([sys_p, _toks(50 + seed + i, 1 + (seed + i) % 5,
                                               cfg)]), 3 + (seed + i) % 4)
                for i in range(3)]
        base, _, _ = _drive(params, cfg, None, reqs, paged=False)
        out, b, _ = _drive(params, cfg_k, plan, reqs, paged=True,
                           keys=["sys"] * len(reqs), chunk=chunk)
        assert base == out
        b.ptable.check()
