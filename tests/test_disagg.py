"""Prefill/decode disaggregation: the mesh page table and the engine pair.

Deterministic unit tests for ``MeshPageTable``'s namespace, cross-device
migration, and byte-conservation ledgers (the randomized op-program suite
lives in test_disagg_properties.py behind the optional hypothesis dep),
plus the ISSUE's engine acceptance row: ``DisaggregatedEngine`` emits
bit-identical tokens to the single-device ``ContinuousBatcher`` with zero
steady-state re-packs, and its cross-device ledger equals
``predict_pool_counters``'s predicted edge traffic integer-exactly.
"""
import dataclasses

import pytest

from repro.models.kvcache import MeshPageTable, PageTable

DEVS, SLOTS, NP, PG = 3, 2, 4, 8
PAGE_BYTES = float(PG * 64)


def make_mesh():
    return MeshPageTable([PageTable(SLOTS, NP, PG) for _ in range(DEVS)],
                         page_bytes=PAGE_BYTES)


# ------------------------------------------------------------ namespace ----

def test_global_namespace_unique():
    m = make_mesh()
    seen = set()
    for d in range(DEVS):
        for s in range(SLOTS):
            g = m.gslot(d, s)
            assert g not in seen
            seen.add(g)
            assert m.owner(g) == (d, s)
    assert seen == set(range(m.slots))
    with pytest.raises(ValueError):
        m.gslot(0, SLOTS)
    with pytest.raises(ValueError):
        m.owner(m.slots)


def test_share_refused_across_devices():
    m = make_mesh()
    src = m.gslot(0, 0)
    m.alloc(src, 0)
    with pytest.raises(ValueError):
        m.share(m.gslot(1, 0), src, 1)
    # same-device sharing still delegates through
    m.share(m.gslot(0, 1), src, 1)
    assert m.refcount(src, 0) == 2


def test_migrate_within_device_refused():
    m = make_mesh()
    g = m.gslot(0, 0)
    m.alloc(g, 0)
    with pytest.raises(ValueError):
        m.migrate_slot(g, m.gslot(0, 1))


def test_migrate_validates_before_mutating():
    """A refused migration must leave both tables and every ledger alone."""
    m = make_mesh()
    src = m.gslot(0, 0)
    for _ in range(NP):
        m.alloc(src, 0)
    dst = m.gslot(1, 0)
    m.alloc(dst, 0)                          # NP + 1 > pages_per_slot
    with pytest.raises(ValueError):
        m.migrate_slot(src, dst)
    assert m.n_pages(src) == NP and m.n_pages(dst) == 1
    assert m.edge_bytes == {} and m.host_internal_bytes == 0.0
    m.check()


def test_migrate_moves_shared_page_as_private_copy():
    m = make_mesh()
    src, sharer, dst = m.gslot(0, 0), m.gslot(0, 1), m.gslot(1, 0)
    m.alloc(src, 0)
    m.share(sharer, src, 1)
    assert m.refcount(src, 0) == 2
    out = m.migrate_slot(src, dst)
    assert out == {"pages": 1, "hot_bytes": PAGE_BYTES, "cold_bytes": 0.0}
    # the sharer keeps the original physical page, now exclusive
    assert m.refcount(sharer, 0) == 1
    assert m.refcount(dst, 0) == 1
    assert m.edge_bytes == {("dev0", "dev1"): PAGE_BYTES}
    m.check()


def test_cold_pages_rehome_inside_host_memory():
    m = make_mesh()
    src, dst = m.gslot(0, 0), m.gslot(1, 0)
    m.alloc(src, 0)
    m.alloc(src, 0)
    m.demote(src, 0)                         # does not remove the hot page
    # build a fully-cold slot: free and re-alloc one cold page
    m.free_slot(src)
    m.alloc(src, 1)
    out = m.migrate_slot(src, dst)
    assert out["cold_bytes"] == PAGE_BYTES and out["hot_bytes"] == 0.0
    assert m.edge_bytes == {}                # no device link touched
    assert m.host_internal_bytes == PAGE_BYTES
    m.check()


# ------------------------------------------------------------ the engines ----

@pytest.fixture(scope="module")
def engine_pair():
    import jax
    import jax.numpy as jnp

    from repro import runtime
    from repro.configs.base import get_config
    from repro.core.hardware import TPU_V5E
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve import engine
    from repro.serve.disagg import DisaggregatedEngine
    from repro.serve.engine import serve_trace_for

    cfg = dataclasses.replace(get_config("smollm-360m").reduced(),
                              use_paged_decode=True)
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    max_seq, slots = 32, 2
    requests = [(7, 6), (9, 5), (6, 7), (8, 6)]
    trace = serve_trace_for(get_config("smollm-360m"), requests,
                            slots=slots, layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def drive(eng_cls, **kw):
        b = eng_cls(params, cfg, slots, max_seq, plan=plan, **kw)
        key = jax.random.PRNGKey(3)
        for plen, d in requests:
            key, sub = jax.random.split(key)
            b.submit(jax.random.randint(
                sub, (plen,), 0, cfg.vocab_size).astype(jnp.int32), d)
        return b.run(), b

    out_c, bc = drive(engine.ContinuousBatcher, paged=True)
    out_d, bd = drive(DisaggregatedEngine)
    return requests, plan, (out_c, bc), (out_d, bd)


def test_disagg_engine_bit_identical(engine_pair):
    _, _, (out_c, _), (out_d, _) = engine_pair
    assert out_c == out_d


def test_disagg_engine_zero_repacks(engine_pair):
    _, _, _, (_, bd) = engine_pair
    assert bd.counters()["repacks"] == 0


def test_disagg_ledger_matches_prediction_exactly(engine_pair):
    from repro.serve.engine import predict_pool_counters
    requests, plan, (_, bc), (_, bd) = engine_pair
    pred = predict_pool_counters(requests, plan, slots=2, max_seq=32,
                                 page_tokens=bd.page_tokens,
                                 row_bytes=bd._row_bytes)
    assert bd.xdev_migration_bytes == pred["xdev_migration_bytes"]
    assert bd.xdev_migration_bytes > 0
    # the decode-side tiering accounting is untouched by disaggregation
    assert bd.sim_migration_bytes == bc.sim_migration_bytes
    bd.mesh_table.check()


def test_disagg_requires_pools_layout():
    import jax

    from repro import runtime
    from repro.configs.base import get_config
    from repro.core.hardware import TPU_V5E
    from repro.models import model
    from repro.models.layers import split_params
    from repro.serve.disagg import DisaggregatedEngine
    from repro.serve.engine import serve_trace_for

    cfg = get_config("smollm-360m").reduced()   # no use_paged_decode
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    trace = serve_trace_for(get_config("smollm-360m"), [(7, 6)], slots=2,
                            layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=16, slot_hot_windows=[4, 8],
                               page_tokens=4)
    with pytest.raises(ValueError):
        DisaggregatedEngine(params, cfg, 2, 32, plan=plan)
    with pytest.raises(ValueError):
        DisaggregatedEngine(params, cfg, 2, 32, plan=None)


def test_price_disagg_prefill_heavy_wins():
    """The planner-side model of the ISSUE's throughput gate: under a
    prefill-heavy mix, disaggregated tokens/sec at or above colocated at
    equal total HBM, with the KV stream priced on the device edge."""
    from repro.core.hardware import default_cost_model
    from repro.serve.disagg import price_disagg
    from repro.serve.engine import serve_trace_for
    from repro.configs.base import get_config

    cfg = get_config("smollm-360m")
    heavy = [(480, 24), (512, 16), (448, 32), (500, 20)]
    trace = serve_trace_for(cfg, heavy, slots=4, layer_group=8)
    res = price_disagg(trace, default_cost_model(),
                       0.2 * trace.peak_kv_bytes())
    assert res["disagg"].tokens_per_s >= res["colocated"].tokens_per_s
    assert res["edge_bytes"] > 0
    assert set(res["graph"].names) == {"dev0", "dev1", "host"}


def test_disagg_groups_split():
    from repro.launch.mesh import disagg_groups
    one = ["a"]
    p, d = disagg_groups(one)
    assert p == d == one                     # degenerate single device
    # decode leads (it owns the pools + the default device) and takes the
    # larger share on odd counts
    p, d = disagg_groups(["a", "b", "c"])
    assert d == ["a", "b"] and p == ["c"]
    p, d = disagg_groups(["a", "b", "c", "d"])
    assert d == ["a", "b"] and p == ["c", "d"]
