"""Paged decode-attention: Pallas kernel vs the page-loop jnp oracle
(bit-exact in interpret mode), the oracle vs the dense decode oracle, the
pool-packing helper's layout invariants, and ops dispatch."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_decode import paged_decode_attention, pack_kv_pools

KEY = jax.random.PRNGKey(11)


def make_case(B, S, H, KVH, D, page, dtype=jnp.float32, cold_frac=0.5):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, S, KVH, D), dtype)
    vc = jax.random.normal(ks[2], (B, S, KVH, D), dtype)
    lengths = jnp.array([S - 1 - (5 * b) % (S // 2) for b in range(B)],
                        jnp.int32)
    cold = [int(int(l) * cold_frac) for l in lengths]
    pools = pack_kv_pools(kc, vc, cold, page)
    return q, kc, vc, lengths, pools


@pytest.mark.parametrize("B,S,H,KVH,D,page", [
    (2, 64, 4, 2, 16, 8),
    (3, 128, 8, 4, 32, 16),
    (1, 32, 2, 1, 128, 8),       # MQA, page smaller than D
    (2, 96, 6, 2, 64, 16),       # non-power-of-two heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_bit_exact_vs_oracle(B, S, H, KVH, D, page, dtype):
    """The kernel and the oracle run the same op sequence (shared
    masked_scores/online_softmax_update), so interpret mode must agree
    bit-for-bit, not merely to tolerance."""
    q, _, _, lengths, (kh, vh, kc, vc, tab, tier) = make_case(
        B, S, H, KVH, D, page, dtype)
    out = paged_decode_attention(q, kh, vh, kc, vc, tab, tier, lengths,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kh, vh, kc, vc, tab, tier,
                                          lengths)
    assert out.dtype == q.dtype
    assert jnp.array_equal(out, want)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (24, 0.0), (0, 30.0),
                                        (16, 50.0)])
def test_paged_kernel_window_softcap_bit_exact(window, cap):
    q, _, _, lengths, (kh, vh, kc, vc, tab, tier) = make_case(
        2, 96, 4, 2, 32, 16)
    out = paged_decode_attention(q, kh, vh, kc, vc, tab, tier, lengths,
                                 window=window, softcap_val=cap,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, kh, vh, kc, vc, tab, tier,
                                          lengths, window=window,
                                          softcap_val=cap)
    assert jnp.array_equal(out, want)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (24, 0.0), (8, 30.0)])
def test_paged_oracle_matches_dense_decode(window, cap):
    """Paging (and the hot/cold split) is a layout change only: the paged
    oracle agrees with the dense decode oracle to float tolerance."""
    q, kc_d, vc_d, lengths, (kh, vh, kc, vc, tab, tier) = make_case(
        2, 64, 4, 2, 32, 8)
    out = ref.paged_decode_attention_ref(q, kh, vh, kc, vc, tab, tier,
                                         lengths, window=window,
                                         softcap_val=cap)
    want = ref.decode_attention_ref(q, kc_d, vc_d, lengths, window=window,
                                    softcap_val=cap)
    assert jnp.max(jnp.abs(out - want)) < 1e-4


def test_pack_kv_pools_layout_invariants():
    """Physical ids are unique within a tier, tiers form a per-slot cold
    prefix, and gathering pages back through the table reconstructs the
    dense cache exactly."""
    B, S, KVH, D, page = 3, 64, 2, 16, 8
    ks = jax.random.split(KEY, 2)
    kc = jax.random.normal(ks[0], (B, S, KVH, D))
    vc = jax.random.normal(ks[1], (B, S, KVH, D))
    cold = [16, 0, 40]
    kh, vh, kcold, vcold, tab, tier = pack_kv_pools(kc, vc, cold, page)
    NP = S // page
    for t in (0, 1):
        ids = [int(tab[b, i]) for b in range(B) for i in range(NP)
               if int(tier[b, i]) == t]
        assert len(ids) == len(set(ids))
    for b in range(B):
        n_cold = cold[b] // page
        assert [int(x) for x in tier[b]] == [1] * n_cold + [0] * (NP - n_cold)
    # reconstruct
    for b in range(B):
        for i in range(NP):
            pool = kcold if int(tier[b, i]) else kh
            assert jnp.array_equal(pool[int(tab[b, i])],
                                   kc[b, i * page:(i + 1) * page])


def test_ops_dispatch_paged():
    """ops.paged_decode_attention: jnp oracle on CPU by default; forced
    Pallas path (interpret) returns the identical array."""
    q, _, _, lengths, (kh, vh, kc, vc, tab, tier) = make_case(
        2, 64, 4, 2, 16, 8)
    want = ref.paged_decode_attention_ref(q, kh, vh, kc, vc, tab, tier,
                                          lengths)
    out = ops.paged_decode_attention(q, kh, vh, kc, vc, tab, tier, lengths)
    assert jnp.array_equal(out, want)
    ops.use_pallas(True)
    try:
        out_pl = ops.paged_decode_attention(q, kh, vh, kc, vc, tab, tier,
                                            lengths)
    finally:
        ops.use_pallas(False)
    assert jnp.array_equal(out_pl, want)
