"""Roofline-measurement layer: jaxpr trip-aware costing + HLO collective
parsing (the §Roofline methodology is itself under test)."""
import jax
import jax.numpy as jnp

from repro.launch.costing import jaxpr_cost


def test_scan_trip_counts_multiply():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=8)
        return c.sum()

    jx = jax.make_jaxpr(f)(jnp.ones((64, 64)), jnp.ones((64, 64)))
    cost = jaxpr_cost(jx)
    one = 2 * 64 ** 3
    assert abs(cost["matmul_flops"] - 8 * one) / (8 * one) < 0.01


def test_nested_scan_trips():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c.sum()

    jx = jax.make_jaxpr(f)(jnp.ones((32, 32)), jnp.ones((32, 32)))
    cost = jaxpr_cost(jx)
    one = 2 * 32 ** 3
    assert abs(cost["matmul_flops"] - 12 * one) / (12 * one) < 0.01


def test_grad_includes_backward_flops():
    def f(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    x = jnp.ones((64, 64))
    fwd = jaxpr_cost(jax.make_jaxpr(f)(x, x))["matmul_flops"]
    bwd = jaxpr_cost(jax.make_jaxpr(jax.grad(f))(x, x))["matmul_flops"]
    assert bwd >= 1.9 * fwd  # fwd + dW matmul (x is not differentiated)


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """\
%wide.region_1 (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ar = f32[16,128]{1,0} all-reduce(%x), to_apply=%add
}
ENTRY %main (p0: f32[256]) -> f32[256] {
  %ag = bf16[1024]{0} all-gather(%y), dimensions={0}
  %ar2 = f32[256]{0} all-reduce(%z), to_apply=%add.clone_promoted
}
"""
    out = collective_bytes(hlo, loop_trips=10.0)
    assert out["counts"]["all-reduce"] == 2
    assert out["counts"]["all-gather"] == 1
    # loop-body AR x10 trips; ENTRY AG x1; promoted ENTRY AR halved
    assert out["bytes"]["all-reduce"] == 16 * 128 * 4 * 10 + 256 * 4 * 0.5
    assert out["bytes"]["all-gather"] == 1024 * 2
