"""Architecture-signature tests: the structural features each assigned arch
is known for actually hold in the built models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_batch
from repro.configs.base import SHARED_ATTN, get_config
from repro.models import kvcache, model
from repro.models.layers import split_params


def test_zamba2_shared_attention_single_copy(rng):
    """Zamba signature: ONE attention weight copy serves every shared block."""
    cfg = get_config("zamba2-7b")
    params_sds, _ = __import__("repro.launch.specs", fromlist=["specs"]) \
        .param_structs(cfg)
    stack = params_sds["stack"]
    assert "shared" in stack
    # the shared slot in the scanned stack carries no weights
    shared_slot = [s for s, kind in enumerate(cfg.period)
                   if kind == SHARED_ATTN]
    for s in shared_slot:
        assert not jax.tree.leaves(stack["slots"][s])
    # but every period still gets its own KV cache for that slot
    caches = jax.eval_shape(lambda: kvcache.init_cache(cfg, 1, 128))
    assert caches["slots"][shared_slot[0]]["k"].shape[0] == cfg.num_periods


def test_zamba2_shared_grads_accumulate(rng):
    """Gradients through the shared block accumulate across its uses."""
    cfg = get_config("zamba2-7b").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng, B=1, S=8)
    g = jax.grad(lambda p: model.loss_fn(p, cfg, batch))(params)
    gw = g["stack"]["shared"]["attn"]["wq"]
    assert float(jnp.abs(gw).sum()) > 0


def test_mla_cache_is_compressed():
    """DeepSeek MLA: the decode cache holds the latent (kv_lora + rope dims),
    not full K/V — the whole point of MLA."""
    cfg = get_config("deepseek-v2-lite-16b")
    caches = jax.eval_shape(lambda: kvcache.init_cache(cfg, 1, 1024))
    layer = caches["slots"][0]
    per_tok = layer["ckv"].shape[-1] + layer["krope"].shape[-1]
    full_kv = 2 * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    assert per_tok == cfg.kv_lora_rank + cfg.qk_rope_dim == 576
    assert per_tok < full_kv / 7  # >7x compression


def test_gemma_local_global_pattern():
    g3 = get_config("gemma3-12b")
    assert list(g3.period).count("local") == 5 and list(g3.period).count("attn") == 1
    g2 = get_config("gemma2-2b")
    assert list(g2.period) == ["local", "attn"]
    assert g2.attn_softcap == 50.0 and g2.final_softcap == 30.0


def test_sliding_window_actually_masks(rng):
    """A token beyond the window cannot influence a local layer's output."""
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-2b").reduced(),
                              sliding_window=4)
    params, _ = split_params(model.init_params(rng, cfg))
    toks = jax.random.randint(rng, (1, 16), 0, cfg.vocab_size).astype(jnp.int32)
    out1, _, _ = model.forward(params, cfg, {"tokens": toks})
    # flip token 0: positions >= 0+window in PURE-local stacks would be
    # unaffected, but global layers see everything; so flip and check that
    # the local mask at least keeps position 1..3 behaviour consistent:
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    out2, _, _ = model.forward(params, cfg, {"tokens": toks2})
    # position 0 logits must change; late positions may change via global
    assert not np.allclose(np.asarray(out1[0, 0]), np.asarray(out2[0, 0]))


def test_musicgen_codebook_shapes(rng):
    cfg = get_config("musicgen-medium").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng, B=2, S=8)
    logits, _, _ = model.forward(params, cfg, batch)
    assert logits.shape == (2, 8, 4, cfg.padded_vocab)


def test_paligemma_prefix_is_bidirectional(rng):
    """Prefix-LM: a LATER prefix patch influences an EARLIER prefix position
    (impossible under causal masking)."""
    cfg = get_config("paligemma-3b").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    batch = tiny_batch(cfg, rng, B=1, S=8)
    out1, _, _ = model.forward(params, cfg, batch)
    pe = batch["prefix_embed"].at[0, -1].add(1.0)   # last prefix token
    out2, _, _ = model.forward(params, cfg, {**batch, "prefix_embed": pe})
    # position 0 (earlier than the perturbed prefix token) must change
    assert not np.allclose(np.asarray(out1[0, 0]), np.asarray(out2[0, 0]),
                           atol=1e-6)


def test_causal_no_future_leak(rng):
    """Pure causal arch: perturbing token t never changes logits at < t."""
    cfg = get_config("smollm-360m").reduced()
    params, _ = split_params(model.init_params(rng, cfg))
    toks = jax.random.randint(rng, (1, 12), 0, cfg.vocab_size).astype(jnp.int32)
    out1, _, _ = model.forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, 6].set((toks[0, 6] + 1) % cfg.vocab_size)
    out2, _, _ = model.forward(params, cfg, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(out1[0, :6]),
                               np.asarray(out2[0, :6]), atol=1e-5)
