"""Tier-graph runtime: the directed-graph generalization of the two-tier
model (``runtime/tiergraph.py``).

The load-bearing claim is *exact backward equivalence*: a 2-node
``TierGraph`` built from a two-tier machine must reproduce today's behavior
byte-for-byte — every registered policy's simulation result, the planner's
serialized plan JSON, and the cost model's priced step times — so the graph
path can sit underneath the whole runtime without a compatibility flag.
"""
import json

import pytest

from repro import runtime
from repro.core.hardware import HWSpec, TPU_V5E
from repro.runtime import TPU_V5E_COST, GraphHW, TierEdge, TierGraph
from repro.runtime.objects import tiers_from_hw
from repro.runtime.synthetic import synthetic_profile, synthetic_serve_trace

HW = HWSpec("diff", peak_flops=1e12, fast_bw=100e9, slow_bw=20e9,
            mig_bw=20e9, fast_bytes=1e9)

KNOBS = {"sentinel": {"lookahead": 6}, "sentinel_slo": {"lookahead": 6},
         "alpha_migration": {"lookahead": 6},
         "lru_page": {"page_bytes": 4096}, "sentinel_mi": {"mi": 3},
         "ial": {"repeats": 2}, "lru": {"repeats": 2}}


def policies():
    return [p for p in runtime.list_policies() if p != "base"]


# ------------------------------------------------------------- structure ----

def test_two_tier_shape():
    g = TierGraph.two_tier(HW, 1e9)
    assert g.names == ["fast", "slow"]
    assert g.is_two_tier
    assert g.capacity("fast") == 1e9
    assert g.capacity("slow") is None
    assert g.edge_bw("slow", "fast") == HW.mig_bw
    assert g.edge_bw("fast", "slow") == HW.mig_bw
    assert g.matches_two_tier(HW, 1e9)
    assert not g.matches_two_tier(HW, 2e9)


def test_two_tier_matches_legacy_tiers():
    g = TierGraph.two_tier(HW, 1e9)
    assert g.tiers == tiers_from_hw(HW, 1e9)


def test_two_tier_edges_split_by_dma_direction():
    """On a CostModel the promote edge is the migration *read* DMA and the
    demote edge the *write* DMA — the directions the two-tier model folded
    into one ``mig_bw``."""
    g = TierGraph.two_tier(TPU_V5E_COST, 1e9)
    assert g.edge_bw("slow", "fast") == TPU_V5E_COST.mig_read_bw
    assert g.edge_bw("fast", "slow") == TPU_V5E_COST.mig_write_bw


def test_validation():
    fast = TierGraph.two_tier(HW, 1e9).node("fast")
    slow = TierGraph.two_tier(HW, 1e9).node("slow")
    with pytest.raises(ValueError):          # duplicate names
        TierGraph((fast, fast))
    with pytest.raises(ValueError):          # unknown edge endpoint
        TierGraph((fast, slow), (TierEdge("fast", "ghost", 1e9),))
    with pytest.raises(ValueError):          # self-edge
        TierGraph((fast, slow), (TierEdge("fast", "fast", 1e9),))
    with pytest.raises(ValueError):          # non-positive bandwidth
        TierGraph((fast, slow), (TierEdge("slow", "fast", 0.0),))


def test_mesh_widest_path():
    g = TierGraph.mesh(2, TPU_V5E_COST, 1e9)
    assert set(g.names) == {"dev0", "dev1", "host"}
    # direct host->dev edge
    assert g.path_bw("host", "dev0") == TPU_V5E_COST.mig_read_bw
    # dev<->dev goes over the inter-device link when one exists, else 0
    link = getattr(TPU_V5E_COST, "link_bw", 0.0)
    if link:
        assert g.path_bw("dev0", "dev1") == pytest.approx(link)
    assert g.path_bw("dev0", "dev0") == float("inf")


def test_serialization_round_trip():
    for g in (TierGraph.two_tier(HW, 1e9),
              TierGraph.mesh(3, TPU_V5E_COST, 1e9, link_bw=40e9)):
        back = TierGraph.from_dict(g.to_dict())
        assert back == g
        assert json.dumps(back.to_dict()) == json.dumps(g.to_dict())


def test_graph_hw_view_folds_to_machine():
    g = TierGraph.two_tier(HW, 1e9)
    v = g.hw_view(HW)
    assert isinstance(v, GraphHW)
    assert v.fast_bw == HW.fast_bw
    assert v.slow_bw == HW.slow_bw
    assert v.mig_bw == HW.mig_bw
    assert v.fast_bytes == 1e9
    assert v.peak_flops == HW.peak_flops        # delegated to the machine


# ----------------------------------------------- backward equivalence -------

@pytest.mark.parametrize("policy", policies())
def test_every_policy_identical_through_two_tier_graph(policy):
    """The differential oracle of this PR: simulate() through the canonical
    2-node graph is bit-identical to the legacy two-tier path for every
    registered policy."""
    tr = synthetic_serve_trace()
    fast = 0.2 * tr.peak_kv_bytes()
    knobs = KNOBS.get(policy, {})
    legacy = runtime.simulate(tr, HW, fast, policy, **knobs)
    graph = runtime.simulate(tr, HW, fast, policy,
                             tier_graph=TierGraph.two_tier(HW, fast),
                             **knobs)
    assert legacy.time == graph.time
    assert legacy.compute_time == graph.compute_time
    assert legacy.migrations == graph.migrations
    assert legacy.bytes_s2f == graph.bytes_s2f
    assert legacy.bytes_f2s == graph.bytes_f2s


@pytest.mark.parametrize("objective", ["bytes", "latency"])
def test_plan_byte_identical_through_two_tier_graph(objective):
    tr = synthetic_serve_trace()
    fast = 0.2 * tr.peak_kv_bytes()
    base = runtime.plan(tr, TPU_V5E_COST, fast, objective=objective)
    via = runtime.plan(tr, TPU_V5E_COST, fast, objective=objective,
                       tier_graph=TierGraph.two_tier(TPU_V5E_COST, fast))
    assert via.to_json() == base.to_json()
    # the canonical two-tier graph is folded away: no key in the wire form
    assert "tier_graph" not in json.loads(base.to_json())


def test_training_plan_byte_identical_through_two_tier_graph():
    prof = synthetic_profile()
    fast = 0.3 * prof.peak_bytes()
    base = runtime.plan(prof, TPU_V5E, fast)
    via = runtime.plan(prof, TPU_V5E, fast,
                       tier_graph=TierGraph.two_tier(TPU_V5E, fast))
    assert via.to_json() == base.to_json()


def test_mesh_plan_carries_graph_and_round_trips():
    tr = synthetic_serve_trace()
    fast = 0.2 * tr.peak_kv_bytes()
    g = TierGraph.mesh(2, TPU_V5E_COST, fast)
    pl = runtime.plan(tr, TPU_V5E_COST, fast, tier_graph=g)
    assert pl.tier_graph is not None
    assert TierGraph.from_dict(pl.tier_graph) == g
    back = runtime.PlacementPlan.from_json(pl.to_json())
    assert back.to_json() == pl.to_json()
    assert [t.name for t in pl.tiers] == g.names


def test_price_on_graph_two_tier_is_price():
    """Pricing a traffic series on the canonical 2-node graph returns the
    exact two-tier report: the edge pipes can never exceed the serialized
    migration term already inside step_time."""
    cm = TPU_V5E_COST
    tr = synthetic_serve_trace()
    fast = 0.2 * tr.peak_kv_bytes()
    res = runtime.simulate(tr, cm, fast, "sentinel", lookahead=6)
    base = cm.price(res.step_traffic)
    g = cm.price_on_graph(res.step_traffic, TierGraph.two_tier(cm, fast))
    assert g.step_times == base.step_times
    assert g.time == base.time
    assert g.compute_time == base.compute_time
    assert g.tokens == base.tokens


def test_price_on_graph_unreachable_edge_raises():
    cm = TPU_V5E_COST
    tr = synthetic_serve_trace()
    fast = 0.2 * tr.peak_kv_bytes()
    res = runtime.simulate(tr, cm, fast, "sentinel", lookahead=6)
    two = TierGraph.two_tier(cm, fast)
    # drop the demote edge: fast -> slow traffic has no path at all
    g = TierGraph(two.nodes, (two.edges[0],))
    flows = [{} for _ in res.step_traffic]
    flows[0] = {("fast", "slow"): 1.0}
    with pytest.raises(ValueError):
        cm.price_on_graph(res.step_traffic, g, flows)


def test_golden_plans_unchanged():
    """The three checked-in golden plans predate the tier graph; rerouting
    ``tiers_from_hw`` through ``TierGraph.two_tier`` must leave their wire
    form untouched (covered in depth by test_runtime_api, asserted here
    against the files so a regression points at this subsystem)."""
    import pathlib
    gold = pathlib.Path(__file__).parent / "golden"
    for name in ("latency_plan.json", "multi_tenant_plan.json"):
        text = (gold / name).read_text()
        assert "tier_graph" not in text
