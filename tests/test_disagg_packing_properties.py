"""Property-based suite (hypothesis) for slot->shard packings.

Fuzzes random slot->device packings through the pure-python replay
(``predict_pool_counters``) and through the planner's packer: for ANY
legal packing the per-edge admit ledger must attribute every stream to
the slot's owning shard and sum to ``xdev_migration_bytes``, and
``pack_slots`` must always emit a geometry ``validate_slot_devices``
accepts.  The deterministic seeded twins of these properties live in
``test_disagg_multidev.py`` so the invariants stay exercised without the
optional dep.
"""
import dataclasses

import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_replay_edge_ledger_under_random_packings(data):
    from repro import runtime
    from repro.core.hardware import TPU_V5E
    from repro.core.hmsim import build_serve_trace
    from repro.serve.engine import predict_pool_counters
    slots = data.draw(st.integers(2, 4))
    n_dev = data.draw(st.integers(1, 3))
    packing = [data.draw(st.integers(0, n_dev - 1)) for _ in range(slots)]
    reqs = [(data.draw(st.integers(5, 14)), data.draw(st.integers(3, 7)))
            for _ in range(data.draw(st.integers(slots, slots + 3)))]
    trace = build_serve_trace(reqs, num_slots=slots, num_layers=4,
                              kv_token_bytes=64)
    plan = runtime.plan(trace, TPU_V5E, 0.3 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, page_tokens=4, hot_window=8,
                               slot_hot_windows=None)
    pred = predict_pool_counters(reqs, plan, slots=slots, max_seq=32,
                                 page_tokens=4, row_bytes=64.0,
                                 dense_admit=True, slot_devices=packing)
    edges = pred["edge_migration_bytes"]
    used = {f"dev{d}" for d in packing}
    for (src, dst), v in edges.items():
        assert src == "prefill" and dst in used
        assert v >= 0 and v == int(v)
    assert sum(edges.values()) == pred["xdev_migration_bytes"]
    assert set(pred["device_hot_peak"]) <= used


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_pack_slots_legal_and_balanced(data):
    from repro.runtime.plan import pack_slots, validate_slot_devices
    slots = data.draw(st.integers(1, 8))
    n_dev = data.draw(st.integers(1, 4))
    weights = [data.draw(st.floats(0.0, 1e6, allow_nan=False))
               for _ in range(slots)]
    out = pack_slots(weights, n_dev)
    assert validate_slot_devices(out, slots, n_dev) == out
    counts = [out.count(d) for d in range(n_dev)]
    if slots >= n_dev:
        # LPT never leaves a device idle while another stacks up
        assert min(counts) >= 1 or max(counts) <= 1
