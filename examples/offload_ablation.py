"""Sentinel offload ablation: the four runtime modes on one model, verifying
numerical equivalence and reporting the jaxpr-level memory profile of each —
the CPU-visible proxy for the HBM savings the offload buys on TPU.

    PYTHONPATH=src python examples/offload_ablation.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import profiler
from repro.core.offload import SentinelConfig, loss_kwargs
from repro.models import model
from repro.models.layers import split_params

cfg = get_config("smollm-360m").reduced()
params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
batch = {"tokens": jnp.ones((4, 64), jnp.int32),
         "labels": jnp.ones((4, 64), jnp.int32)}

ref_loss = None
for mode in ["full", "save_hbm", "offload", "remat"]:
    for mi in ([1, 2] if mode != "full" else [1]):
        scfg = SentinelConfig(mode=mode, mi_periods=mi)
        kw = loss_kwargs(scfg)
        fn = jax.jit(jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch, **kw)))
        loss, grads = fn(params)
        co = fn.lower(params).compile()
        ma = co.memory_analysis()
        fl = co.cost_analysis()["flops"]
        if ref_loss is None:
            ref_loss = float(loss)
        drift = abs(float(loss) - ref_loss)
        print(f"mode={mode:9s} MI={mi}: loss drift {drift:.2e} | "
              f"temp {ma.temp_size_in_bytes / 1e6:7.1f} MB | "
              f"flops {fl / 1e9:6.2f} G (recompute shows up here)")
