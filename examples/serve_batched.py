"""Batched serving example: prefill a batch of prompts, decode in lockstep,
including a MusicGen-style 4-codebook stream and a PaliGemma-style
image-prefix request — then Sentinel-Serve tiered continuous batching: the
decode-phase planner picks a hot window, the cold KV prefix is held in host
memory, and the tiered run reproduces the all-HBM outputs exactly.

    PYTHONPATH=src python examples/serve_batched.py

``--disagg`` instead demos prefill/decode disaggregation on a 2-device CPU
mesh (forced host devices): prefill runs on one device, the finished KV
pages stream over the device edge into the decode pools, and the outputs
match the single-device engine bit for bit.

    PYTHONPATH=src python examples/serve_batched.py --disagg
"""
import os
import sys

if "--disagg" in sys.argv:               # must land before the jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import time

import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.models import model
from repro.models.layers import split_params
from repro.serve import engine


def demo(arch: str, num_tokens: int = 16):
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(1)
    B, S = 4, 12
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prompts = {"tokens": toks.astype(jnp.int32)}
    if cfg.num_prefix_tokens:
        prompts["prefix_embed"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model))
    t0 = time.perf_counter()
    out = engine.generate(params, cfg, prompts, num_tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{arch:24s} generated {out.shape} in {dt:5.2f}s "
          f"({B * num_tokens / dt:7.1f} tok/s)")


def demo_tiered(arch: str = "smollm-360m", slots: int = 2, max_seq: int = 48):
    """Tiered continuous batching end-to-end: plan -> cold prefix on host ->
    identical outputs to the all-HBM batcher."""
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    requests = [(8 + i, 6) for i in range(2 * slots)]

    # plan on the serving trace (full-size byte geometry, grouped objects)
    trace = engine.serve_trace_for(get_config(arch), requests, slots=slots,
                                   layer_group=8)
    fast = 0.2 * trace.peak_kv_bytes()
    plan = runtime.plan(trace, TPU_V5E, fast)
    print(f"[plan] hot_window={plan.hot_window} tokens, "
          f"lookahead={plan.lookahead}, cold_len({max_seq})="
          f"{plan.cold_len(max_seq)}")
    for pol in ("prefer_fast", "lru_page", "sentinel", "sentinel_mi"):
        r = runtime.simulate(trace, TPU_V5E, fast, pol)
        print(f"[sim]  {pol:12s} {r.decode_throughput:9.1f} tok/s "
              f"(slowdown {r.slowdown:.3f}, {r.migrations} migrations)")

    def run(p, paged=False):
        b = engine.ContinuousBatcher(params, cfg, slots, max_seq, plan=p,
                                     paged=paged)
        key = jax.random.PRNGKey(7)
        for (plen, d) in requests:
            key, sub = jax.random.split(key)
            toks = jax.random.randint(sub, (plen,), 0,
                                      cfg.vocab_size).astype(jnp.int32)
            b.submit(toks, d)
        t0 = time.perf_counter()
        out = b.run()
        return out, time.perf_counter() - t0, b.sim_migration_bytes

    # force a real cold prefix even if the planned window covers max_seq
    import dataclasses
    tiered_plan = dataclasses.replace(
        plan, hot_window=min(plan.hot_window, max_seq // 2),
        slot_hot_windows=[min(w, max_seq // 2)
                          for w in (plan.slot_hot_windows or [])] or None,
        page_tokens=min(plan.page_tokens or 8, 8))
    base, t_base, _ = run(None)
    tier, t_tier, mig_c = run(tiered_plan)
    pag, t_pag, mig_p = run(tiered_plan, paged=True)
    match = base == tier == pag
    print(f"[e2e]  all-HBM {t_base:5.2f}s | concat-tiered {t_tier:5.2f}s "
          f"({mig_c / 1e6:.2f} MB re-hosted) | paged per-slot {t_pag:5.2f}s "
          f"({mig_p / 1e6:.2f} MB re-hosted) | outputs match: {match}")
    assert match, "tiered decode diverged from the all-HBM reference"
    assert mig_p <= mig_c, "per-slot paging moved more bytes than concat"


def demo_disagg(arch: str = "smollm-360m", slots: int = 2,
                max_seq: int = 32):
    """Prefill/decode disaggregation across the forced 2-device host mesh:
    same plan, same requests, bit-identical outputs — with every admitted
    page crossing the prefill->decode edge as an accounted migration."""
    import dataclasses

    from repro.launch.mesh import disagg_groups
    from repro.serve.disagg import DisaggregatedEngine

    prefill_devs, decode_devs = disagg_groups()
    print(f"[mesh] {len(jax.devices())} devices: "
          f"prefill={prefill_devs} decode={decode_devs}")
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              use_paged_decode=True)
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    requests = [(7, 6), (9, 5), (6, 7), (8, 6)]
    trace = engine.serve_trace_for(get_config(arch), requests, slots=slots,
                                   layer_group=8)
    plan = runtime.plan(trace, TPU_V5E, 0.2 * trace.peak_kv_bytes())
    plan = dataclasses.replace(plan, hot_window=max_seq // 2,
                               slot_hot_windows=[4, 8], page_tokens=4)

    def run(eng_cls, **kw):
        b = eng_cls(params, cfg, slots, max_seq, plan=plan, **kw)
        key = jax.random.PRNGKey(7)
        for (plen, d) in requests:
            key, sub = jax.random.split(key)
            toks = jax.random.randint(sub, (plen,), 0,
                                      cfg.vocab_size).astype(jnp.int32)
            b.submit(toks, d)
        t0 = time.perf_counter()
        out = b.run()
        return out, time.perf_counter() - t0, b

    base, t_base, _ = run(engine.ContinuousBatcher, paged=True)
    dis, t_dis, bd = run(DisaggregatedEngine)
    match = base == dis
    print(f"[e2e]  single-device {t_base:5.2f}s | disaggregated "
          f"{t_dis:5.2f}s ({bd.xdev_migration_bytes / 1e3:.1f} kB over the "
          f"prefill->decode edge, {bd.counters()['repacks']} re-packs) | "
          f"outputs match: {match}")
    assert match, "disaggregated decode diverged from the single-device run"


if __name__ == "__main__":
    if "--disagg" in sys.argv:
        demo_disagg()
    else:
        for arch in ["smollm-360m", "gemma2-2b", "musicgen-medium",
                     "paligemma-3b", "zamba2-7b", "xlstm-1.3b"]:
            demo(arch)
        demo_tiered()
