"""Batched serving example: prefill a batch of prompts, decode in lockstep,
including a MusicGen-style 4-codebook stream and a PaliGemma-style
image-prefix request.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import model
from repro.models.layers import split_params
from repro.serve import engine


def demo(arch: str, num_tokens: int = 16):
    cfg = get_config(arch).reduced()
    params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
    key = jax.random.PRNGKey(1)
    B, S = 4, 12
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prompts = {"tokens": toks.astype(jnp.int32)}
    if cfg.num_prefix_tokens:
        prompts["prefix_embed"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model))
    t0 = time.perf_counter()
    out = engine.generate(params, cfg, prompts, num_tokens)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{arch:24s} generated {out.shape} in {dt:5.2f}s "
          f"({B * num_tokens / dt:7.1f} tok/s)")


if __name__ == "__main__":
    for arch in ["smollm-360m", "gemma2-2b", "musicgen-medium",
                 "paligemma-3b", "zamba2-7b", "xlstm-1.3b"]:
        demo(arch)
