"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
Sentinel offload runtime, checkpointing and crash recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-width]

Default runs a width-reduced model sized for CPU; --full-width uses the real
smollm-360m config (360M params — sized for a TPU host).
"""
import argparse
import dataclasses

from repro.configs.base import get_config
from repro.core.offload import SentinelConfig
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    base = get_config("smollm-360m")
    if args.full_width:
        cfg = base
    else:
        # ~8M params: same family, laptop-scale
        cfg = dataclasses.replace(base, num_layers=8, d_model=256,
                                  num_heads=8, num_kv_heads=4, d_ff=1024,
                                  head_dim=32, vocab_size=4096,
                                  dtype="float32")

    scfg = SentinelConfig(mode="offload", mi_periods=2)
    ocfg = adamw.OptConfig(lr=3e-4, total_steps=args.steps,
                           warmup_steps=max(10, args.steps // 20))
    dcfg = DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    tcfg = loop.TrainConfig(steps=args.steps, ckpt_every=100,
                            ckpt_dir="/tmp/repro_train_lm", log_every=20)
    out = loop.run(cfg, tcfg, scfg, ocfg, dcfg)
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(from {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
