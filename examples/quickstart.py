"""Quickstart: the Sentinel pipeline end-to-end on one small model.

    PYTHONPATH=src python examples/quickstart.py

1. Build a model from the arch registry.
2. Profile one training step at the data-object level (the paper's §3).
3. Plan the migration interval via the unified runtime API
   (§4.4: Eq. 1/2 pruning + simulated sweep through the policy registry).
4. Train with the planned offload configuration.
5. Compare Sentinel vs the IAL baseline vs fast-memory-only on the simulator.
"""
import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs.base import get_config
from repro.core import profiler
from repro.core.hardware import PAPER_HM
from repro.core.offload import from_plan
from repro.data.pipeline import DataConfig
from repro.models import model
from repro.models.layers import split_params
from repro.optim import adamw
from repro.train import loop

ARCH = "smollm-360m"

# 1. model ------------------------------------------------------------------
cfg = get_config(ARCH).reduced()
params, _ = split_params(model.init_params(jax.random.PRNGKey(0), cfg))
print(f"[1] {ARCH} (reduced): {cfg.num_layers} layers, d={cfg.d_model}")

# 2. profile one step (exact, zero-overhead: jaxpr walk) ---------------------
pshapes = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
prof = profiler.trace_profile(
    jax.grad(lambda p, b: model.loss_fn(p, cfg, b, unroll_periods=True)),
    pshapes, batch, num_periods=cfg.num_periods)
acts = [o for o in prof.objects if o.kind == "activation"]
short = prof.short_lived(include_fused=True)
print(f"[2] profiled {len(prof.objects)} data objects; "
      f"{100 * len(short) / len(acts):.0f}% short-lived (paper Obs.1: ~92%); "
      f"peak {prof.peak_bytes() / 1e6:.1f} MB")

# 3. plan the migration interval --------------------------------------------
fast = 0.25 * prof.peak_bytes()
plan = runtime.plan(prof, PAPER_HM, fast)
print(f"[3] planned MI={plan.mi} ({plan.steps_used} steps used for p,m&t; "
      f"paper Table 3 uses 2-8); cases={plan.sim.cases}")

# 4. train with the planned Sentinel config ----------------------------------
scfg = from_plan(prof, plan)
out = loop.run(cfg,
               loop.TrainConfig(steps=20, ckpt_every=0,
                                ckpt_dir="/tmp/repro_quickstart"),
               scfg,
               adamw.OptConfig(total_steps=20, warmup_steps=2),
               DataConfig(seed=0, vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4))
print(f"[4] trained 20 steps with MI={scfg.mi_periods} offload blocks; "
      f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

# 5. the paper's comparison ---------------------------------------------------
fast_only = runtime.simulate(prof, PAPER_HM, fast, "all_fast")
ial = runtime.simulate(prof, PAPER_HM, fast, "ial")
print(f"[5] step-time vs fast-only: sentinel "
      f"{plan.sim.step_time / fast_only.step_time:.3f}x, "
      f"IAL {ial.step_time / fast_only.step_time:.3f}x "
      f"(paper: <=1.08x and ~1.17-1.32x)")
